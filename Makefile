# Repo tooling. `make bench` refreshes the committed BENCH_*.json perf
# trajectory (run it in any PR that touches the control plane); `make test`
# is the tier-1 gate.

PYTHONPATH := src

.PHONY: test bench bench-all

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json control_plane

bench-all:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json
