# Repo tooling. `make bench` refreshes the committed BENCH_*.json perf
# trajectory (run it in any PR that touches the control or data plane);
# `make test` is the tier-1 gate; `make bench-check` is the CI hook that
# re-runs the sweeps and fails on a >20% flatness/gain regression against
# the committed trajectory.

PYTHONPATH := src

.PHONY: test bench bench-all bench-check bench-check-ci chaos trace-report

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json control_plane pipeline_plane autoscale durability workloads observability train_throughput kernels_bench

# Full 50k-task chaos matrix (scripted master crashes, exactly-once
# verdicts) — the human-readable face of the durability suite
chaos:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.durability --chaos

bench-all:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json

bench-check:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.check

# CI variant: only the suites whose gated ratios are deterministic counts
# (RPCs per task, fabric-clock ticks, simulated byte ledgers) —
# control_plane's flatness ratios are wall-clock microseconds, too noisy to
# gate on shared CI runners, but its locality block (cross-boundary bytes
# per remote read, replica fan-out on/off) and notify block (cross-boundary
# bytes per delivered watch event, per-watcher round trips vs the
# replica-fed watch plane) are deterministic and gated here via suite:part
# specs.
# durability:recovery re-runs the chaos matrix at a CI-sized task count and
# gates hard zeros (lost/double-run tasks) plus the deterministic replay-
# amplification ratio — record counts, host-independent
# workloads:overhead gates the deterministic plane-RPCs-per-task count; the
# suite's wall-clock gates (plane-overhead ratio, compiled-step-cache gain)
# only run in the full `make bench-check`
# observability:overhead gates exact span accounting (5 spans per executed
# task, hard-zero lost/double-closed/leaked spans across one injected
# crash), trace bytes per task, and the hard-zero cross-boundary cost of a
# fleet-wide /metrics/ read — all deterministic ledgers; the tracing
# wall-clock ratio (observability:overhead_wall) only runs in the full
# `make bench-check`
bench-check-ci:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.check pipeline_plane autoscale control_plane:locality control_plane:notify durability:recovery durability:migration workloads:overhead observability:overhead

# the flight recorder's human view: critical-path decomposition of the
# slowest trace on a freshly traced DAG (queue-wait vs execute vs commit)
trace-report:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.observability --report
