# Repo tooling. `make bench` refreshes the committed BENCH_*.json perf
# trajectory (run it in any PR that touches the control or data plane);
# `make test` is the tier-1 gate; `make bench-check` is the CI hook that
# re-runs the sweeps and fails on a >20% flatness/gain regression against
# the committed trajectory.

PYTHONPATH := src

.PHONY: test bench bench-all bench-check

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json control_plane pipeline_plane

bench-all:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json

bench-check:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.check
