"""Elastic + fault-tolerant training through the management plane.

Timeline: dispatch a training job to a 2-cluster fleet -> kill the hosting
cluster mid-run -> failure detector fires -> the dispatcher re-dispatches from
the last committed checkpoint manifest -> a NEW cluster joins and is visible to
subsequent placements. Prints the plane's op log tail as the audit trail.

  PYTHONPATH=src python examples/elastic_training.py
"""
from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.runtime.elastic import ElasticController
from repro.runtime.local_plane import JaxLocalPlane


def add_jax_cluster(plane, name):
    plane.add_cluster(name, local_plane=JaxLocalPlane(
        steps_per_poll=3,
        publish=lambda jid, man, _n=name: plane.agents[_n].ow.put(
            f"/checkpoints/{jid}", man),
        checkpoint_root=f"/tmp/titchener_elastic/{name}"))


def main() -> None:
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    for n in ("zone-a", "zone-b"):
        add_jax_cluster(plane, n)

    memberships = []
    ElasticController(plane.overwatch,
                      lambda m: memberships.append(tuple(m)))

    jid = plane.submit_job(
        "train", arch="qwen3-0.6b", steps=12, tags={"requires": ("train",)},
        payload={"arch": "qwen3-0.6b", "steps": 12, "seq_len": 16,
                 "global_batch": 2, "checkpoint_every": 4})
    # run until the first checkpoint manifest commits
    for _ in range(40):
        plane.tick()
        if plane.overwatch.handle(
                {"op": "get", "key": f"/checkpoints/{jid}"})["value"]:
            break
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    print(f"checkpoint committed while running on {placed}; killing it")
    plane.fabric.partition_cluster(placed)

    add_jax_cluster(plane, "zone-c")          # elastic join mid-failure
    assert plane.run_until_done([jid], max_ticks=300)
    st = plane.job_status(jid)
    print(f"job finished on {st['cluster']} (progress {st['progress']}, "
          f"loss {st.get('loss')})")
    assert st["cluster"] != placed
    print(f"membership transitions seen by the elastic controller: "
          f"{len(memberships)}")
    print("last membership:", memberships[-1])
    print("\noverwatch op-log tail (the audit trail):")
    for rev, op, key, _ in plane.overwatch.op_log[-5:]:
        print(f"  rev {rev:4d} {op:7s} {key}")


if __name__ == "__main__":
    main()
