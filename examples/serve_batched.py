"""Continuous-batching serving example: mixed prompt/generation lengths share
decode slots; results are identical to unbatched greedy decoding.

  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.runtime.serve_loop import Server, ServeJobConfig


def main() -> None:
    server = Server(ServeJobConfig(arch="qwen3-0.6b", slots=3, max_len=96))
    prompts = [([1, 2, 3, 4, 5], 12), ([9, 8], 4), ([7, 7, 7], 8),
               ([2, 4, 6], 6), ([5], 10), ([3, 1, 4, 1, 5], 5)]
    for p, n in prompts:
        server.submit(p, max_new=n)
    done = server.run()
    total_new = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {total_new} tokens in "
          f"{server.steps} batched decode steps "
          f"(vs {total_new} unbatched steps)")
    for r in done:
        print(f"  {r.req_id}: {r.prompt} -> {r.generated}")
    assert len(done) == len(prompts)


if __name__ == "__main__":
    main()
