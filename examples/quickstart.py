"""Quickstart: the single pane of glass in ~40 lines.

Builds a hybrid fleet (public master + two private clusters), dispatches a
real JAX training job and a serving job through the SAME interface, and prints
the boundary-traffic ledger — the paper's three claims in one script.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.runtime.local_plane import JaxLocalPlane


def main() -> None:
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    for name in ("onprem-a", "onprem-b"):
        plane.add_cluster(name, local_plane=JaxLocalPlane(
            publish=lambda jid, man, _n=name: plane.agents[_n].ow.put(
                f"/checkpoints/{jid}", man),
            checkpoint_root="/tmp/titchener_quickstart"))

    train_id = plane.submit_job(
        "train", arch="qwen3-0.6b", steps=10,
        tags={"requires": ("train",)},
        payload={"arch": "qwen3-0.6b", "steps": 10, "seq_len": 32,
                 "global_batch": 4, "checkpoint_every": 5})
    serve_id = plane.submit_job(
        "serve", arch="qwen3-0.6b", tags={"requires": ("serve",)},
        payload={"arch": "qwen3-0.6b", "slots": 2, "max_len": 64,
                 "requests": [{"prompt": [1, 2, 3], "max_new": 5},
                              {"prompt": [7, 8], "max_new": 4}]})

    assert plane.run_until_done([train_id, serve_id], max_ticks=300)
    for jid in (train_id, serve_id):
        st = plane.job_status(jid)
        print(f"{jid}: {st['status']} on {st['cluster']} "
              f"(progress {st['progress']})")

    rep = plane.boundary_report()
    print(f"cross-cloud bytes: {rep['cross_cluster_bytes']:,} "
          f"(locality {rep['locality_ratio']:.1%} local) — "
          "the paper's thin boundary, measured")


if __name__ == "__main__":
    main()
