"""Paper §5 end to end: a hybrid Airflow/Composer ETL->train->eval->export DAG.

Scheduler/broker/taskdb live on the public master; one worker is public (the
cheap IO tier), one is on-prem (the accelerator tier). The 'train' task is
compliance-tagged to run on-prem (the paper's "data must stay private" case);
every hop between worker and broker/db crosses the hybrid platform's gateways.

Two workload optimizations ride the same run:

  * roofline-cost-aware routing (``cost_aware=True``): each task is priced as
    a cost vector and its steering tag joins the queue name — the compute-
    bound train/eval stages ride the ``accel`` queues to the on-prem worker,
    the IO-bound extract/export stages ride ``cheap-io`` to the public one;
  * the compiled-step cache: train and eval share one warm jit-compiled
    Trainer on the on-prem worker (eval re-binds it instead of rebuilding).

  PYTHONPATH=src python examples/hybrid_pipeline.py
"""
import tempfile

from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.pipelines import DAG, Task, HybridComposer


def main() -> None:
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control", "cheap-io")))
    plane.add_cluster("onprem",
                      local_plane=SimLocalPlane(caps=("cpu", "onprem",
                                                      "accel")))
    comp = HybridComposer(
        plane,
        workers={"master": ["w-public"], "onprem": ["w-onprem"]},
        # queue names are capability sets: with cost_aware on, the steered
        # queues are the steering tags (plus any compliance pins), so each
        # worker subscribes the queues its tier should drain
        worker_queues={"w-public": ("cheap-io", "default"),
                       "w-onprem": ("accel", "accel,onprem", "onprem",
                                    "default")},
        cost_aware=True)

    ck_dir = tempfile.mkdtemp(prefix="titchener_pipeline_ck_")
    dag = DAG("daily_finetune", [
        Task("extract", kind="etl", payload={"batches": 3, "seq_len": 32}),
        Task("train_private", kind="train", upstream=("extract",),
             requires=("onprem",),                 # compliance pin
             payload={"arch": "qwen3-0.6b", "steps": 6, "seq_len": 32,
                      "global_batch": 4, "checkpoint_dir": ck_dir}),
        Task("evaluate", kind="eval", upstream=("train_private",),
             payload={"arch": "qwen3-0.6b", "seq_len": 32, "global_batch": 4,
                      "restore_from": {"path": ck_dir}}),
        Task("export", kind="export", upstream=("evaluate",),
             payload={"arch": "qwen3-0.6b"}),
    ])
    comp.add_dag(dag)
    ok = comp.run_dag("daily_finetune", max_ticks=400)
    print("DAG success:", ok)
    state = comp.taskdb.handle({"op": "dag_state",
                                "dag": "daily_finetune"})["tasks"]
    for name, row in sorted(state.items()):
        print(f"  {name:15s} {row['status']:8s} worker={row.get('worker')} "
              f"result={row.get('result')}")
    # cost-aware steering: compute-bound stages on the accel tier, IO-bound
    # on the cheap tier; train+eval shared one warm compiled Trainer
    assert state["train_private"]["worker"] == "w-onprem"
    assert state["evaluate"]["worker"] == "w-onprem"
    assert state["extract"]["worker"] == "w-public"
    assert state["export"]["worker"] == "w-public"
    cache = comp.workers[1]._trainer_cache
    if cache is not None:
        print(f"compiled-step cache: {cache.stats()}")
    rep = plane.boundary_report()
    print(f"cross-cloud bytes {rep['cross_cluster_bytes']:,}, "
          f"locality {rep['locality_ratio']:.1%}")
    assert ok
    assert state["evaluate"]["result"]["restored_step"] == 6
    assert state["train_private"]["result"]["ran_steps"] == 6


if __name__ == "__main__":
    main()
