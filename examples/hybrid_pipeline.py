"""Paper §5 end to end: a hybrid Airflow/Composer ETL->train->eval->export DAG.

Scheduler/broker/taskdb live on the public master; one worker is public, one is
on-prem. The 'train' task is compliance-tagged to run on-prem (the paper's
"data must stay private" case); every hop between worker and broker/db crosses
the hybrid platform's gateways.

  PYTHONPATH=src python examples/hybrid_pipeline.py
"""
from repro.core.plane import ManagementPlane
from repro.pipelines import DAG, Task, HybridComposer


def main() -> None:
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem")
    comp = HybridComposer(
        plane,
        workers={"master": ["w-public"], "onprem": ["w-onprem"]},
        worker_queues={"w-public": ("default",),
                       "w-onprem": ("onprem", "default")})

    dag = DAG("daily_finetune", [
        Task("extract", kind="etl", payload={"batches": 3, "seq_len": 32}),
        Task("train_private", kind="train", upstream=("extract",),
             requires=("onprem",),                 # compliance pin
             payload={"arch": "qwen3-0.6b", "steps": 6, "seq_len": 32,
                      "global_batch": 4,
                      "checkpoint_dir": "/tmp/titchener_pipeline_ck"}),
        Task("evaluate", kind="eval", upstream=("train_private",),
             payload={"arch": "qwen3-0.6b", "seq_len": 32, "global_batch": 4,
                      "restore_from": {"path": "/tmp/titchener_pipeline_ck"}}),
        Task("export", kind="export", upstream=("evaluate",),
             payload={"arch": "qwen3-0.6b"}),
    ])
    comp.add_dag(dag)
    ok = comp.run_dag("daily_finetune", max_ticks=400)
    print("DAG success:", ok)
    state = comp.taskdb.handle({"op": "dag_state",
                                "dag": "daily_finetune"})["tasks"]
    for name, row in sorted(state.items()):
        print(f"  {name:15s} {row['status']:8s} worker={row.get('worker')} "
              f"result={row.get('result')}")
    rep = plane.boundary_report()
    print(f"cross-cloud bytes {rep['cross_cluster_bytes']:,}, "
          f"locality {rep['locality_ratio']:.1%}")
    assert ok


if __name__ == "__main__":
    main()
