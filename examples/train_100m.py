"""End-to-end driver: train a ~100M-parameter model for a few hundred steps.

Uses the qwen3-0.6b family at width 512/12L (~100M params incl. embeddings) on
the synthetic next-token 'ramp' task; loss must fall well below the uniform
baseline ln(1024)=6.93 — the curve is printed every 20 steps.

  PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import dataclasses
import math
import time

import jax

from repro.configs import base as configs
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import init_train_state, make_train_step
from repro.models.model import Model
from repro.data.pipeline import SyntheticTokens
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import MeshPlan


def build_100m():
    base = configs.get("qwen3-0.6b")
    return dataclasses.replace(
        base, num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=1536, vocab_size=32_000, remat="none",
        max_context=2048)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = build_100m()
    print(f"params: {cfg.param_count()/1e6:.1f}M")
    model = Model(cfg, MeshPlan(mesh=make_test_mesh(), fsdp=False))
    state = init_train_state(model, jax.random.PRNGKey(0))
    opt = AdamWConfig(peak_lr=3e-3, warmup_steps=30, total_steps=args.steps,
                      weight_decay=0.01)
    step_fn = jax.jit(make_train_step(model, opt, 1))
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                           global_batch=args.batch, task="ramp")

    t0, losses = time.time(), []
    for i in range(args.steps):
        state, m = step_fn(state, data.global_batch_at(i))
        losses.append(float(m["loss"]))
        if (i + 1) % 20 == 0 or i == 0:
            rate = args.batch * args.seq_len * (i + 1) / (time.time() - t0)
            print(f"step {i+1:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(m['lr']):.2e}  {rate:,.0f} tok/s")

    uniform = math.log(min(cfg.vocab_size, 1024))
    print(f"\nfinal loss {losses[-1]:.3f} vs uniform {uniform:.3f}")
    if args.steps >= 150:
        assert losses[-1] < uniform - 2.0, "model failed to learn ramp task"
        print("learned the next-token structure — end-to-end training works")
    else:
        # smoke-sized run (the CI example test uses --steps 40): the full bar
        # needs the lr schedule to play out; just require real learning
        assert losses[-1] < losses[0] - 1.0, "loss did not fall"
        print("loss falling — end-to-end training works (smoke-sized run)")


if __name__ == "__main__":
    main()
