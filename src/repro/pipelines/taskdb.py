"""Task-instance database (the Airflow metadata SQL DB, paper §5).

Hosted on the master partition; workers commit every finished task here (the
paper: "commit each finished task to an SQL database"). Rows are keyed
(dag_id, task, try_number) with status transitions
queued -> running -> success | failed.
"""
from __future__ import annotations

from typing import Dict, Optional


class TaskDB:
    """In-memory table behind a service handler (swap for CloudSQL in prod)."""

    def __init__(self):
        self.rows: Dict[tuple, dict] = {}

    # ---------------------------------------------------------------- service API
    def handle(self, msg: dict) -> dict:
        op = msg.get("op")
        if op == "upsert":
            key = (msg["dag"], msg["task"], int(msg.get("try", 1)))
            row = self.rows.setdefault(key, {"dag": msg["dag"],
                                             "task": msg["task"],
                                             "try": key[2]})
            for k in ("status", "worker", "result", "clock", "error"):
                if k in msg:
                    row[k] = msg[k]
            return {"ok": True}
        if op == "get":
            key = (msg["dag"], msg["task"], int(msg.get("try", 1)))
            return {"ok": True, "row": self.rows.get(key)}
        if op == "latest":
            rows = [r for (d, t, _), r in self.rows.items()
                    if d == msg["dag"] and t == msg["task"]]
            rows.sort(key=lambda r: r["try"])
            return {"ok": True, "row": rows[-1] if rows else None}
        if op == "dag_state":
            out = {}
            for (d, t, n), r in self.rows.items():
                if d != msg["dag"]:
                    continue
                cur = out.get(t)
                if cur is None or n > cur["try"]:
                    out[t] = r
            return {"ok": True, "tasks": out}
        return {"ok": False, "error": f"unknown op {op}"}
