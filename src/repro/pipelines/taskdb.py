"""Task-instance database (the Airflow metadata SQL DB, paper §5).

Hosted on the master partition; workers commit every finished task here (the
paper: "commit each finished task to an SQL database"). Rows are keyed
(dag_id, task, try_number) with status transitions
queued -> running -> success | failed.

Hot path (the scaling overhaul): the DB maintains a per-DAG latest-try view
and a per-DAG change log, so

  * ``dag_state`` / ``latest`` no longer scan every row in the table;
  * the ``dag_delta`` op gives the scheduler incremental dirty-task
    deltas — rows changed since a cursor — so a quiescent DAG costs O(1)
    per scheduler tick instead of a full state dump;
  * ``dag_delta_many`` multiplexes the deltas of every registered DAG into
    one call — the scheduler pays a single taskdb round-trip per tick no
    matter how many DAGs it owns;
  * ``upsert_many`` applies a whole batch of rows (in order) in one
    round-trip — a worker commits an executed pull batch (running + terminal
    row per task) and the scheduler commits a whole ready frontier with a
    single RPC instead of one per row.

Durability: with a ``LogStore`` attached, every upsert batch appends one
``("upN", rows)`` WAL record; the snapshot is simply the row table. Replay
re-runs ``_upsert`` (idempotent per key — the last write for a (dag, task,
try) wins, exactly like live traffic) and marks every replayed row dirty, so
a recovering scheduler probing from cursor 0 sees the complete state.
``status_many`` is the workers' post-crash dedup probe: the status of each
(dag, task, try) key, None for unknown rows.
"""
from __future__ import annotations

import bisect
from collections import Counter
from typing import Dict, List, Tuple


class TaskDB:
    """In-memory table behind a service handler (swap for CloudSQL in prod)."""

    def __init__(self, durability=None, shard_name: str = "taskdb"):
        self.rows: Dict[tuple, dict] = {}
        # dag -> task -> latest-try row (same row objects as self.rows)
        self._latest: Dict[str, Dict[str, dict]] = {}
        self._seq = 0
        # dag -> append-only [(seq, task)] change log, compacted when it
        # outgrows the task count (bounded memory, cursor-stable)
        self._changes: Dict[str, List[Tuple[int, str]]] = {}
        self.op_counts: Counter = Counter()          # per-op RPC accounting
        self._dur = durability
        self._shard = shard_name
        self.recovery_replayed = 0
        if durability is not None and durability.has_data(shard_name):
            self.recover()

    def _mark_dirty(self, dag: str, task: str) -> None:
        self._seq += 1
        log = self._changes.setdefault(dag, [])
        log.append((self._seq, task))
        tasks = self._latest.get(dag, {})
        if len(log) > 4 * max(len(tasks), 8):
            last: Dict[str, int] = {}
            for seq, t in log:
                last[t] = seq
            log[:] = sorted((s, t) for t, s in last.items())

    def _upsert(self, msg: dict) -> None:
        key = (msg["dag"], msg["task"], int(msg.get("try", 1)))
        row = self.rows.setdefault(key, {"dag": msg["dag"],
                                         "task": msg["task"],
                                         "try": key[2]})
        for k in ("status", "worker", "result", "clock", "error"):
            if k in msg:
                row[k] = msg[k]
        latest = self._latest.setdefault(msg["dag"], {})
        cur = latest.get(msg["task"])
        if cur is None or key[2] >= cur["try"]:
            latest[msg["task"]] = row
        self._mark_dirty(msg["dag"], msg["task"])

    # ---------------------------------------------------------------- service API
    def handle(self, msg: dict) -> dict:
        op = msg.get("op")
        self.op_counts[op] += 1
        if op == "upsert":
            self._upsert(msg)
            if self._dur is not None:
                self._dur.append(self._shard, ("upN", [msg]))
            return {"ok": True}
        if op == "upsert_many":
            # one batched commit: rows apply in list order, so a worker's
            # running->terminal pair lands as the same transition sequence the
            # per-row protocol produced
            for row in msg["rows"]:
                self._upsert(row)
            if self._dur is not None:
                self._dur.append(self._shard, ("upN", msg["rows"]))
            return {"ok": True, "n": len(msg["rows"])}
        if op == "status_many":
            # post-crash dedup probe: status per (dag, task, try), None if the
            # row is unknown (a read — creates nothing, logs nothing)
            statuses = [
                (self.rows.get((k[0], k[1], int(k[2]))) or {}).get("status")
                for k in msg["keys"]]
            return {"ok": True, "statuses": statuses}
        if op == "get":
            key = (msg["dag"], msg["task"], int(msg.get("try", 1)))
            return {"ok": True, "row": self.rows.get(key)}
        if op == "latest":
            row = self._latest.get(msg["dag"], {}).get(msg["task"])
            return {"ok": True, "row": row}
        if op == "dag_state":
            return {"ok": True,
                    "tasks": dict(self._latest.get(msg["dag"], {}))}
        if op == "dag_delta":
            return self._dag_delta(msg["dag"], int(msg.get("since", 0)))
        if op == "dag_delta_many":
            deltas = {}
            for dag, since in msg["dags"].items():
                tasks = self._dag_delta(dag, int(since))["tasks"]
                if tasks:
                    deltas[dag] = tasks
            return {"ok": True, "deltas": deltas, "cursor": self._seq}
        return {"ok": False, "error": f"unknown op {op}"}

    # ------------------------------------------------------------- durability
    def snapshot_payload(self) -> dict:
        return {"rows": [dict(r) for r in self.rows.values()]}

    def recover(self) -> None:
        """Snapshot rows + replayed WAL batches through the normal ``_upsert``
        path: the latest-try view and change log rebuild as a side effect, and
        every recovered row is dirty from cursor 0 — a fresh scheduler's first
        probe sees the full surviving state."""
        dur = self._dur
        self._dur = None
        try:
            payload, records = dur.load(self._shard)
            if payload:
                for row in payload["rows"]:
                    self._upsert(row)
            for rec in records:
                for row in rec[1]:
                    self._upsert(row)
            self.recovery_replayed = len(records)
        finally:
            self._dur = dur

    def _dag_delta(self, dag: str, since: int) -> dict:
        """Latest rows for tasks changed after cursor ``since``."""
        log = self._changes.get(dag, [])
        i = bisect.bisect_left(log, (since + 1,))
        latest = self._latest.get(dag, {})
        tasks = {}
        for _, t in log[i:]:
            if t not in tasks and t in latest:
                tasks[t] = latest[t]
        return {"ok": True, "tasks": tasks, "cursor": self._seq}
