"""HybridComposer — the paper's §5 use case, end to end.

Embeds the Airflow components as pods/services of an AppSpec over the hybrid
platform: scheduler + broker + taskdb on the master (public) partition, workers
on any partitions (private clusters included). ``upload()`` runs the
configuration phase (CRD broadcast -> Algorithm 5 in every agent); afterwards
workers on private partitions consume the master-hosted broker/DB purely
through gateway routes — Figure 3 of the paper, reproduced as a test (see
tests/test_pipelines.py, which also asserts the ACLs block any pod NOT in the
dependency graph).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.plane import ManagementPlane
from repro.core.service_graph import AppSpec, Pod, Service
from repro.pipelines.broker import Broker
from repro.pipelines.dag import DAG
from repro.pipelines.scheduler import Scheduler
from repro.pipelines.services import ServiceClient, ServiceEndpoint
from repro.pipelines.taskdb import TaskDB
from repro.pipelines.worker import PipelineWorker

BROKER_PORT = 6379      # the paper's redis
TASKDB_PORT = 5432      # the paper's SQL database


def composer_appspec(master: str,
                     workers: Dict[str, Sequence[str]]) -> AppSpec:
    """workers: cluster -> worker pod names hosted there."""
    pods = [Pod("scheduler-pod", needs=("broker", "taskdb")),
            Pod("broker-pod", needs=()),
            Pod("taskdb-pod", needs=())]
    partition = {"scheduler-pod": master, "broker-pod": master,
                 "taskdb-pod": master}
    for cluster, names in workers.items():
        for w in names:
            pods.append(Pod(w, needs=("broker", "taskdb")))
            partition[w] = cluster
    services = (Service("broker", BROKER_PORT, ("broker-pod",)),
                Service("taskdb", TASKDB_PORT, ("taskdb-pod",)))
    return AppSpec(services=services, pods=tuple(pods), partition=partition)


class HybridComposer:
    def __init__(self, plane: ManagementPlane,
                 workers: Dict[str, Sequence[str]],
                 worker_queues: Optional[Dict[str, Tuple[str, ...]]] = None):
        self.plane = plane
        self.spec = composer_appspec(plane.master, workers)
        plane.upload_spec(self.spec)

        fabric = plane.fabric
        master_state = plane.master_agent.state
        self.broker = Broker(clock_fn=lambda: fabric.clock)
        self.taskdb = TaskDB()
        ServiceEndpoint(fabric, self.spec, master_state, "broker",
                        self.broker.handle)
        ServiceEndpoint(fabric, self.spec, master_state, "taskdb",
                        self.taskdb.handle)

        sched_client = ServiceClient(fabric, master_state, "scheduler-pod")
        self.scheduler = Scheduler(sched_client, clock_fn=lambda: fabric.clock)

        self.workers: List[PipelineWorker] = []
        for cluster, names in workers.items():
            state = plane.agents[cluster].state
            for w in names:
                client = ServiceClient(fabric, state, w)
                queues = (worker_queues or {}).get(w, ("default",))
                self.workers.append(PipelineWorker(
                    client, w, queues=queues, clock_fn=lambda: fabric.clock))

    # ------------------------------------------------------------------- user API
    def add_dag(self, dag: DAG) -> None:
        self.scheduler.add_dag(dag)

    def tick(self) -> None:
        self.scheduler.tick()
        for w in self.workers:
            w.tick()
        self.plane.tick()

    def run_dag(self, dag_id: str, max_ticks: int = 500) -> bool:
        for _ in range(max_ticks):
            self.tick()
            if self.scheduler.dag_done(dag_id):
                return self.scheduler.dag_success(dag_id)
        return False

    def status(self, dag_id: str) -> Dict[str, str]:
        return self.scheduler.dag_status(dag_id)
