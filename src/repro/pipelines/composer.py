"""HybridComposer — the paper's §5 use case, end to end.

Embeds the Airflow components as pods/services of an AppSpec over the hybrid
platform: scheduler + broker + taskdb on the master (public) partition, workers
on any partitions (private clusters included). ``upload()`` runs the
configuration phase (CRD broadcast -> Algorithm 5 in every agent); afterwards
workers on private partitions consume the master-hosted broker/DB purely
through gateway routes — Figure 3 of the paper, reproduced as a test (see
tests/test_pipelines.py, which also asserts the ACLs block any pod NOT in the
dependency graph).

The composer also drives the broker's depth telemetry: on a sweep cadence
(``depth_publish_every`` fabric-clock units, only queues whose counts moved)
it publishes ``{"ready", "inflight"}`` under ``/queues/<name>`` in the
overwatch via the master agent, which feeds the dispatcher's materialized
queue-depth view — the "place workers near deep queues" loop.

``pipelined=True`` (default) runs the batched data plane end to end: the
scheduler coalesces each tick's frontier into one ``upsert_many`` plus one
``push_many`` per queue, and workers drain ``worker_batch`` tasks per
``pull_many`` and commit through ``upsert_many``/``ack_many``.
``pipelined=False`` keeps the seed's per-task protocol (4+ RPCs per task) —
the two produce identical terminal taskdb states.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.plane import ManagementPlane
from repro.core.service_graph import AppSpec, Pod, Service
from repro.pipelines.broker import Broker
from repro.pipelines.dag import DAG
from repro.pipelines.scheduler import Scheduler
from repro.pipelines.services import ServiceClient, ServiceEndpoint
from repro.pipelines.taskdb import TaskDB
from repro.pipelines.worker import PipelineWorker

BROKER_PORT = 6379      # the paper's redis
TASKDB_PORT = 5432      # the paper's SQL database


def composer_appspec(master: str,
                     workers: Dict[str, Sequence[str]]) -> AppSpec:
    """workers: cluster -> worker pod names hosted there."""
    pods = [Pod("scheduler-pod", needs=("broker", "taskdb")),
            Pod("broker-pod", needs=()),
            Pod("taskdb-pod", needs=())]
    partition = {"scheduler-pod": master, "broker-pod": master,
                 "taskdb-pod": master}
    for cluster, names in workers.items():
        for w in names:
            pods.append(Pod(w, needs=("broker", "taskdb")))
            partition[w] = cluster
    services = (Service("broker", BROKER_PORT, ("broker-pod",)),
                Service("taskdb", TASKDB_PORT, ("taskdb-pod",)))
    return AppSpec(services=services, pods=tuple(pods), partition=partition)


class HybridComposer:
    def __init__(self, plane: ManagementPlane,
                 workers: Dict[str, Sequence[str]],
                 worker_queues: Optional[Dict[str, Tuple[str, ...]]] = None,
                 worker_batch: int = 16, pipelined: bool = True,
                 depth_publish_every: float = 1.0):
        self.plane = plane
        self.spec = composer_appspec(plane.master, workers)
        plane.upload_spec(self.spec)

        fabric = plane.fabric
        master_state = plane.master_agent.state
        self.broker = Broker(clock_fn=lambda: fabric.clock)
        self.taskdb = TaskDB()
        ServiceEndpoint(fabric, self.spec, master_state, "broker",
                        self.broker.handle)
        ServiceEndpoint(fabric, self.spec, master_state, "taskdb",
                        self.taskdb.handle)

        sched_client = ServiceClient(fabric, master_state, "scheduler-pod")
        self.scheduler = Scheduler(sched_client, clock_fn=lambda: fabric.clock,
                                   batched=pipelined)

        self.workers: List[PipelineWorker] = []
        for cluster, names in workers.items():
            state = plane.agents[cluster].state
            for w in names:
                client = ServiceClient(fabric, state, w)
                queues = (worker_queues or {}).get(w, ("default",))
                self.workers.append(PipelineWorker(
                    client, w, queues=queues, clock_fn=lambda: fabric.clock,
                    batch=worker_batch, pipelined=pipelined))
        self.depth_publish_every = depth_publish_every
        self._depth_published_at: Optional[float] = None

    # ------------------------------------------------------------------- user API
    def add_dag(self, dag: DAG) -> None:
        self.scheduler.add_dag(dag)

    def tick(self) -> None:
        self.scheduler.tick()
        for w in self.workers:
            w.tick()
        self.publish_queue_depths()
        self.plane.tick()

    # ------------------------------------------------------------ depth telemetry
    def publish_queue_depths(self) -> None:
        """Sweep-cadence depth publication: at most once per
        ``depth_publish_every`` fabric-clock units, put the (ready, inflight)
        counts of every queue whose depth changed under ``/queues/<name>`` —
        a handful of coalesce-friendly puts, not one per queue per tick."""
        now = self.plane.fabric.clock
        if (self._depth_published_at is not None
                and now - self._depth_published_at < self.depth_publish_every):
            return
        self._depth_published_at = now
        ow = self.plane.master_agent.ow
        for queue, depth in self.broker.changed_depths().items():
            ow.put(f"/queues/{queue}", {**depth, "clock": now})

    def run_dag(self, dag_id: str, max_ticks: int = 500) -> bool:
        for _ in range(max_ticks):
            self.tick()
            # probe-free doneness: the next tick's shared probe folds in any
            # commits this tick's workers made, so the check lags by at most
            # one tick instead of paying a second delta RPC every tick
            if self.scheduler.dag_done(dag_id, probe=False):
                return self.scheduler.dag_success(dag_id, probe=False)
        # budget exhausted: one probed check so a DAG finishing on the very
        # last tick isn't misreported by the one-tick observation lag
        if self.scheduler.dag_done(dag_id):
            return self.scheduler.dag_success(dag_id, probe=False)
        return False

    def status(self, dag_id: str) -> Dict[str, str]:
        return self.scheduler.dag_status(dag_id)
