"""HybridComposer — the paper's §5 use case, end to end.

Embeds the Airflow components as pods/services of an AppSpec over the hybrid
platform: scheduler + broker + taskdb on the master (public) partition, workers
on any partitions (private clusters included). ``upload()`` runs the
configuration phase (CRD broadcast -> Algorithm 5 in every agent); afterwards
workers on private partitions consume the master-hosted broker/DB purely
through gateway routes — Figure 3 of the paper, reproduced as a test (see
tests/test_pipelines.py, which also asserts the ACLs block any pod NOT in the
dependency graph).

The composer also drives the broker's depth telemetry: on a sweep cadence
(``depth_publish_every`` fabric-clock units, only queues whose counts moved)
it publishes ``{"ready", "inflight"}`` under ``/queues/<name>`` in the
overwatch via the master agent, which feeds the dispatcher's materialized
queue-depth view — the "place workers near deep queues" loop. A queue that
drains to zero is TOMBSTONED (the key is deleted) rather than left at a
stale 0/0, so the depth view only ever lists queues with live backlog.

Worker fleets are elastic: ``add_worker``/``remove_worker`` grow and shrink
the pod set at runtime — each change rebuilds the AppSpec and re-broadcasts
it (Algorithm 5 re-runs on every agent: DNS/routes idempotently, ACLs from
scratch), so a new worker pod gains broker/taskdb access the moment it lands
and a removed pod loses it. ``attach_autoscaler`` wires the
``repro.autoscale`` reconciler into the tick loop: the published queue
depths drive worker-pod placement and retirement with no manual sizing.

``broker_shards=N`` splits the broker per queue family behind a
``BrokerRouter`` (consistent hash over queue names, the overwatch shard
discipline): one ``Broker`` + one service/fabric endpoint per shard, with the
scheduler and every worker routing each queue's ops to its owning shard —
disjoint families stop serializing through one handler. One shard keeps the
single historic ``"broker"`` service and is behavior-identical.
``depth_gated_workers=True`` (needs the plane's replica fan-out) lets remote
workers consult their cluster's watch-materialized ``/queues/`` view — fed by
the replica notify plane, one shipped envelope per sweep however many workers
subscribe — and skip the cross-boundary ``pull_many`` for queues the local
view shows empty.

``pipelined=True`` (default) runs the batched data plane end to end: the
scheduler coalesces each tick's frontier into one ``upsert_many`` plus one
``push_many`` per queue, and workers drain ``worker_batch`` tasks per
``pull_many`` and commit through ``upsert_many``/``ack_many``.
``pipelined=False`` keeps the seed's per-task protocol (4+ RPCs per task) —
the two produce identical terminal taskdb states.

Crash survival (the durable control plane): with a shared ``LogStore``
(``durability=``) the taskdb and every broker shard write WAL records as they
mutate, group-committed once per composer tick — taskdb FIRST, then brokers,
so an ack can only be durable if the rows it covers are too (the invariant
that makes post-crash redelivery loss-free). ``recover()`` rebuilds the whole
master-hosted pipeline after a crash of the global plane: fresh
broker/taskdb services replay their snapshots + WAL onto the same fabric
addresses, a fresh scheduler re-registers the DAGs and probes the recovered
table from cursor 0, surviving workers run the recovery barrier (drop
unexecuted leases, retry interrupted commits verbatim, re-upsert their
``recent_rows`` resync rings), the autoscaler is rebuilt and ADOPTS the
surviving worker-pod fleet from overwatch placements, and ``_reseed_tasks``
re-pushes (flagged redelivered) any queued/running task whose broker message
died with the uncommitted tail. Exactly-once for executions holds across any
master crash; the one fundamental exception — a PARTITIONED worker's
executed-but-unlanded batch may re-run elsewhere — is the classic
impossibility, not a recovery bug.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.plane import ManagementPlane
from repro.core.service_graph import AppSpec, Pod, Service
from repro.core.transport import DeliveryError, StaleEpochError
from repro.pipelines.broker import Broker, BrokerRouter, broker_service_names
from repro.pipelines.dag import DAG
from repro.pipelines.scheduler import Scheduler, queue_for
from repro.pipelines.services import ServiceClient, ServiceEndpoint
from repro.pipelines.taskdb import TaskDB
from repro.pipelines.worker import PipelineWorker

BROKER_PORT = 6379      # the paper's redis
TASKDB_PORT = 5432      # the paper's SQL database


def composer_appspec(master: str, workers: Dict[str, Sequence[str]],
                     broker_shards: int = 1) -> AppSpec:
    """workers: cluster -> worker pod names hosted there. With
    ``broker_shards > 1`` the broker is one service PER SHARD
    (``broker-s<k>``, consecutive ports) so each shard gets its own fabric
    endpoint and gateway tunnels; one shard keeps the historic single
    ``"broker"`` service — the AppSpec is byte-identical to pre-sharding."""
    broker_svcs = broker_service_names(broker_shards)
    needs = tuple(broker_svcs) + ("taskdb",)
    pods = [Pod("scheduler-pod", needs=needs),
            Pod("broker-pod", needs=()),
            Pod("taskdb-pod", needs=())]
    partition = {"scheduler-pod": master, "broker-pod": master,
                 "taskdb-pod": master}
    for cluster, names in workers.items():
        for w in names:
            pods.append(Pod(w, needs=needs))
            partition[w] = cluster
    services = tuple(Service(s, BROKER_PORT + i, ("broker-pod",))
                     for i, s in enumerate(broker_svcs))
    services += (Service("taskdb", TASKDB_PORT, ("taskdb-pod",)),)
    return AppSpec(services=services, pods=tuple(pods), partition=partition)


class HybridComposer:
    def __init__(self, plane: ManagementPlane,
                 workers: Dict[str, Sequence[str]],
                 worker_queues: Optional[Dict[str, Tuple[str, ...]]] = None,
                 worker_batch: int = 16, pipelined: bool = True,
                 depth_publish_every: float = 1.0,
                 worker_setup=None,
                 broker_shards: int = 1,
                 depth_gated_workers: bool = False,
                 depth_gate_max_lag: float = 2.0,
                 durability=None,
                 wal_snapshot_every: int = 8192,
                 cost_aware: bool = False,
                 step_cache: int = 4,
                 trace_sample: float = 0.0,
                 tracer=None):
        self.plane = plane
        self.worker_batch = worker_batch
        self.pipelined = pipelined
        # flight recorder: an explicit tracer wins, else trace_sample > 0
        # creates one on the fabric clock, else the plane's own (if any).
        # No tracer anywhere => no "trace" keys ever attached => every fabric
        # payload is byte-identical to the uninstrumented plane.
        if tracer is not None:
            self.tracer = tracer
        elif trace_sample > 0:
            from repro.observability.trace import Tracer
            self.tracer = Tracer(clock_fn=lambda: plane.fabric.clock,
                                 sample=trace_sample)
        else:
            self.tracer = getattr(plane, "tracer", None)
        # roofline-cost-aware queue routing (repro.roofline.cost): priced
        # tasks gain their steering capability tag in the queue name, so
        # compute-bound stages route to accelerator-tier workers and IO-bound
        # stages to the cheap tier. False (default) is byte-identical to the
        # depth-aware-only plane; unpriced tasks are never steered.
        self.cost_aware = cost_aware
        # per-worker compiled-step cache capacity ((arch, shape, mode) ->
        # warm Trainer/Server); 0 disables (cold rebuild per task)
        self.step_cache = step_cache
        # durability (repro.core.durability.LogStore): WAL shards "taskdb" +
        # one per broker service, group-committed per tick (taskdb first).
        # None => byte-identical to the non-durable composer. Public: the
        # chaos harness reaches it to model commit loss at a crash.
        self.durability = durability
        self.wal_snapshot_every = wal_snapshot_every
        self.recovery_stats: Dict[str, int] = {}
        # applied to every worker, static AND dynamically spawned — the hook
        # for registering custom task kinds on autoscaled pods
        self.worker_setup = worker_setup
        self.broker_shards = max(1, broker_shards)
        self.router = BrokerRouter(self.broker_shards)
        self._broker_services = broker_service_names(self.broker_shards)
        # remote workers consult their cluster-local overwatch replica's
        # /queues/ view before pulling (needs plane replica fan-out; workers
        # on clusters without a replica keep the always-pull protocol)
        self.depth_gated_workers = depth_gated_workers
        self.depth_gate_max_lag = depth_gate_max_lag
        self.spec = composer_appspec(plane.master, workers,
                                     self.broker_shards)
        plane.upload_spec(self.spec)

        self._build_master_services()

        self.workers: List[PipelineWorker] = []
        for cluster, names in workers.items():
            for w in names:
                queues = (worker_queues or {}).get(w, ("default",))
                self._make_worker(w, cluster, queues)
        self.depth_publish_every = depth_publish_every
        self._depth_published_at: Optional[float] = None
        self._published_queues: set = set()
        self._spec_dirty = False
        self.autoscaler = None
        self._autoscaler_args: Optional[tuple] = None
        self._dags: Dict[str, DAG] = {}

    def _build_master_services(self) -> None:
        """(Re)build the master-hosted services — broker shards, taskdb,
        scheduler — on their fabric addresses. With durability attached,
        fresh brokers/taskdb recover from their WAL shards in their
        constructors; ``register_handler`` overwrites, so a rebuild (crash
        recovery) answers on the exact addresses surviving workers use."""
        fabric = self.plane.fabric
        master_state = self.plane.master_agent.state
        self.brokers = [Broker(clock_fn=lambda: fabric.clock,
                               durability=self.durability, shard_name=sname,
                               tracer=self.tracer)
                        for sname in self._broker_services]
        self.broker = self.brokers[0]   # single-shard accessor (tests, back-compat)
        self.taskdb = TaskDB(durability=self.durability)
        co = getattr(self.plane, "coordinator", None)
        for i, sname in enumerate(self._broker_services):
            # index closure, not a bound method: a live migration or master
            # failover swaps self.brokers[i] in place and the endpoint (and
            # the coordinator's re-guards) follow for free
            handler = (lambda msg, _i=i: self.brokers[_i].handle(msg))
            ep = ServiceEndpoint(fabric, self.spec, master_state, sname,
                                 handler)
            if co is not None:
                co.register_shard(
                    sname, ep.addr, handler,
                    ops={"freeze": (lambda _i=i: setattr(
                            self.brokers[_i], "frozen", True)),
                         "unfreeze": (lambda _i=i: setattr(
                            self.brokers[_i], "frozen", False)),
                         "export": (lambda _i=i:
                                    self.brokers[_i].snapshot_payload()),
                         "import_": (lambda p, _i=i:
                                     self._install_broker_shard(_i, p)),
                         "rebuild": (lambda _i=i:
                                     self._failover_broker_shard(_i))},
                    wal_shards=(sname,))
                self.brokers[i].on_stale = (
                    lambda _s=sname, _co=co: _co.note_stale(_s))
        ServiceEndpoint(fabric, self.spec, master_state, "taskdb",
                        self.taskdb.handle)
        sched_client = ServiceClient(fabric, master_state, "scheduler-pod")
        self.scheduler = Scheduler(sched_client, clock_fn=lambda: fabric.clock,
                                   batched=self.pipelined,
                                   broker_for=self.router.service_for_queue,
                                   cost_aware=self.cost_aware,
                                   tracer=self.tracer)
        self._register_metrics()

    def _register_metrics(self) -> None:
        """Adopt the pipeline's legacy stats dicts into the master agent's
        metrics registry under stable dotted names. Sources late-bind through
        ``self`` (``self.brokers[i]``, ``self.taskdb``, ``self.autoscaler``),
        so a crash-recovery rebuild needs no re-registration — the next
        snapshot reads the fresh objects."""
        reg = getattr(self.plane.master_agent, "metrics", None)
        if reg is None:
            return
        for i, sname in enumerate(self._broker_services):
            def broker_stats(i=i):
                b = self.brokers[i]
                out = dict(b.stats)
                out.update({f"ops.{k}": v for k, v in b.op_counts.items()})
                return out
            reg.register_source(f"broker.{sname}", broker_stats)
        reg.register_source("taskdb",
                            lambda: dict(self.taskdb.op_counts))
        reg.register_source("autoscale", self._autoscale_metrics)
        if self.tracer is not None:
            reg.register_source("trace",
                                lambda: dict(self.tracer.stats))

    def _autoscale_metrics(self) -> dict:
        a = self.autoscaler
        if a is None:
            return {}
        out = {"events": a.events.total_appended}
        for family, pods in a.pods.items():
            out[f"pods.{family}"] = len(pods)
        return out

    def _make_worker(self, name: str, cluster: str,
                     queues: Tuple[str, ...]) -> PipelineWorker:
        agent = self.plane.agents[cluster]
        fabric = self.plane.fabric
        client = ServiceClient(fabric, agent.state, name)
        worker = PipelineWorker(
            client, name, queues=queues, clock_fn=lambda: fabric.clock,
            batch=self.worker_batch, pipelined=self.pipelined,
            broker_for=self.router.service_for_queue,
            depth_hint=self._depth_hint_for(agent),
            step_cache=self.step_cache, tracer=self.tracer,
            metrics=getattr(agent, "metrics", None))
        reg = getattr(agent, "metrics", None)
        if reg is not None:
            def worker_stats(w=worker):
                out = {"executed": w.executed, "deduped": w.deduped,
                       "skipped_pulls": w.skipped_pulls}
                if w._trainer_cache is not None:
                    out.update({f"step_cache.{k}": v for k, v
                                in w._trainer_cache.stats().items()})
                return out
            reg.register_source(f"worker.{name}", worker_stats)
        if self.worker_setup is not None:
            self.worker_setup(worker)
        self.workers.append(worker)
        return worker

    def _depth_hint_for(self, agent):
        """The worker depth gate: believed ready depth off the hosting
        cluster's watch-materialized ``/queues/`` view (``agent.local_view``)
        — maintained purely from the replica-fed notify plane, never a
        per-call probe. None (always pull) when gating is off, the worker is
        master-local (its pulls never cross the boundary), or the cluster
        hosts no replica. An out-of-bound replica reports "unknown" (pull)
        rather than a confidently wrong zero — the same transparent
        primary-fallback contract as ``range_stale``."""
        if (not self.depth_gated_workers or agent.replica is None
                or agent.cluster == self.plane.master):
            return None
        replica, fabric = agent.replica, self.plane.fabric
        view = agent.local_view("/queues/")
        max_lag = self.depth_gate_max_lag

        def hint(queue: str) -> int:
            if replica.lag(fabric.clock) > max_lag:
                return 1                     # unknown: fall back to pulling
            row = view.get(f"/queues/{queue}")
            return int((row or {}).get("ready", 0))

        return hint

    # ------------------------------------------------------------------- user API
    def add_dag(self, dag: DAG) -> None:
        self._dags[dag.dag_id] = dag
        self.scheduler.add_dag(dag)

    def tick(self) -> None:
        self.scheduler.tick()
        for w in list(self.workers):
            try:
                w.tick()
            except DeliveryError:
                # the worker's cluster is partitioned/dead: its leased tasks
                # redeliver on lease expiry, and the autoscaler (if attached)
                # prunes and replaces the pod on its next pass
                continue
        self.publish_queue_depths()
        if self.autoscaler is not None:
            self.autoscaler.reconcile()
        self._commit_pipeline_wal()
        self.plane.tick()

    def _commit_pipeline_wal(self) -> None:
        """Per-tick group commit of the pipeline WAL shards. Taskdb FIRST:
        a crash between the two commits may leave an ack durable only when
        the rows it covers already are — never an acked task whose terminal
        row was lost (that would be a silently dropped execution). Snapshot +
        truncate whenever a shard's replay tail outgrows
        ``wal_snapshot_every``."""
        dur = self.durability
        if dur is None:
            return
        dur.commit(self.taskdb._shard)
        if (dur.records_since_snapshot(self.taskdb._shard)
                >= self.wal_snapshot_every):
            dur.snapshot(self.taskdb._shard, self.taskdb.snapshot_payload())
        for shard in self.brokers:
            dur.commit(shard._shard)
            if (dur.records_since_snapshot(shard._shard)
                    >= self.wal_snapshot_every):
                dur.snapshot(shard._shard, shard.snapshot_payload())

    # ------------------------------------------------------------- elastic fleet
    def add_worker(self, name: str, cluster: str,
                   queues: Tuple[str, ...] = ("default",),
                   broadcast: bool = True) -> PipelineWorker:
        """Materialize a new worker pod at runtime: extend the AppSpec with
        the pod, re-broadcast the CRD (every agent re-runs Algorithm 5 — the
        new pod gets DNS + ACL access to broker/taskdb), then start the
        local ``PipelineWorker``. ``broadcast=False`` defers the re-broadcast
        (mark dirty, ``flush_spec`` later) so a burst of pod changes costs
        ONE broadcast — safe as long as the flush lands before the new
        worker's first tick, which the autoscaler guarantees by flushing at
        the end of every reconcile pass."""
        pods = tuple(self.spec.pods) + (
            Pod(name, needs=tuple(self._broker_services) + ("taskdb",)),)
        partition = {**self.spec.partition, name: cluster}
        self.spec = AppSpec(services=self.spec.services, pods=pods,
                            partition=partition)
        self._spec_dirty = True
        if broadcast:
            self.flush_spec()
        return self._make_worker(name, cluster, queues)

    def remove_worker(self, worker: PipelineWorker,
                      broadcast: bool = True) -> None:
        """Tear a worker pod out of the app: drop it from the local fleet and
        re-broadcast the shrunk AppSpec so its ACL entries are revoked (a
        removed pod can no longer reach the broker — Algorithm 3 is rebuilt
        default-deny on every re-broadcast). ``broadcast=False`` defers like
        ``add_worker``."""
        # A drained pod's final rows + acks may still sit in the uncommitted
        # WAL tail, and its ``recent_rows`` resync ring leaves the fleet with
        # it: force the group commit NOW, so a crash after removal can never
        # lose work only this (now gone) pod could have re-proven terminal.
        # Pod removals are rare (scale-down / lost-pod events), so the extra
        # commit is noise.
        self._commit_pipeline_wal()
        if worker in self.workers:
            self.workers.remove(worker)
        if worker.pod not in self.spec.partition:
            return
        pods = tuple(p for p in self.spec.pods if p.name != worker.pod)
        partition = {k: v for k, v in self.spec.partition.items()
                     if k != worker.pod}
        self.spec = AppSpec(services=self.spec.services, pods=pods,
                            partition=partition)
        self._spec_dirty = True
        if broadcast:
            self.flush_spec()

    def flush_spec(self) -> None:
        """Re-broadcast the AppSpec if any deferred pod change is pending."""
        if self._spec_dirty:
            self._spec_dirty = False
            self.plane.upload_spec(self.spec)

    def attach_autoscaler(self, policies, **kwargs):
        """Create and wire a ``repro.autoscale.Reconciler`` into the tick
        loop (see that module for the policy/quota/spillover model)."""
        from repro.autoscale.reconciler import Reconciler
        self._autoscaler_args = (policies, dict(kwargs))
        self.autoscaler = Reconciler(self, policies, **kwargs)
        return self.autoscaler

    # ----------------------------------------------------------- crash recovery
    def recover(self) -> Dict[str, int]:
        """Rebuild the master-hosted pipeline after a global-plane crash
        (call AFTER ``plane.recover_global_plane()``). The sequence is the
        recovery barrier the worker docstring's contract assumes:

          1. fresh brokers/taskdb/scheduler replay their WAL shards onto the
             same fabric addresses; DAGs re-register (terminal states come
             back through the scheduler's first probe from cursor 0);
          2. surviving workers drop unexecuted leases (the recovered broker
             requeued them flagged), retry any commit the crash interrupted
             — verbatim, no re-execution — and re-upsert their
             ``recent_rows`` resync rings, making every completed execution's
             terminal row durable even if its original commit died with the
             uncommitted tail;
          3. the autoscaler is rebuilt and adopts the surviving worker-pod
             fleet from overwatch placements (finishing any interrupted
             drains);
          4. ``_reseed_tasks`` re-pushes lost messages / marks broker-held
             ones, then the WAL is committed so recovery itself is durable.

        Workers on partitioned clusters are skipped wherever they are
        unreachable and converge after heal via lease expiry + redelivery."""
        if self.tracer is not None:
            # spans owned by the crashed master's components truncate at the
            # recovery epoch BEFORE the rebuild: WAL replay inside the fresh
            # brokers re-opens queue spans under the same keys, so the order
            # is load-bearing (truncate-after would kill the replayed spans).
            # Task ROOT spans and worker execute/commit spans live on — roots
            # still close at the terminal row, worker commit spans when the
            # retried commit's acks land.
            self.tracer.truncate_open(components=("scheduler", "broker"))
        self._build_master_services()
        for dag in self._dags.values():
            self.scheduler.add_dag(dag)
        stats = {"dropped_leases": 0, "retried_commits": 0,
                 "resynced_rows": 0,
                 "taskdb_replayed": self.taskdb.recovery_replayed,
                 "broker_replayed": sum(
                     b.stats.get("recovery_replayed", 0)
                     for b in self.brokers)}
        for w in list(self.workers):
            # stale backoff windows must not skip the recovery barrier calls
            w.client.reset_backoff()
            stats["dropped_leases"] += w.reset_after_master_restart()
            try:
                if w._pending_commit is not None:
                    w.retry_pending()
                    stats["retried_commits"] += 1
                rows = list(w.recent_rows)
                if rows:
                    w.client.call("taskdb", {"op": "upsert_many",
                                             "rows": rows})
                    stats["resynced_rows"] += len(rows)
            except DeliveryError:
                continue   # partitioned: converges after heal via redelivery
        if self._autoscaler_args is not None:
            from repro.autoscale.reconciler import Reconciler
            policies, kwargs = self._autoscaler_args
            self.autoscaler = Reconciler(self, policies, **kwargs)
            stats["adopted_pods"] = self.autoscaler.adopt(self.workers)
        stats.update(self._reseed_tasks())
        # recovered /queues/ state may predate the last published depths:
        # resync the tombstone set to the store and force a full republish
        ow_queues = self.plane.overwatch.handle(
            {"op": "range", "prefix": "/queues/"})["items"]
        self._published_queues = {k[len("/queues/"):] for k in ow_queues}
        self._depth_published_at = None
        self._commit_pipeline_wal()
        self.recovery_stats = stats
        return stats

    def _reseed_tasks(self) -> Dict[str, int]:
        """Close the scheduler-vs-broker gap the crash tore open. After WAL
        replay the taskdb and brokers are each internally consistent but may
        disagree: a task row can say queued/running while its broker message
        died in the uncommitted tail (re-push it, flagged redelivered — the
        worker-side dedup probe makes that safe even if it actually ran), and
        the broker can hold a message whose queued row was lost (mark it
        running via ``note_inflight`` so the frontier never stages a
        duplicate)."""
        held: set = set()
        for shard in self.brokers:
            held |= shard.held_task_keys()
        held_tasks = {(d, t) for d, t, _ in held}
        self.scheduler._probe()
        pushes: Dict[str, List[dict]] = {}
        reseeded = noted = 0
        for did, dag in sorted(self._dags.items()):
            state = self.scheduler._state.get(did, {})
            for name, task in sorted(dag.tasks.items()):
                row = state.get(name)
                status = (row or {}).get("status")
                if status in ("queued", "running"):
                    if (did, name, row["try"]) not in held:
                        m = Scheduler.build_message(did, task, row["try"])
                        if self.tracer is not None:
                            # re-attach to the surviving root span, if traced
                            ctx = self.tracer.ctx_for(("task", did, name))
                            if ctx is not None:
                                m["trace"] = ctx
                        pushes.setdefault(
                            queue_for(task, self.cost_aware), []).append(m)
                        reseeded += 1
                elif row is None and (did, name) in held_tasks:
                    self.scheduler.note_inflight(did, name)
                    noted += 1
        for q in sorted(pushes):
            # through the scheduler's bounded-retry push path: a target shard
            # that is itself frozen / failing over stashes the batch for next
            # tick instead of losing it (double-failover scenarios)
            self.scheduler._push(q, pushes[q], redelivered=True)
        return {"reseeded": reseeded, "noted_inflight": noted}

    # ------------------------------------------------------- shard migration
    def _install_broker_shard(self, i: int, payload: dict) -> None:
        """Live-migration import (coordinator ``import_`` op): a fresh
        ``Broker`` under the target master installs the transferred payload
        directly — no WAL replay, the payload IS the committed state (the
        coordinator snapshotted it at transfer). Counters start fresh: the
        target is a different process in the model."""
        fabric = self.plane.fabric
        sname = self._broker_services[i]
        old = self.brokers[i]
        fresh = Broker(clock_fn=lambda: fabric.clock,
                       durability=self.durability, shard_name=sname,
                       tracer=self.tracer, recover=False)
        fresh.install_payload(payload)
        fresh.on_stale = old.on_stale
        self.brokers[i] = fresh
        if i == 0:
            self.broker = fresh

    def _failover_broker_shard(self, i: int) -> None:
        """Failover rebuild (coordinator ``rebuild`` op): the owning master
        died with this shard's uncommitted WAL tail. A fresh ``Broker``
        replays the committed snapshot + records in its constructor —
        requeueing recovered in-flight flagged and bumping the tag epoch so
        the dead owner's outstanding leases can never ack — then
        ``_reseed_tasks`` closes the taskdb-vs-broker gap for messages that
        died in the lost tail."""
        fabric = self.plane.fabric
        sname = self._broker_services[i]
        old = self.brokers[i]
        fresh = Broker(clock_fn=lambda: fabric.clock,
                       durability=self.durability, shard_name=sname,
                       tracer=self.tracer)
        fresh.on_stale = old.on_stale
        self.brokers[i] = fresh
        if i == 0:
            self.broker = fresh
        # the rebuilt shard answers immediately (its frozen flag is fresh);
        # re-dirty its depth view so the next sweep republishes every queue
        self._depth_published_at = None
        stats = self._reseed_tasks()
        for k, v in stats.items():
            self.recovery_stats[f"failover_{k}"] = (
                self.recovery_stats.get(f"failover_{k}", 0) + v)

    # ------------------------------------------------------------ depth telemetry
    def publish_queue_depths(self) -> None:
        """Sweep-cadence depth publication: at most once per
        ``depth_publish_every`` fabric-clock units, put the (ready, inflight)
        counts of every queue whose depth changed under ``/queues/<name>`` —
        a handful of coalesce-friendly puts, not one per queue per tick.

        A queue that drained to zero (no ready, no inflight) is tombstoned:
        its key is DELETED so the dispatcher's ``_queue_depth`` view drops
        the entry instead of carrying a stale last-depth forever. A queue
        that appears and fully drains within one cadence window is never
        published at all."""
        now = self.plane.fabric.clock
        if (self._depth_published_at is not None
                and now - self._depth_published_at < self.depth_publish_every):
            return
        self._depth_published_at = now
        ow = self.plane.master_agent.ow
        for i, shard in enumerate(self.brokers):
            # each shard reports only the families it owns (belt-and-braces:
            # the router already steers every op to its owner), so a family
            # is published exactly once however many shards exist
            owned = (None if self.broker_shards == 1
                     else (lambda q, _i=i:
                           self.router.shard_for_queue(q) == _i))
            for queue, depth in shard.changed_depths(families=owned).items():
                try:
                    if not depth["ready"] and not depth["inflight"]:
                        if queue in self._published_queues:
                            ow.delete(f"/queues/{queue}")
                            self._published_queues.discard(queue)
                        continue
                    ow.put(f"/queues/{queue}", {**depth, "clock": now})
                    self._published_queues.add(queue)
                except (DeliveryError, StaleEpochError):
                    # the owning overwatch shard is frozen / failing over:
                    # re-dirty so the next sweep republishes this queue
                    shard._published.pop(queue, None)
                    shard._depth_dirty.add(queue)

    def run_dag(self, dag_id: str, max_ticks: int = 500) -> bool:
        for _ in range(max_ticks):
            self.tick()
            # probe-free doneness: the next tick's shared probe folds in any
            # commits this tick's workers made, so the check lags by at most
            # one tick instead of paying a second delta RPC every tick
            if self.scheduler.dag_done(dag_id, probe=False):
                return self.scheduler.dag_success(dag_id, probe=False)
        # budget exhausted: one probed check so a DAG finishing on the very
        # last tick isn't misreported by the one-tick observation lag
        if self.scheduler.dag_done(dag_id):
            return self.scheduler.dag_success(dag_id, probe=False)
        return False

    def status(self, dag_id: str) -> Dict[str, str]:
        return self.scheduler.dag_status(dag_id)
