"""DAG scheduler (the Airflow scheduler, paper §5).

Runs as a pod on the master partition: every tick it reads task states from the
taskdb, computes the ready frontier of each registered DAG, and places ready
task instances onto the broker — one queue per ``requires`` capability set, so
compliance-constrained tasks (e.g. "onprem-only ETL") are only visible to
workers inside the right partition. Failed tasks are retried up to
``Task.retries`` times; tasks downstream of a permanently failed task are
marked upstream_failed.

Hot path (the data-plane throughput overhaul): the scheduler is fully
delta-driven.

  * One ``dag_delta_many`` probe per tick covers every registered DAG (a
    quiescent DAG costs nothing beyond its slice of that probe).
  * The per-DAG done/running/failed sets are maintained INCREMENTALLY from
    those deltas — never rebuilt from the full cached state — and the ready
    frontier comes from an indegree counter per task (``_undone_up``): when a
    task succeeds, each direct downstream's counter drops, and a counter
    hitting zero promotes the task into the candidate set. Scheduling work is
    O(changed tasks) per tick, not O(DAG size).
  * Placement is coalesced: each tick flushes ONE taskdb ``upsert_many``
    carrying every queued/retry/upstream_failed row plus ONE broker
    ``push_many`` per target queue — 2 RPCs per tick per active DAG instead
    of 2 per task (``batched=False`` keeps the per-task protocol for
    equivalence tests and the benchmark baseline).
  * ``dag_status``/``dag_done``/``dag_success`` read the cached ``_state``,
    refreshed by the same delta probe — no full ``dag_state`` dump per call.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Set, Tuple

from repro.core.transport import DeliveryError
from repro.pipelines.dag import DAG, Task
from repro.pipelines.services import ServiceClient

TERMINAL = ("success", "failed", "upstream_failed")


def queue_for(task: Task, cost_aware: bool = False) -> str:
    """Queue name = sorted capability set. With ``cost_aware`` the task's
    roofline steering tag (``repro.roofline.cost``) joins the set, so the
    existing capability-set routing — broker queues, dispatcher depth-aware
    placement, autoscaler families — steers by cost class with no new wire
    protocol. An unpriced task (no cost signal) routes exactly as before."""
    tags = set(task.requires)
    if cost_aware:
        from repro.roofline.cost import steering_tag   # lazy: off-path import
        tag = steering_tag(task)
        if tag:
            tags.add(tag)
    return ",".join(sorted(tags)) or "default"


class Scheduler:
    # a broker push that bounces (shard frozen / migrating) or dies
    # (unreachable master) is retried once per tick up to this bound, then
    # its tasks are marked failed — surfaced, never hung
    PUSH_MAX_ATTEMPTS = 8

    def __init__(self, client: ServiceClient, clock_fn=None,
                 batched: bool = True, broker_for=None,
                 cost_aware: bool = False, tracer=None):
        self.client = client
        self.stats: Counter = Counter()
        # (queue, redelivered) -> msgs awaiting re-push / attempt count
        self._push_retry: Dict[Tuple[str, bool], List[dict]] = {}
        self._push_attempts: Dict[Tuple[str, bool], int] = {}
        self.dags: Dict[str, DAG] = {}
        self.clock_fn = clock_fn or (lambda: 0.0)
        self.batched = batched
        # flight recorder: when set (and the task samples), _stage opens the
        # task's ROOT span plus a "schedule" child and rides the context on
        # the broker message; the root closes when _apply_rows observes the
        # terminal taskdb row — the scheduler is the one component that sees
        # both birth and death of every task instance
        self.tracer = tracer
        self._staged_spans: List = []
        # partial sampling: every round(1/sample)-th staged task traces
        self._stage_n = 0
        self._stride = (max(1, round(1.0 / tracer.sample))
                        if tracer is not None and 0.0 < tracer.sample < 1.0
                        else 0)
        # roofline-cost-aware queue routing; False is byte-identical to the
        # depth-aware-only plane (asserted by test_workloads equivalence)
        self.cost_aware = cost_aware
        # queue -> broker service name (per-family sharding); the default is
        # the single unsharded "broker" service
        self.broker_for = broker_for or (lambda queue: "broker")
        self._state: Dict[str, Dict[str, dict]] = {}   # cached latest rows
        self._cursor: Dict[str, int] = {}
        self._quiescent: Set[str] = set()
        # ---------------- incrementally maintained per-DAG scheduling state
        self._done: Dict[str, Set[str]] = {}
        self._running: Dict[str, Set[str]] = {}        # queued or running
        self._failed: Dict[str, Set[str]] = {}         # permanent (incl. upstream)
        self._retry_pending: Dict[str, Dict[str, int]] = {}  # task -> next try
        self._fail_new: Dict[str, Set[str]] = {}       # to propagate downstream
        self._undone_up: Dict[str, Dict[str, int]] = {}  # not-yet-done upstreams
        self._candidates: Dict[str, Set[str]] = {}     # all upstreams done

    def add_dag(self, dag: DAG) -> None:
        did = dag.dag_id
        self.dags[did] = dag
        self._state.setdefault(did, {})
        self._cursor.setdefault(did, 0)
        self._quiescent.discard(did)
        self._done.setdefault(did, set())
        self._running.setdefault(did, set())
        self._failed.setdefault(did, set())
        self._retry_pending.setdefault(did, {})
        self._fail_new.setdefault(did, set())
        undone = {n: len(t.upstream) for n, t in dag.tasks.items()}
        self._undone_up.setdefault(did, undone)
        self._candidates.setdefault(
            did, {n for n, d in undone.items() if d == 0})

    # ------------------------------------------------------------ delta intake
    def _apply_rows(self, dag: DAG, changed: Dict[str, dict]) -> None:
        """Fold a taskdb delta into the incremental scheduling sets.

        Pure state tracking — no RPCs. Scheduling side effects (enqueueing
        retries, propagating failures) are staged in ``_retry_pending`` /
        ``_fail_new`` and drained by ``_schedule_dag`` so that an observation
        probe (``dag_status``) can consume deltas without scheduling.
        """
        did = dag.dag_id
        self._state[did].update(changed)
        done = self._done[did]
        running = self._running[did]
        failed = self._failed[did]
        candidates = self._candidates[did]
        undone = self._undone_up[did]
        retry = self._retry_pending[did]
        tr = self.tracer
        # root spans close at terminal rows — collected here, closed in two
        # batch calls (one clock read) after the fold
        closed_ok: List[tuple] = []
        closed_failed: List[tuple] = []
        for t, r in changed.items():
            if t not in dag.tasks:
                continue
            s = r.get("status")
            if s == "success":
                if t in done:
                    continue
                done.add(t)
                running.discard(t)
                candidates.discard(t)
                retry.pop(t, None)
                # a retry can outrace a same-tick upstream_failed mark; the
                # success row wins (it is the higher try), so the sets agree
                failed.discard(t)
                if tr is not None:
                    closed_ok.append(("task", did, t))
                for d in dag.children[t]:
                    undone[d] -= 1
                    if undone[d] == 0 and d not in done and d not in failed:
                        candidates.add(d)
            elif s in ("queued", "running"):
                if t not in done and t not in failed:
                    running.add(t)
                    candidates.discard(t)
            elif s == "failed":
                running.discard(t)
                if t in done or t in failed:
                    continue
                if r["try"] < dag.tasks[t].retries + 1:
                    retry[t] = r["try"] + 1
                else:
                    failed.add(t)
                    candidates.discard(t)
                    retry.pop(t, None)
                    self._fail_new[did].add(t)
                    if tr is not None:
                        closed_failed.append(("task", did, t))
            elif s == "upstream_failed":
                running.discard(t)
                candidates.discard(t)
                retry.pop(t, None)
                if t not in done:
                    failed.add(t)
                    if tr is not None:
                        closed_failed.append(("task", did, t))
        if closed_ok or closed_failed:
            tnow = tr.clock()
            if closed_ok:
                tr.close_keyed_many(closed_ok, tnow)
            if closed_failed:
                tr.close_keyed_many(closed_failed, tnow, status="failed")

    def _probe(self) -> Dict[str, Dict[str, dict]]:
        """One multiplexed delta round-trip for every registered DAG."""
        resp = self.client.call("taskdb", {
            "op": "dag_delta_many",
            "dags": {d: self._cursor.get(d, 0) for d in self.dags}})
        deltas = resp["deltas"]
        cursor = resp["cursor"]
        for dag in self.dags.values():
            self._cursor[dag.dag_id] = cursor
            changed = deltas.get(dag.dag_id, {})
            if changed:
                self._apply_rows(dag, changed)
                # state moved: the next tick must re-examine this DAG even
                # though its delta was consumed here (observation probes and
                # scheduling ticks share one cursor)
                self._quiescent.discard(dag.dag_id)
        return deltas

    # -------------------------------------------------------------------- one tick
    def tick(self) -> List[str]:
        scheduled: List[str] = []
        if self._push_retry:
            self._drain_push_retry()
        if not self.dags:
            return scheduled
        deltas = self._probe()
        for dag in self.dags.values():
            did = dag.dag_id
            if (did in self._quiescent and not deltas.get(did)
                    and not self._retry_pending[did]
                    and not self._fail_new[did]):
                continue                  # nothing moved, frontier unchanged
            n_before = len(scheduled)
            self._schedule_dag(dag, scheduled)
            if len(scheduled) == n_before:
                self._quiescent.add(did)
            else:
                self._quiescent.discard(did)
        return scheduled

    def _schedule_dag(self, dag: DAG, scheduled: List[str]) -> None:
        did = dag.dag_id
        clock = self.clock_fn()
        rows: List[dict] = []
        pushes: Dict[str, List[dict]] = {}
        done, running = self._done[did], self._running[did]
        failed, candidates = self._failed[did], self._candidates[did]
        # retries first, so a retrying task is marked running before the
        # frontier below could mistake it for never-scheduled
        retries, self._retry_pending[did] = self._retry_pending[did], {}
        for t in sorted(retries):
            self._stage(did, dag.tasks[t], retries[t], clock, rows, pushes)
            running.add(t)
            scheduled.append(f"{did}.{t}#retry{retries[t]}")
        # propagate permanent failure downstream (transitively, so only the
        # originally failed task needs walking)
        fail_new, self._fail_new[did] = self._fail_new[did], set()
        for t in sorted(fail_new):
            for d in sorted(dag.downstream_of(t)):
                if d in done or d in failed:
                    continue
                # d can never hold a pending retry here: a task downstream of
                # a newly permanently-failed task was never schedulable, and
                # _apply_rows refuses retries for tasks already in ``failed``
                failed.add(d)
                candidates.discard(d)
                rows.append({"dag": did, "task": d, "try": 1,
                             "status": "upstream_failed", "clock": clock})
        # ready frontier: candidates are maintained by the indegree counters;
        # running/failed membership is already kept out of the set, the
        # difference below only guards same-tick transitions
        for t in sorted(candidates - running - failed - done):
            self._stage(did, dag.tasks[t], 1, clock, rows, pushes)
            running.add(t)
            candidates.discard(t)
            scheduled.append(f"{did}.{t}")
        self._flush(rows, pushes)

    def _stage(self, did: str, task: Task, try_n: int, clock: float,
               rows: List[dict], pushes: Dict[str, List[dict]]) -> None:
        rows.append({"dag": did, "task": task.name, "try": try_n,
                     "status": "queued", "clock": clock})
        msg = self.build_message(did, task, try_n)
        tr = self.tracer
        if tr is not None:
            s = tr.sample
            if s >= 1.0:
                tid = f"{did}/{task.name}"
            elif s <= 0.0:
                tid = None
            else:
                # deterministic stride sampling: the sim stages tasks in a
                # deterministic order, so the same workload traces the same
                # tasks on every run — and the unsampled hot path pays one
                # int op instead of an f-string + checksum per task
                n = self._stage_n = self._stage_n + 1
                tid = f"{did}/{task.name}" if n % self._stride == 0 else None
            if tid:
                # keyed root: a retry re-stage reuses the surviving root span
                ctx = tr.open_keyed(("task", did, task.name), "task", "task",
                                    trace_id=tid, t0=clock)
                self._staged_spans.append((ctx, clock))
                msg["trace"] = ctx      # downstream spans parent under root
        pushes.setdefault(queue_for(task, self.cost_aware), []).append(msg)

    @staticmethod
    def build_message(did: str, task: Task, try_n: int) -> dict:
        """The broker message for a task instance — also what crash recovery
        re-pushes, so a reseeded message is byte-identical to a staged one."""
        return {"dag": did, "task": task.name, "kind": task.kind,
                "payload": task.payload, "try": try_n}

    def note_inflight(self, dag_id: str, task: str) -> None:
        """Crash recovery: the broker still holds a message for this task but
        its taskdb row was lost with the uncommitted tail. Mark it running so
        the frontier does not stage a duplicate; the broker's (flagged) copy
        carries the execution, and its committed rows restore the real state."""
        if dag_id not in self.dags or task not in self.dags[dag_id].tasks:
            return
        if task in self._done[dag_id] or task in self._failed[dag_id]:
            return
        self._running[dag_id].add(task)
        self._candidates[dag_id].discard(task)
        self._quiescent.discard(dag_id)

    def _flush(self, rows: List[dict],
               pushes: Dict[str, List[dict]]) -> None:
        """Commit the tick's staged work: rows before pushes, so no worker can
        pull a task instance whose queued row is not yet visible."""
        if self.batched:
            if rows:
                self.client.call("taskdb", {"op": "upsert_many", "rows": rows})
            for queue in sorted(pushes):
                self._push(queue, pushes[queue])
        else:
            for row in rows:
                self.client.call("taskdb", {"op": "upsert", **row})
            for queue in sorted(pushes):
                for m in pushes[queue]:
                    self.client.call(self.broker_for(queue),
                                     {"op": "push", "queue": queue, "msg": m})
        # schedule spans are recorded once the placement RPCs land; a crash
        # mid-flush drops the staged tuples with the dead scheduler — the
        # aborted attempt never hits the tracer, and the post-recovery
        # re-stage records the one schedule span that actually committed
        if self._staged_spans:
            tr = self.tracer
            t1 = tr.clock()              # one read for the whole batch
            rec = tr.rec                 # raw event appends, one bound check
            for ctx, t0 in self._staged_spans:
                rec((None, ctx, "schedule", "scheduler", t0, t1, "ok", None))
            tr.bound()
            self._staged_spans = []

    def _push(self, queue: str, msgs: List[dict],
              redelivered: bool = False) -> None:
        """Push a batch to its owning broker shard, surviving epoch fences
        and dead masters. The sim is synchronous, so a bounce means the batch
        was NOT applied (responses cannot be lost): stash it and re-push at
        the next tick — the migration freeze window and the failover repair
        both span a bounded number of ticks. Past ``PUSH_MAX_ATTEMPTS`` the
        batch's tasks are marked failed (their retry budget decides what
        happens next); the scheduler never hangs and never silently drops.

        The taskdb row for each message is already durable (rows flush before
        pushes), so a stashed batch that dies with a scheduler crash is
        re-seeded by recovery — the stash is an optimization, not the source
        of truth."""
        try:
            req = {"op": "push_many", "queue": queue, "msgs": msgs}
            if redelivered:
                req["redelivered"] = True
            resp = self.client.call(self.broker_for(queue), req)
        except DeliveryError:
            resp = None
        key = (queue, redelivered)
        if resp is not None and resp.get("ok", True):
            self._push_attempts.pop(key, None)
            return
        attempts = self._push_attempts.get(key, 0) + 1
        self._push_attempts[key] = attempts
        if attempts <= self.PUSH_MAX_ATTEMPTS:
            self._push_retry.setdefault(key, []).extend(msgs)
            self.stats["push_retries"] += 1
            return
        # bound exhausted: surface as task failures, never a hang
        clock = self.clock_fn()
        rows = [{"dag": m["dag"], "task": m["task"], "try": m["try"],
                 "status": "failed", "clock": clock} for m in msgs]
        try:
            self.client.call("taskdb", {"op": "upsert_many", "rows": rows})
            self._push_attempts.pop(key, None)
            self.stats["push_gave_up"] += len(msgs)
        except DeliveryError:
            # even the failure report could not land: keep the batch, the
            # attempt counter stays saturated so the report retries next tick
            self._push_retry.setdefault(key, []).extend(msgs)

    def _drain_push_retry(self) -> None:
        """Re-push every stashed batch (tick start, before new staging so a
        retried batch keeps its place ahead of this tick's frontier)."""
        for key in sorted(self._push_retry):
            msgs = self._push_retry.pop(key)
            self._push(key[0], msgs, redelivered=key[1])

    # ------------------------------------------------------------------ observation
    def dag_status(self, dag_id: str) -> Dict[str, str]:
        """Cached-state read: one shared delta probe, never a ``dag_state``
        round-trip — the cache is exactly as fresh as the probe's cursor."""
        self._probe()
        state = self._state.get(dag_id, {})
        return {t: state.get(t, {}).get("status", "pending")
                for t in self.dags[dag_id].tasks}

    def dag_done(self, dag_id: str, probe: bool = True) -> bool:
        """O(1) after the probe: the incremental done/failed sets partition
        the terminal tasks (``failed`` includes upstream_failed).

        ``probe=False`` skips the delta round-trip and answers from the sets
        as of the last probe — right for a driver loop that just ticked
        (doneness then lags commits by at most one tick, and terminal states
        never regress), wrong for a caller needing read-your-writes."""
        if probe:
            self._probe()
        dag = self.dags[dag_id]
        return (len(self._done[dag_id]) + len(self._failed[dag_id])
                == len(dag.tasks))

    def dag_success(self, dag_id: str, probe: bool = True) -> bool:
        if probe:
            self._probe()
        return len(self._done[dag_id]) == len(self.dags[dag_id].tasks)
