"""DAG scheduler (the Airflow scheduler, paper §5).

Runs as a pod on the master partition: every tick it reads task states from the
taskdb, computes the ready frontier of each registered DAG, and places ready
task instances onto the broker — one queue per ``requires`` capability set, so
compliance-constrained tasks (e.g. "onprem-only ETL") are only visible to
workers inside the right partition. Failed tasks are retried up to
``Task.retries`` times; tasks downstream of a permanently failed task are
marked upstream_failed.

Hot path (the scaling overhaul): instead of pulling the full ``dag_state`` for
every DAG on every tick, the scheduler keeps a cached per-DAG state and asks
the taskdb only for the *deltas* since its cursors — multiplexed over ALL
registered DAGs in one ``dag_delta_many`` round-trip per tick. A DAG whose
tasks did not change and which scheduled nothing last pass is quiescent and
costs nothing beyond its slice of that single probe — event-driven scheduling
rather than polling.
"""
from __future__ import annotations

from typing import Dict, List, Set

from repro.pipelines.dag import DAG, Task
from repro.pipelines.services import ServiceClient

TERMINAL = ("success", "failed", "upstream_failed")


def queue_for(task: Task) -> str:
    return ",".join(sorted(task.requires)) or "default"


class Scheduler:
    def __init__(self, client: ServiceClient, clock_fn=None):
        self.client = client
        self.dags: Dict[str, DAG] = {}
        self.clock_fn = clock_fn or (lambda: 0.0)
        self._state: Dict[str, Dict[str, dict]] = {}   # cached latest rows
        self._cursor: Dict[str, int] = {}
        self._quiescent: Set[str] = set()

    def add_dag(self, dag: DAG) -> None:
        self.dags[dag.dag_id] = dag
        self._state.setdefault(dag.dag_id, {})
        self._cursor.setdefault(dag.dag_id, 0)
        self._quiescent.discard(dag.dag_id)

    # -------------------------------------------------------------------- one tick
    def tick(self) -> List[str]:
        scheduled: List[str] = []
        if not self.dags:
            return scheduled
        # one multiplexed delta probe for every registered DAG
        resp = self.client.call("taskdb", {
            "op": "dag_delta_many",
            "dags": {d: self._cursor.get(d, 0) for d in self.dags}})
        deltas = resp["deltas"]
        cursor = resp["cursor"]
        for dag in self.dags.values():
            changed = deltas.get(dag.dag_id, {})
            self._cursor[dag.dag_id] = cursor
            state = self._state.setdefault(dag.dag_id, {})
            state.update(changed)
            if not changed and dag.dag_id in self._quiescent:
                continue                      # nothing moved, frontier unchanged
            n_before = len(scheduled)
            self._schedule_dag(dag, state, scheduled)
            if len(scheduled) == n_before:
                self._quiescent.add(dag.dag_id)
            else:
                self._quiescent.discard(dag.dag_id)
        return scheduled

    def _schedule_dag(self, dag: DAG, state: Dict[str, dict],
                      scheduled: List[str]) -> None:
        done = {t for t, r in state.items() if r.get("status") == "success"}
        running = {t for t, r in state.items()
                   if r.get("status") in ("queued", "running")}
        failed = set()
        for t, r in state.items():
            if r.get("status") == "failed":
                task = dag.tasks[t]
                if r["try"] < task.retries + 1:
                    self._enqueue(dag, task, r["try"] + 1)
                    running.add(t)
                    scheduled.append(f"{dag.dag_id}.{t}#retry{r['try']+1}")
                else:
                    failed.add(t)
            elif r.get("status") == "upstream_failed":
                failed.add(t)
        # propagate permanent failure downstream
        for t in sorted(failed):
            for d in dag.downstream_of(t):
                if d not in done and d not in failed:
                    self.client.call("taskdb", {
                        "op": "upsert", "dag": dag.dag_id, "task": d,
                        "try": 1, "status": "upstream_failed",
                        "clock": self.clock_fn()})
                    failed.add(d)
        for task in dag.ready_tasks(done, running, failed):
            self._enqueue(dag, task, 1)
            scheduled.append(f"{dag.dag_id}.{task.name}")

    def _enqueue(self, dag: DAG, task: Task, try_n: int) -> None:
        self.client.call("taskdb", {"op": "upsert", "dag": dag.dag_id,
                                    "task": task.name, "try": try_n,
                                    "status": "queued",
                                    "clock": self.clock_fn()})
        self.client.call("broker", {"op": "push", "queue": queue_for(task),
                                    "msg": {"dag": dag.dag_id,
                                            "task": task.name,
                                            "kind": task.kind,
                                            "payload": task.payload,
                                            "try": try_n}})

    # ------------------------------------------------------------------ observation
    def dag_status(self, dag_id: str) -> Dict[str, str]:
        state = self.client.call("taskdb", {"op": "dag_state",
                                            "dag": dag_id})["tasks"]
        dag = self.dags[dag_id]
        return {t: state.get(t, {}).get("status", "pending")
                for t in dag.tasks}

    def dag_done(self, dag_id: str) -> bool:
        return all(s in TERMINAL for s in self.dag_status(dag_id).values())

    def dag_success(self, dag_id: str) -> bool:
        return all(s == "success" for s in self.dag_status(dag_id).values())
