"""Service plumbing for pipeline components over the hybrid platform.

``ServiceEndpoint`` registers a handler at the service's real address on its
host cluster (where Algorithm 2 forwards ingress traffic); ``ServiceClient``
is how a *pod* (worker/scheduler) dials a service BY NAME: it resolves the
local DNS entry (Algorithm 1) and sends on the fabric — the route tables,
channels and ACLs (Algorithms 2-4) do the rest. Pods never know where a
service actually lives; that is the paper's seamless-partitioning claim.

Requests ride ``Envelope`` payloads: a batched request (``push_many`` /
``upsert_many`` carrying a whole frontier or commit batch) crosses several
fabric hops between a private worker and the master-hosted services, and the
envelope caches its byte size so the ledger walks the batch once, not once
per hop.

Clients work for ELASTIC pods too: a worker pod added at runtime (composer
``add_worker`` / autoscaler spawn) dials services the moment the AppSpec
re-broadcast lands — DNS and ACLs are per-spec state rebuilt by Algorithm 5,
not per-process state — and a pod removed from the spec is denied again at
the next call (default-deny ACL rebuild), which is what the drained-worker
tests assert.

Retry discipline (the multi-master robustness pass): the simulation is
synchronous, so retrying *within* a call is useless — the same instant gives
the same answer. Instead the client keeps a per-service **backoff window**
across calls: after a ``DeliveryError`` the service is marked down until a
deterministic (pod-seeded, sim-clock) exponential-backoff deadline, and calls
inside the window fail fast (``stats["fast_fails"]``) without touching the
fabric. Each real attempt past the first counts in ``stats["retries"]``; a
streak reaching ``MAX_ATTEMPTS`` counts one ``stats["gave_up"]`` (the
caller's cue to surface a task failure rather than spin), then the cycle
restarts at the capped delay. A success clears the window
(``stats["recovered"]``). ``reset_backoff()`` drops every window — recovery
barriers call it so a post-restart resync is never skipped by a stale
window.
"""
from __future__ import annotations

import random
import zlib
from collections import Counter
from typing import Callable, Dict, Tuple

from repro.core import gateways as GW
from repro.core.service_graph import AppSpec
from repro.core.transport import DeliveryError, Envelope, Fabric


class ServiceEndpoint:
    def __init__(self, fabric: Fabric, spec: AppSpec, state: GW.GatewayState,
                 name: str, handler: Callable[[dict], dict]):
        svc = spec.service(name)
        if spec.host_cluster(name) != state.cluster:
            raise ValueError(f"{name} is not hosted on {state.cluster}")
        rank = GW.service_rank(spec, name)
        self.addr = (state.service_ip(rank), svc.port)
        fabric.register_handler(state.cluster, self.addr, handler)


class ServiceClient:
    MAX_ATTEMPTS = 5
    BACKOFF_BASE = 1.0                       # sim-seconds, ~one tick
    BACKOFF_CAP = 8.0

    def __init__(self, fabric: Fabric, state: GW.GatewayState, pod: str):
        self.fabric = fabric
        self.state = state
        self.pod = pod
        self.stats: Counter = Counter()
        # service -> (retry_at, consecutive-failure streak)
        self._down: Dict[str, Tuple[float, int]] = {}
        self._rng = random.Random(zlib.crc32(pod.encode()))

    def reset_backoff(self) -> None:
        self._down.clear()

    def call(self, service: str, msg: dict) -> dict:
        if service not in self.state.dns:
            raise DeliveryError(f"no DNS entry for {service} in "
                                f"{self.state.cluster}")
        down = self._down.get(service)
        now = self.fabric.clock
        if down is not None and now < down[0]:
            self.stats["fast_fails"] += 1
            raise DeliveryError(
                f"{service} backing off until t={down[0]:.2f} "
                f"(streak {down[1]})")
        addr = self.state.dns[service]
        if not isinstance(msg, Envelope):
            msg = Envelope(msg)              # size once, reuse across hops
        try:
            resp = self.fabric.send(self.state.cluster, self.pod,
                                    self.state.cluster, addr, msg)
        except DeliveryError:
            streak = (down[1] if down is not None else 0) + 1
            if streak > 1:
                self.stats["retries"] += 1
            if streak >= self.MAX_ATTEMPTS:
                self.stats["gave_up"] += 1
                streak = 0                   # restart the cycle at cap delay
                delay = self.BACKOFF_CAP
            else:
                delay = min(self.BACKOFF_BASE * (2 ** (streak - 1)),
                            self.BACKOFF_CAP)
            delay *= 0.5 + 0.5 * self._rng.random()
            self._down[service] = (now + delay, streak)
            raise
        if down is not None:
            del self._down[service]
            self.stats["recovered"] += 1
        return resp
