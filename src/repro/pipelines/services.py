"""Service plumbing for pipeline components over the hybrid platform.

``ServiceEndpoint`` registers a handler at the service's real address on its
host cluster (where Algorithm 2 forwards ingress traffic); ``ServiceClient``
is how a *pod* (worker/scheduler) dials a service BY NAME: it resolves the
local DNS entry (Algorithm 1) and sends on the fabric — the route tables,
channels and ACLs (Algorithms 2-4) do the rest. Pods never know where a
service actually lives; that is the paper's seamless-partitioning claim.

Requests ride ``Envelope`` payloads: a batched request (``push_many`` /
``upsert_many`` carrying a whole frontier or commit batch) crosses several
fabric hops between a private worker and the master-hosted services, and the
envelope caches its byte size so the ledger walks the batch once, not once
per hop.

Clients work for ELASTIC pods too: a worker pod added at runtime (composer
``add_worker`` / autoscaler spawn) dials services the moment the AppSpec
re-broadcast lands — DNS and ACLs are per-spec state rebuilt by Algorithm 5,
not per-process state — and a pod removed from the spec is denied again at
the next call (default-deny ACL rebuild), which is what the drained-worker
tests assert.
"""
from __future__ import annotations

from typing import Callable

from repro.core import gateways as GW
from repro.core.service_graph import AppSpec
from repro.core.transport import DeliveryError, Envelope, Fabric


class ServiceEndpoint:
    def __init__(self, fabric: Fabric, spec: AppSpec, state: GW.GatewayState,
                 name: str, handler: Callable[[dict], dict]):
        svc = spec.service(name)
        if spec.host_cluster(name) != state.cluster:
            raise ValueError(f"{name} is not hosted on {state.cluster}")
        rank = GW.service_rank(spec, name)
        self.addr = (state.service_ip(rank), svc.port)
        fabric.register_handler(state.cluster, self.addr, handler)


class ServiceClient:
    def __init__(self, fabric: Fabric, state: GW.GatewayState, pod: str):
        self.fabric = fabric
        self.state = state
        self.pod = pod

    def call(self, service: str, msg: dict) -> dict:
        if service not in self.state.dns:
            raise DeliveryError(f"no DNS entry for {service} in "
                                f"{self.state.cluster}")
        addr = self.state.dns[service]
        if not isinstance(msg, Envelope):
            msg = Envelope(msg)              # size once, reuse across hops
        return self.fabric.send(self.state.cluster, self.pod,
                                self.state.cluster, addr, msg)
