"""Hybrid workflow orchestration (paper §5: Cloud Composer / Apache Airflow).

Scheduler + broker + task DB live on the master partition; workers live on any
partition and reach them exclusively through the hybrid platform's gateway
routes — the exact pod-service dependency graph of Figure 3.
"""
from repro.pipelines.dag import DAG, Task
from repro.pipelines.composer import HybridComposer
