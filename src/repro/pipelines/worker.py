"""Pipeline worker (the privately-hosted Airflow worker of paper §5/Figure 3).

A worker is an application POD: it lives on some partition, pulls task
instances from the broker, executes them, and commits results to the taskdb —
both services resolved by name through the hybrid platform (the worker has no
idea they live on the master cluster; cross-cloud traffic flows gateway ->
channel -> gateway exactly as in Figure 2 of the paper).

Built-in task kinds exercise the real JAX substrate:
  etl    — deterministic shard statistics over the synthetic pipeline
  train  — a reduced-config Trainer run (payload: arch/steps/...)
  eval   — forward loss of a fresh reduced model on held-out batches
  export — parameter manifest (count + tree paths)
Custom kinds register via ``register(kind, fn)``.

Commit pipelining (the data-plane throughput overhaul): a pipelined worker
drains up to ``batch`` task instances per queue per tick with ONE broker
``pull_many``, executes them, then commits the whole batch with ONE taskdb
``upsert_many`` (a running + terminal row pair per task, applied in order)
and ONE broker ``ack_many`` — 3 RPCs per batch instead of 4 per task. A task
that is pulled but never committed (worker death) is simply redelivered when
its broker lease expires, exactly as in the per-task protocol; the terminal
taskdb states of both protocols are identical (``pipelined=False`` keeps the
seed's per-task path for equivalence tests and the benchmark baseline).
"""
from __future__ import annotations

import traceback
from typing import Callable, Dict, List, Tuple

from repro.pipelines.services import ServiceClient


def _etl(payload: dict) -> dict:
    import jax.numpy as jnp
    from repro.data.pipeline import SyntheticTokens
    data = SyntheticTokens(vocab_size=payload.get("vocab", 512),
                           seq_len=payload.get("seq_len", 32),
                           global_batch=payload.get("batch", 4),
                           seed=payload.get("seed", 0))
    n = payload.get("batches", 2)
    toks = 0
    for i in range(n):
        b = data.batch_at(i)
        toks += int(b["tokens"].size)
    return {"batches": n, "tokens": toks}


def _train(payload: dict) -> dict:
    from repro.runtime.train_loop import Trainer, TrainJobConfig
    cfg = TrainJobConfig.from_job({"payload": dict(payload)})
    tr = Trainer(cfg)
    m = tr.run()
    out = {"steps": tr.step, "loss": m.get("loss")}
    if cfg.checkpoint_dir:
        out["checkpoint"] = tr.save_checkpoint()
    return out


def _eval(payload: dict) -> dict:
    from repro.runtime.train_loop import Trainer, TrainJobConfig
    cfg = TrainJobConfig.from_job({"payload": dict(payload)})
    tr = Trainer(cfg)
    if payload.get("restore_from"):
        tr.restore(payload["restore_from"])
    batch = tr._sync_batch(10_000)
    loss, _ = tr.model.loss_fn(tr.params_for_eval()
                               if cfg.mode == "local_sgd"
                               else tr.state["params"], batch)
    return {"eval_loss": float(loss)}


def _export(payload: dict) -> dict:
    import jax
    from repro.configs import base as configs
    from repro.models.params import param_defs, is_def
    cfg = configs.get(payload.get("arch", "qwen3-0.6b"))
    if payload.get("reduced", True):
        cfg = cfg.reduced()
    defs = jax.tree_util.tree_leaves(param_defs(cfg), is_leaf=is_def)
    n = sum(int(__import__("numpy").prod(d.shape)) for d in defs)
    return {"exported_params": n, "leaves": len(defs)}


DEFAULT_HANDLERS: Dict[str, Callable[[dict], dict]] = {
    "etl": _etl, "train": _train, "eval": _eval, "export": _export,
    "python": lambda p: {"echo": p},
}


class PipelineWorker:
    def __init__(self, client: ServiceClient, pod: str,
                 queues: Tuple[str, ...] = ("default",), clock_fn=None,
                 batch: int = 16, pipelined: bool = True):
        self.client = client
        self.pod = pod
        self.queues = tuple(queues)
        self.handlers = dict(DEFAULT_HANDLERS)
        self.clock_fn = clock_fn or (lambda: 0.0)
        self.batch = max(int(batch), 1)
        self.pipelined = pipelined
        self.executed = 0

    def register(self, kind: str, fn: Callable[[dict], dict]) -> None:
        self.handlers[kind] = fn

    # --------------------------------------------------------------------- one tick
    def tick(self) -> List[str]:
        """Drain up to ``batch`` tasks per queue; returns the executed ids."""
        if not self.pipelined:
            one = self._tick_sync()
            return [one] if one else []
        executed: List[str] = []
        for queue in self.queues:
            resp = self.client.call("broker", {"op": "pull_many",
                                               "queue": queue,
                                               "max_n": self.batch})
            msgs = resp.get("msgs") or []
            if not msgs:
                continue
            rows: List[dict] = []
            for msg in msgs:
                rows.extend(self._run(msg))
                executed.append(f"{msg['dag']}.{msg['task']}")
            # one batched commit, then one batched ack: the taskdb rows are
            # durable before the broker forgets the leases, so a crash between
            # the two at worst re-runs already-committed tasks (same-try
            # upserts are idempotent), never loses one
            self.client.call("taskdb", {"op": "upsert_many", "rows": rows})
            self.client.call("broker", {"op": "ack_many",
                                        "tags": resp.get("tags") or []})
        return executed

    def _run(self, msg: dict) -> List[dict]:
        """Execute one task; return its (running, terminal) row pair."""
        key = {"dag": msg["dag"], "task": msg["task"], "try": msg["try"]}
        rows = [{**key, "status": "running", "worker": self.pod,
                 "clock": self.clock_fn()}]
        fn = self.handlers.get(msg["kind"])
        try:
            if fn is None:
                raise KeyError(f"no handler for kind {msg['kind']!r}")
            result = fn(dict(msg.get("payload") or {}))
            rows.append({**key, "status": "success", "result": result,
                         "worker": self.pod, "clock": self.clock_fn()})
        except Exception as e:                               # noqa: BLE001
            rows.append({**key, "status": "failed",
                         "error": f"{type(e).__name__}: {e}",
                         "worker": self.pod, "clock": self.clock_fn()})
            traceback.print_exc()
        self.executed += 1
        return rows

    # ------------------------------------------------------- per-task protocol
    def _tick_sync(self):
        """The seed's one-task path: pull, upsert(running), execute,
        upsert(terminal), ack — 4 RPCs per task."""
        for queue in self.queues:
            resp = self.client.call("broker", {"op": "pull", "queue": queue})
            msg = resp.get("msg")
            if msg is None:
                continue
            self._execute(msg, resp.get("tag"))
            return f"{msg['dag']}.{msg['task']}"
        return None

    def _execute(self, msg: dict, tag) -> None:
        key = {"dag": msg["dag"], "task": msg["task"], "try": msg["try"]}
        self.client.call("taskdb", {"op": "upsert", **key, "status": "running",
                                    "worker": self.pod,
                                    "clock": self.clock_fn()})
        fn = self.handlers.get(msg["kind"])
        try:
            if fn is None:
                raise KeyError(f"no handler for kind {msg['kind']!r}")
            result = fn(dict(msg.get("payload") or {}))
            self.client.call("taskdb", {"op": "upsert", **key,
                                        "status": "success", "result": result,
                                        "worker": self.pod,
                                        "clock": self.clock_fn()})
        except Exception as e:                               # noqa: BLE001
            self.client.call("taskdb", {
                "op": "upsert", **key, "status": "failed",
                "error": f"{type(e).__name__}: {e}",
                "worker": self.pod, "clock": self.clock_fn()})
            traceback.print_exc()
        finally:
            self.executed += 1
            self.client.call("broker", {"op": "ack", "tag": tag})
