"""Pipeline worker (the privately-hosted Airflow worker of paper §5/Figure 3).

A worker is an application POD: it lives on some partition, pulls task
instances from the broker, executes them, and commits results to the taskdb —
both services resolved by name through the hybrid platform (the worker has no
idea they live on the master cluster; cross-cloud traffic flows gateway ->
channel -> gateway exactly as in Figure 2 of the paper).

Built-in task kinds exercise the real JAX substrate:
  etl    — deterministic shard statistics over the synthetic pipeline
  train  — a reduced-config Trainer run (payload: arch/steps/...)
  eval   — forward loss of a fresh reduced model on held-out batches
  export — parameter manifest (count + tree paths)
Custom kinds register via ``register(kind, fn)``.
"""
from __future__ import annotations

import traceback
from typing import Callable, Dict, Optional, Tuple

from repro.pipelines.services import ServiceClient


def _etl(payload: dict) -> dict:
    import jax.numpy as jnp
    from repro.data.pipeline import SyntheticTokens
    data = SyntheticTokens(vocab_size=payload.get("vocab", 512),
                           seq_len=payload.get("seq_len", 32),
                           global_batch=payload.get("batch", 4),
                           seed=payload.get("seed", 0))
    n = payload.get("batches", 2)
    toks = 0
    for i in range(n):
        b = data.batch_at(i)
        toks += int(b["tokens"].size)
    return {"batches": n, "tokens": toks}


def _train(payload: dict) -> dict:
    from repro.runtime.train_loop import Trainer, TrainJobConfig
    cfg = TrainJobConfig.from_job({"payload": dict(payload)})
    tr = Trainer(cfg)
    m = tr.run()
    out = {"steps": tr.step, "loss": m.get("loss")}
    if cfg.checkpoint_dir:
        out["checkpoint"] = tr.save_checkpoint()
    return out


def _eval(payload: dict) -> dict:
    from repro.runtime.train_loop import Trainer, TrainJobConfig
    cfg = TrainJobConfig.from_job({"payload": dict(payload)})
    tr = Trainer(cfg)
    if payload.get("restore_from"):
        tr.restore(payload["restore_from"])
    batch = tr._sync_batch(10_000)
    loss, _ = tr.model.loss_fn(tr.params_for_eval()
                               if cfg.mode == "local_sgd"
                               else tr.state["params"], batch)
    return {"eval_loss": float(loss)}


def _export(payload: dict) -> dict:
    import jax
    from repro.configs import base as configs
    from repro.models.params import param_defs, is_def
    cfg = configs.get(payload.get("arch", "qwen3-0.6b"))
    if payload.get("reduced", True):
        cfg = cfg.reduced()
    defs = jax.tree_util.tree_leaves(param_defs(cfg), is_leaf=is_def)
    n = sum(int(__import__("numpy").prod(d.shape)) for d in defs)
    return {"exported_params": n, "leaves": len(defs)}


DEFAULT_HANDLERS: Dict[str, Callable[[dict], dict]] = {
    "etl": _etl, "train": _train, "eval": _eval, "export": _export,
    "python": lambda p: {"echo": p},
}


class PipelineWorker:
    def __init__(self, client: ServiceClient, pod: str,
                 queues: Tuple[str, ...] = ("default",), clock_fn=None):
        self.client = client
        self.pod = pod
        self.queues = tuple(queues)
        self.handlers = dict(DEFAULT_HANDLERS)
        self.clock_fn = clock_fn or (lambda: 0.0)
        self.executed = 0

    def register(self, kind: str, fn: Callable[[dict], dict]) -> None:
        self.handlers[kind] = fn

    # --------------------------------------------------------------------- one tick
    def tick(self) -> Optional[str]:
        """Pull at most one task, execute it, commit the result."""
        for queue in self.queues:
            resp = self.client.call("broker", {"op": "pull", "queue": queue})
            msg = resp.get("msg")
            if msg is None:
                continue
            self._execute(msg, resp.get("tag"))
            return f"{msg['dag']}.{msg['task']}"
        return None

    def _execute(self, msg: dict, tag) -> None:
        key = {"dag": msg["dag"], "task": msg["task"], "try": msg["try"]}
        self.client.call("taskdb", {"op": "upsert", **key, "status": "running",
                                    "worker": self.pod,
                                    "clock": self.clock_fn()})
        fn = self.handlers.get(msg["kind"])
        try:
            if fn is None:
                raise KeyError(f"no handler for kind {msg['kind']!r}")
            result = fn(dict(msg.get("payload") or {}))
            self.client.call("taskdb", {"op": "upsert", **key,
                                        "status": "success", "result": result,
                                        "worker": self.pod,
                                        "clock": self.clock_fn()})
        except Exception as e:                               # noqa: BLE001
            self.client.call("taskdb", {
                "op": "upsert", **key, "status": "failed",
                "error": f"{type(e).__name__}: {e}",
                "worker": self.pod, "clock": self.clock_fn()})
            traceback.print_exc()
        finally:
            self.executed += 1
            self.client.call("broker", {"op": "ack", "tag": tag})
