"""Pipeline worker (the privately-hosted Airflow worker of paper §5/Figure 3).

A worker is an application POD: it lives on some partition, pulls task
instances from the broker, executes them, and commits results to the taskdb —
both services resolved by name through the hybrid platform (the worker has no
idea they live on the master cluster; cross-cloud traffic flows gateway ->
channel -> gateway exactly as in Figure 2 of the paper).

Built-in task kinds exercise the real JAX substrate:
  etl    — deterministic shard statistics over the synthetic pipeline
  train  — a reduced-config Trainer run (payload: arch/steps/...); resumes
           from its own checkpoint_dir and runs only the remaining steps
  eval   — forward loss on held-out batches; a ``restore_from`` manifest is
           restored STRICTLY (missing/torn checkpoint fails the task)
  serve  — synthetic prompts through the continuous-batching Server
  export — parameter manifest (count + tree paths)
Custom kinds register via ``register(kind, fn)``.

Warm workers (the compiled-step cache): ``step_cache > 0`` binds train/eval/
serve to per-worker LRU caches of jit-compiled Trainer/Server objects keyed
by compiled family (``repro.runtime.step_cache``) — a same-family task skips
model build + jit entirely and pays only its actual steps. ``step_cache=0``
keeps the seed's cold build-per-task behavior.

Commit pipelining (the data-plane throughput overhaul): a pipelined worker
drains up to ``batch`` task instances per queue per tick with ONE broker
``pull_many``, executes them, then commits the whole batch with ONE taskdb
``upsert_many`` (a running + terminal row pair per task, applied in order)
and ONE broker ``ack_many`` — 3 RPCs per batch instead of 4 per task. A task
that is pulled but never committed (worker death) is simply redelivered when
its broker lease expires, exactly as in the per-task protocol; the terminal
taskdb states of both protocols are identical (``pipelined=False`` keeps the
seed's per-task path for equivalence tests and the benchmark baseline).

Cross-boundary locality (the traffic overhaul): ``broker_for`` routes each
queue's ops to its owning broker shard's service (``BrokerRouter`` — one
``ack_many`` per shard that leased work, still one RPC total when unsharded),
and an optional ``depth_hint`` (the cluster-local, watch-materialized
``/queues/<name>`` view — maintained by the replica-fed notify plane, so any
number of workers share one shipped envelope per sweep) skips the
``pull_many`` round-trip entirely for queues the local view shows empty — a
remote worker polling idle queues stops paying a cross-boundary RPC per
queue per tick. A stale-zero hint only
delays the pull by the replica's staleness bound; a stale-positive hint costs
one empty pull — both degrade to the ungated protocol.

Drain protocol (the autoscaling plane): a worker being retired must hand its
slot back WITHOUT losing or re-running any leased task. The tick is split
into two explicit phases around an in-flight buffer —

  ``pull_phase``   lease up to ``batch`` messages per queue into the buffer;
  ``commit_phase`` execute the buffer, ONE ``upsert_many`` with every
                   (running, terminal) row pair, then ONE final ``ack_many``;

and ``drain()`` runs the graceful exit: stop pulling (state -> ``draining``),
execute + commit whatever is in flight, final-ack it, then flip to
``drained`` and fire ``on_drained`` (the autoscaler's hook that retires the
pod's job and publishes the drained state). Because every leased tag is
acked exactly after its terminal row is durable, the broker is left with no
lease to expire — nothing is redelivered, nothing runs twice. A drained
worker's ``tick()`` is a no-op forever after.

Crash survival (the durable control plane): workers live on their own
clusters and SURVIVE a master crash — the recovery contract has three parts.
(1) An executed-but-uncommitted batch is stashed in ``_pending_commit``
before any RPC, so a commit interrupted by master death retries verbatim
(same rows, same tags) instead of re-running handlers. (2) Messages the
broker redelivers arrive flagged; before executing a flagged message the
worker probes the taskdb (``status_many``) and skips anything already
terminal — the cross-restart dedup that makes redelivery safe. (3)
``reset_after_master_restart()`` drops unexecuted leases (the recovered
broker already requeued them) and re-arms the worker; its small ring of
recently executed terminal rows (``recent_rows``) is re-upserted by the
composer's recovery barrier, closing the window where an execution's row was
still volatile when the master died.
"""
from __future__ import annotations

import time
import traceback
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from repro.pipelines.services import ServiceClient


def _etl(payload: dict) -> dict:
    import jax.numpy as jnp
    from repro.data.pipeline import SyntheticTokens
    data = SyntheticTokens(vocab_size=payload.get("vocab", 512),
                           seq_len=payload.get("seq_len", 32),
                           global_batch=payload.get("batch", 4),
                           seed=payload.get("seed", 0))
    n = payload.get("batches", 2)
    toks = 0
    for i in range(n):
        b = data.batch_at(i)
        toks += int(b["tokens"].size)
    return {"batches": n, "tokens": toks}


def _train(payload: dict) -> dict:
    # cold path (no cache): a worker-bound handler passes its TrainerCache
    from repro.runtime.step_cache import run_train_task
    return run_train_task(None, payload)


def _eval(payload: dict) -> dict:
    from repro.runtime.step_cache import run_eval_task
    return run_eval_task(None, payload)


def _serve(payload: dict) -> dict:
    from repro.runtime.step_cache import run_serve_task
    return run_serve_task(None, payload)


def _export(payload: dict) -> dict:
    import jax
    from repro.configs import base as configs
    from repro.models.params import param_defs, is_def
    cfg = configs.get(payload.get("arch", "qwen3-0.6b"))
    if payload.get("reduced", True):
        cfg = cfg.reduced()
    defs = jax.tree_util.tree_leaves(param_defs(cfg), is_leaf=is_def)
    n = sum(int(__import__("numpy").prod(d.shape)) for d in defs)
    return {"exported_params": n, "leaves": len(defs)}


DEFAULT_HANDLERS: Dict[str, Callable[[dict], dict]] = {
    "etl": _etl, "train": _train, "eval": _eval, "serve": _serve,
    "export": _export,
    "python": lambda p: {"echo": p},
}


class PipelineWorker:
    def __init__(self, client: ServiceClient, pod: str,
                 queues: Tuple[str, ...] = ("default",), clock_fn=None,
                 batch: int = 16, pipelined: bool = True,
                 on_drained: Optional[Callable[["PipelineWorker"], None]]
                 = None,
                 broker_for: Optional[Callable[[str], str]] = None,
                 depth_hint: Optional[Callable[[str], int]] = None,
                 step_cache: int = 4, tracer=None, metrics=None):
        self.client = client
        self.pod = pod
        # flight recorder: traced messages get an "execute" span around the
        # handler and a "commit" span that stays open until the batch's acks
        # land (a master-crash-interrupted commit retries verbatim, and its
        # spans close when the retry commits — worker spans never truncate);
        # ``metrics`` records per-queue-family service-time histograms at ack
        # time (the predictive autoscaler's future input), sampled or not
        self.tracer = tracer
        self.metrics = metrics
        self._pending_trace: List[tuple] = []   # (queue, wall_s, commit_span)
        self.queues = tuple(queues)
        self.handlers = dict(DEFAULT_HANDLERS)
        # warm-worker compiled-step cache: train/eval/serve handlers reuse a
        # jit-compiled Trainer/Server across tasks of the same compiled
        # family instead of rebuilding (and re-jitting) per task. 0 disables
        # (cold per-task builds — the benchmark baseline). The caches are
        # created lazily on first use so a control-plane-only worker never
        # imports the JAX substrate.
        self.step_cache = max(int(step_cache), 0)
        self._trainer_cache = None
        self._server_cache = None
        if self.step_cache:
            self.handlers["train"] = self._cached_train
            self.handlers["eval"] = self._cached_eval
            self.handlers["serve"] = self._cached_serve
        self.clock_fn = clock_fn or (lambda: 0.0)
        self.batch = max(int(batch), 1)
        self.pipelined = pipelined
        # queue -> broker service (per-family sharding); default: the single
        # unsharded "broker" service, exactly the pre-sharding wire protocol
        self.broker_for = broker_for or (lambda queue: "broker")
        # queue -> believed ready depth, served from the cluster-local
        # overwatch replica (fan-out mode). 0 skips the pull round-trip for
        # that queue this tick — an empty remote queue no longer costs a
        # cross-boundary pull_many per tick. None (default): always pull.
        self.depth_hint = depth_hint
        self.skipped_pulls = 0
        self.executed = 0
        self.deduped = 0                # flagged redeliveries skipped as done
        self.state = "running"          # running | draining | drained
        self.on_drained = on_drained
        # leased, uncommitted: (msg, tag, broker service, redelivered flag,
        # queue name)
        self._inflight: List[Tuple[dict, int, str, bool, str]] = []
        # executed but not yet successfully committed: (rows, acks, executed)
        self._pending_commit: Optional[tuple] = None
        # resync ring: terminal rows this worker produced, re-upserted at the
        # composer's recovery barrier in case their commit was still volatile
        # when the master died (maxlen >> one tick's commit window)
        self.recent_rows: deque = deque(maxlen=1024)

    def register(self, kind: str, fn: Callable[[dict], dict]) -> None:
        self.handlers[kind] = fn

    # ------------------------------------------------------ warm task handlers
    def trainer_cache(self):
        if self._trainer_cache is None:
            from repro.runtime.step_cache import TrainerCache
            self._trainer_cache = TrainerCache(self.step_cache)
        return self._trainer_cache

    def server_cache(self):
        if self._server_cache is None:
            from repro.runtime.step_cache import ServerCache
            self._server_cache = ServerCache(self.step_cache)
        return self._server_cache

    def _cached_train(self, payload: dict) -> dict:
        from repro.runtime.step_cache import run_train_task
        return run_train_task(self.trainer_cache(), payload)

    def _cached_eval(self, payload: dict) -> dict:
        from repro.runtime.step_cache import run_eval_task
        return run_eval_task(self.trainer_cache(), payload)

    def _cached_serve(self, payload: dict) -> dict:
        from repro.runtime.step_cache import run_serve_task
        return run_serve_task(self.server_cache(), payload)

    # --------------------------------------------------------------------- one tick
    def tick(self) -> List[str]:
        """Drain up to ``batch`` tasks per queue; returns the executed ids."""
        if self.state == "drained":
            return []
        if not self.pipelined:
            if self.state == "draining":
                self._finish_drain()
                return []
            one = self._tick_sync()
            return [one] if one else []
        if self.state == "running":
            self.pull_phase()
        executed = self.commit_phase()
        if self.state == "draining":
            self._finish_drain()
        return executed

    # ------------------------------------------------------------ batch phases
    def pull_phase(self) -> int:
        """Phase 1: lease up to ``batch`` task instances per queue into the
        in-flight buffer (one ``pull_many`` per queue). A draining worker
        never pulls — the first step of the drain protocol."""
        if self.state != "running":
            return 0
        if self._pending_commit is not None:
            return 0                 # commit backlog first: no new leases
        pulled = 0
        for queue in self.queues:
            if self.depth_hint is not None and not self.depth_hint(queue):
                self.skipped_pulls += 1      # local view says empty: no RPC
                continue
            svc = self.broker_for(queue)
            resp = self.client.call(svc, {"op": "pull_many",
                                          "queue": queue,
                                          "max_n": self.batch})
            msgs = resp.get("msgs") or []
            tags = resp.get("tags") or []
            flags = resp.get("redelivered") or [False] * len(msgs)
            self._inflight.extend(
                (m, t, svc, f, queue) for m, t, f in zip(msgs, tags, flags))
            pulled += len(msgs)
        return pulled

    def commit_phase(self) -> List[str]:
        """Phase 2: execute the in-flight buffer, then commit it with ONE
        taskdb ``upsert_many`` and ONE broker ``ack_many`` per broker shard
        that leased work this batch (exactly one with an unsharded broker).
        Rows are durable before any broker forgets its leases, so a crash
        between the two at worst re-runs already-committed tasks (same-try
        upserts are idempotent), never loses one.

        The executed batch is stashed in ``_pending_commit`` BEFORE the
        commit RPCs: if the master dies mid-commit the stash retries verbatim
        on the recovery barrier (or the next tick after a heal) — handlers
        never re-run for a batch that already executed. Flagged (redelivered)
        messages are dedup-probed against the taskdb first; the probe costs
        nothing on the clean path, where no flags arrive."""
        if self._pending_commit is None:
            if not self._inflight:
                return []
            batch, self._inflight = self._inflight, []
            # dedup BEFORE executing: probing raises (master down) with
            # nothing run yet, so dropping the batch back to lease expiry is
            # always duplicate-free
            done = self._probe_terminal(batch)
            rows: List[dict] = []
            acks: Dict[str, List[int]] = {}  # broker service -> leased tags
            executed: List[str] = []
            seen: set = set()
            # one clock read covers the batch: execution is instantaneous in
            # simulated time (the clock only advances between ticks)
            tnow = self.tracer.clock() if self.tracer is not None else 0.0
            for msg, tag, svc, redel, queue in batch:
                key = (msg["dag"], msg["task"], msg["try"])
                if (redel and key in done) or key in seen:
                    self.deduped += 1        # already ran (here or elsewhere)
                else:
                    seen.add(key)
                    pair = self._run_traced(msg, queue, tnow)
                    rows.extend(pair)
                    self.recent_rows.append(pair[-1])
                    executed.append(f"{msg['dag']}.{msg['task']}")
                acks.setdefault(svc, []).append(tag)
            self._pending_commit = (rows, acks, executed)
        rows, acks, executed = self._pending_commit
        if rows:
            self.client.call("taskdb", {"op": "upsert_many", "rows": rows})
        for svc in sorted(acks):
            self.client.call(svc, {"op": "ack_many", "tags": acks[svc]})
        self._pending_commit = None
        self._finish_commit_trace()
        return executed

    def _run_traced(self, msg: dict, queue: str, tnow: float) -> List[dict]:
        """``_run`` plus flight-recorder bookkeeping: the outcome of the
        "execute" span (with the task's step EMA when the runtime reports
        one) and the start of the "commit" span are STASHED, not recorded —
        ``_finish_commit_trace`` appends both once this batch's acks land,
        so after a master crash the stashed batch retries verbatim and its
        spans are recorded exactly once, by the attempt that commits. The
        execution wall time is stashed alongside for the service-time
        histogram, traced or not. ``tnow`` is the batch's single clock read
        — execution is instantaneous in simulated time (the clock only
        advances between ticks); its real cost rides in the ``wall_s``
        attr."""
        w0 = time.perf_counter()
        pair = self._run(msg)
        wall = time.perf_counter() - w0
        ctx = msg.get("trace") if self.tracer is not None else None
        if ctx is not None:
            terminal = pair[-1]
            res = terminal.get("result")
            ema = (res.get("step_ema_s")
                   if isinstance(res, dict) else None)    # StepTimer's EMA
            st = "ok" if terminal["status"] == "success" else "failed"
        else:
            ema, st = None, "ok"
        self._pending_trace.append((queue, wall, ctx, tnow, st, ema))
        return pair

    def _finish_commit_trace(self) -> None:
        """The batch's acks landed: record each task's service time into the
        per-queue-family histogram and its execute/commit span pair — raw
        event appends, one clock read and one bound check per batch."""
        if not self._pending_trace:
            return
        pt, self._pending_trace = self._pending_trace, []
        tr = self.tracer
        metrics = self.metrics
        if tr is None:
            if metrics is not None:
                for queue, wall, _ctx, _t0, _st, _ema in pt:
                    metrics.observe(f"pipeline.service_time.{queue}", wall)
            return
        t1 = tr.clock()                  # one read per batch
        rec = tr.rec
        for queue, wall, ctx, t0, st, ema in pt:
            if metrics is not None:
                metrics.observe(f"pipeline.service_time.{queue}", wall)
            if ctx is not None:
                a = ({"wall_s": wall} if ema is None
                     else {"wall_s": wall, "step_ema_s": ema})
                rec((None, ctx, "execute", "worker", t0, t0, st, a))
                rec((None, ctx, "commit", "worker", t0, t1, "ok", None))
        tr.bound()

    def _probe_terminal(self, batch) -> set:
        """(dag, task, try) keys among the batch's FLAGGED messages that the
        taskdb already shows terminal — one ``status_many`` RPC, only issued
        when at least one message carries the redelivered flag."""
        flagged = [(m["dag"], m["task"], m["try"])
                   for m, _, _, redel, _ in batch if redel]
        if not flagged:
            return set()
        resp = self.client.call("taskdb", {
            "op": "status_many", "keys": [list(k) for k in flagged]})
        return {tuple(k) for k, st in zip(flagged, resp.get("statuses", ()))
                if st in ("success", "failed")}

    # -------------------------------------------------------- crash recovery
    def retry_pending(self) -> List[str]:
        """Re-issue a commit interrupted by master death (no-op otherwise)."""
        if self._pending_commit is None:
            return []
        return self.commit_phase()

    def reset_after_master_restart(self) -> int:
        """Recovery barrier: drop unexecuted leases (the recovered broker
        requeued them under fresh flags — holding them here would double-run),
        keep ``_pending_commit`` for retry, and clear any ``on_drained``
        closure wired to dead pre-crash services (the rebuilt autoscaler
        re-arms draining pods). Returns the number of dropped leases."""
        dropped = len(self._inflight)
        self._inflight = []
        self.on_drained = None
        return dropped

    # ------------------------------------------------------------------- drain
    def drain(self) -> List[str]:
        """Graceful exit: stop pulling, execute + commit the in-flight batch,
        final ack, then publish the drained state through ``on_drained``.
        Loss-free by construction — every lease this worker holds is acked
        after its terminal row commits, so the broker redelivers nothing."""
        if self.state == "drained":
            return []
        self.state = "draining"
        executed = self.commit_phase() if self.pipelined else []
        if self.pipelined and self._inflight:
            # a retried pending commit went first; flush the live buffer too
            executed += self.commit_phase()
        self._finish_drain()
        return executed

    def _finish_drain(self) -> None:
        if (self.state == "drained" or self._inflight
                or self._pending_commit is not None):
            return
        self.state = "drained"
        if self.on_drained is not None:
            self.on_drained(self)

    def _run(self, msg: dict) -> List[dict]:
        """Execute one task; return its (running, terminal) row pair."""
        key = {"dag": msg["dag"], "task": msg["task"], "try": msg["try"]}
        rows = [{**key, "status": "running", "worker": self.pod,
                 "clock": self.clock_fn()}]
        fn = self.handlers.get(msg["kind"])
        try:
            if fn is None:
                raise KeyError(f"no handler for kind {msg['kind']!r}")
            result = fn(dict(msg.get("payload") or {}))
            rows.append({**key, "status": "success", "result": result,
                         "worker": self.pod, "clock": self.clock_fn()})
        except Exception as e:                               # noqa: BLE001
            rows.append({**key, "status": "failed",
                         "error": f"{type(e).__name__}: {e}",
                         "worker": self.pod, "clock": self.clock_fn()})
            traceback.print_exc()
        self.executed += 1
        return rows

    # ------------------------------------------------------- per-task protocol
    def _tick_sync(self):
        """The seed's one-task path: pull, upsert(running), execute,
        upsert(terminal), ack — 4 RPCs per task."""
        for queue in self.queues:
            svc = self.broker_for(queue)
            resp = self.client.call(svc, {"op": "pull", "queue": queue})
            msg = resp.get("msg")
            if msg is None:
                continue
            self._execute(msg, resp.get("tag"), svc, queue)
            return f"{msg['dag']}.{msg['task']}"
        return None

    def _execute(self, msg: dict, tag, svc: str = "broker",
                 queue: Optional[str] = None) -> None:
        key = {"dag": msg["dag"], "task": msg["task"], "try": msg["try"]}
        self.client.call("taskdb", {"op": "upsert", **key, "status": "running",
                                    "worker": self.pod,
                                    "clock": self.clock_fn()})
        fn = self.handlers.get(msg["kind"])
        tr = self.tracer
        ctx = msg.get("trace") if tr is not None else None
        ts0 = tr.clock() if ctx is not None else 0.0
        t0 = time.perf_counter()
        ok = True
        try:
            if fn is None:
                raise KeyError(f"no handler for kind {msg['kind']!r}")
            result = fn(dict(msg.get("payload") or {}))
            if ctx is not None:
                tr.span_complete(ctx, "execute", "worker", ts0)
            self.client.call("taskdb", {"op": "upsert", **key,
                                        "status": "success", "result": result,
                                        "worker": self.pod,
                                        "clock": self.clock_fn()})
        except Exception as e:                               # noqa: BLE001
            ok = False
            if ctx is not None:
                tr.span_complete(ctx, "execute", "worker", ts0, "failed")
            self.client.call("taskdb", {
                "op": "upsert", **key, "status": "failed",
                "error": f"{type(e).__name__}: {e}",
                "worker": self.pod, "clock": self.clock_fn()})
            traceback.print_exc()
        finally:
            self.executed += 1
            tc0 = tr.clock() if ctx is not None else 0.0
            self.client.call(svc, {"op": "ack", "tag": tag})
            if self.metrics is not None and queue is not None:
                self.metrics.observe(f"pipeline.service_time.{queue}",
                                     time.perf_counter() - t0)
            if ctx is not None:
                tr.span_complete(ctx, "commit", "worker", tc0,
                                 "ok" if ok else "failed")
