"""Workflow DAGs of data-processing tasks (the Airflow model, paper §5).

A ``Task`` is a named unit with upstream dependencies, a kind (etl / train /
eval / export / custom python), a payload, and optional placement constraints
(``requires`` capability tags — the paper's compliance routing). A ``DAG``
validates acyclicity and yields ready sets; scheduling/execution live in
scheduler.py / worker.py.
``DAG`` precomputes the downstream adjacency (``children``) once at
construction, so validation, topological order and failure propagation are
O(V + E) — the seed rescanned every task per visited node, which is quadratic
and unusable at the 50k-task scale the pipeline benchmarks run at.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Task:
    name: str
    kind: str = "python"                 # etl | train | eval | export | python
    upstream: Tuple[str, ...] = ()
    payload: dict = dataclasses.field(default_factory=dict)
    requires: Tuple[str, ...] = ()       # capability tags (compliance routing)
    retries: int = 1
    fn: Optional[Callable[[dict], dict]] = None   # python tasks (tests/examples)
    # explicit roofline cost vector (flops/hbm_bytes/collective_bytes/io_bytes
    # — e.g. a committed hlo_stats dry-run artifact); None defers to
    # ``repro.roofline.cost.task_cost``'s payload/analytic fallbacks
    cost: Optional[dict] = None


class DAG:
    def __init__(self, dag_id: str, tasks: Sequence[Task]):
        self.dag_id = dag_id
        self.tasks: Dict[str, Task] = {}
        for t in tasks:
            if t.name in self.tasks:
                raise ValueError(f"duplicate task {t.name}")
            self.tasks[t.name] = t
        # downstream adjacency, one entry per upstream edge occurrence so the
        # indegree arithmetic matches the declared tuples exactly
        self.children: Dict[str, List[str]] = {n: [] for n in self.tasks}
        self._validate()

    def _validate(self) -> None:
        for t in self.tasks.values():
            for u in t.upstream:
                if u not in self.tasks:
                    raise ValueError(f"{t.name} depends on unknown task {u}")
                self.children[u].append(t.name)
        order = self.topological_order()
        if len(order) != len(self.tasks):
            raise ValueError(f"cycle in DAG {self.dag_id}")

    def topological_order(self) -> List[str]:
        indeg = {n: len(t.upstream) for n, t in self.tasks.items()}
        ready = [n for n, d in indeg.items() if d == 0]
        heapq.heapify(ready)                 # name order among the ready set
        out: List[str] = []
        while ready:
            n = heapq.heappop(ready)
            out.append(n)
            for m in self.children[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    heapq.heappush(ready, m)
        return out

    def ready_tasks(self, done: set, running: set, failed: set) -> List[Task]:
        """Tasks whose upstreams are all done and which are not yet scheduled."""
        out = []
        for n, t in self.tasks.items():
            if n in done or n in running or n in failed:
                continue
            if all(u in done for u in t.upstream):
                out.append(t)
        return sorted(out, key=lambda t: t.name)

    def downstream_of(self, name: str) -> set:
        out: set = set()
        stack = [name]
        while stack:
            for m in self.children[stack.pop()]:
                if m not in out:
                    out.add(m)
                    stack.append(m)
        return out
