"""Message broker (the redis of paper §5) — named FIFO queues with lease-style
redelivery: a pulled message is invisible until acked or its lease expires
(worker died mid-task -> the task instance is redelivered, not lost).

Batched protocol (the data-plane throughput overhaul): alongside the original
per-message ops (``push``/``pull``/``ack``/``nack``/``depth``) the broker
speaks batch ops that amortize one round-trip over many messages:

  * ``push_many(queue, msgs)``   — enqueue a whole ready frontier in one RPC;
  * ``pull_many(queue, max_n)``  — a worker drains up to ``max_n`` task
    instances per round-trip (partial fills are fine, empty queues return
    empty lists);
  * ``ack_many(tags)``           — one commit acknowledges a whole executed
    batch (idempotent: unknown/already-acked tags are skipped);
  * ``depth_many(queues?)``      — one probe reads every queue's depth.

Lease bookkeeping is O(log n): pulls push ``(expires_at, tag)`` onto a
lazy-deletion min-heap (acked tags leave stale heap entries that are skipped
when popped), so every op pays one heap peek instead of the old full
``inflight`` scan — the same structure the overwatch lease table uses.

Depth telemetry is truthful: ``depth`` reports ``(ready, inflight)`` — the
messages waiting in the queue AND the ones leased out to workers — and
``changed_depths()`` yields only queues whose counts moved since the last
call, so a sweep-cadence publisher (the composer) writes coalesce-friendly
``/queues/<name>`` deltas into the overwatch instead of re-putting every
queue every tick.

Redelivery keeps the message dict — ``try`` metadata included — byte-for-byte
intact. By default an expired or nacked message re-enters its queue at the
BACK (FIFO arrival order); the old always-``appendleft`` behavior starved the
queue head under churn, because every redelivery jumped ahead of messages
that had been waiting longer. ``requeue_front=True`` (per-broker, or per-op
on ``nack``/``nack_many``) restores jump-the-queue redelivery where lower
redelivery latency matters more than fairness.

Redelivery accounting distinguishes cause: ``stats["redelivered"]`` counts
lease-EXPIRY redeliveries (a worker died holding the lease) while
``stats["redelivered_nacked"]`` counts explicit returns (``nack`` /
``nack_many`` — a worker handing work back on purpose). An autoscaler
draining fleets cleanly should leave the expiry counter untouched; a rising
expiry count is a fleet-health signal, a rising nack count is backpressure.

Read ops are strictly read-only on queue state: ``pull``/``pull_many``/
``depth``/``depth_many`` against an unknown queue return empty/zero and
create NOTHING — probing a queue name must never materialize broker state.
``depth_many`` without an explicit queue list reports only queues with a
non-zero ready or inflight count, matching the tombstoned ``/queues/<name>``
view (a fully drained queue disappears rather than lingering at 0/0).

Per-family sharding (the cross-boundary traffic overhaul): ``BrokerRouter``
splits the broker behind a consistent-hash ring over queue families (a family
IS the queue name — ``scheduler.queue_for`` derives it from the capability
set), the exact discipline the overwatch ``ShardRouter`` uses. Each shard is a
full ``Broker`` behind its OWN fabric endpoint/service (``broker-s<k>``), so
worker ``pull_many``/``ack_many`` batches for disjoint families stop
serializing through one handler, and every client (scheduler, workers) derives
identical routing from the shard count alone — no topology exchange.
``num_shards=1`` keeps the single ``"broker"`` service and is
behavior-identical to the unsharded broker. Both ``depth_many`` and
``changed_depths`` accept a family filter so a publisher only reports the
families its shard owns.

Durability (the crash-survivable control plane): constructed with a
``repro.core.durability.LogStore``, every state-changing op appends a WAL
record and the composer group-commits once per tick — taskdb before broker,
so an acknowledged effect is always at least as durable as its ack. After a
crash ``recover()`` rebuilds from snapshot + replay, requeues every in-flight
lease, marks all surviving messages ``redelivered`` (workers dedup-probe the
taskdb before re-executing), and bumps a persisted tag *epoch* so acks for
pre-crash tags are recognized as stale (``stats["stale_acks"]``) instead of
releasing someone else's lease.
"""
from __future__ import annotations

import heapq
from collections import Counter, deque
from typing import Callable, Deque, Dict, List, Optional, Tuple, Union

from repro.core.overwatch import ShardRouter


BROKER_SERVICE = "broker"


def broker_service_names(num_shards: int) -> Tuple[str, ...]:
    """Service names backing the (possibly sharded) broker. One shard keeps
    the historic ``"broker"`` name — identical AppSpec, DNS, ACLs, channels."""
    if num_shards <= 1:
        return (BROKER_SERVICE,)
    return tuple(f"broker-s{i}" for i in range(num_shards))


class BrokerRouter(ShardRouter):
    """Deterministic queue-family -> shard routing: the overwatch's
    consistent-hash ring (crc32, 32 vnodes/shard) under an independent seed.
    Clients and the composer build the same ring from the shard count alone,
    so routing is a pure function — part of the wire contract exactly like
    the overwatch ring parameters."""

    def __init__(self, num_shards: int, vnodes: int = 32):
        super().__init__(max(1, num_shards), vnodes=vnodes,
                         seed="broker-shard")

    def shard_for_queue(self, queue: str) -> int:
        return self.shard_for_segment(queue)

    def service_for_queue(self, queue: str) -> str:
        """The service name a client dials for this queue's ops."""
        if self.num_shards == 1:
            return BROKER_SERVICE
        return f"broker-s{self.shard_for_queue(queue)}"


FamilyFilter = Union[None, Callable[[str], bool], set, frozenset, list, tuple]


def _family_match(families: FamilyFilter, queue: str) -> bool:
    if families is None:
        return True
    if callable(families):
        return bool(families(queue))
    return queue in families


TAG_EPOCH_STRIDE = 1_000_000_000


class Broker:
    def __init__(self, clock_fn=None, lease: float = 30.0,
                 requeue_front: bool = False,
                 durability=None, shard_name: str = "broker",
                 tracer=None, recover: bool = True):
        # flight recorder: a "queue" span opens at push/requeue and closes at
        # pull — the queue-wait segment of a task's trace. Set BEFORE the
        # durability check below: WAL replay re-pushes messages and must
        # re-open their spans (the pre-crash ones were truncated).
        self.tracer = tracer
        self.queues: Dict[str, Deque[dict]] = {}
        # parallel to queues: per-message redelivered flags. Kept as a SEPARATE
        # aligned deque (not wrapped tuples) so queue entries stay the raw
        # message dicts clients pushed — observable queue state is unchanged.
        self._flags: Dict[str, Deque[bool]] = {}
        # tag -> (queue, msg, expires_at, redelivered); tags are unique per
        # pull, so a heap entry is stale iff its tag is gone from this table
        self.inflight: Dict[int, Tuple[str, dict, float, bool]] = {}
        self._expiry_heap: List[Tuple[float, int]] = []
        self._inflight_count: Counter = Counter()    # per-queue leased-out
        # tag = epoch * TAG_EPOCH_STRIDE + n. Epoch 0 (no durability, or no
        # crash yet) makes tags the plain 1,2,3,... they always were; recovery
        # bumps the epoch so every pre-crash tag misses the new lease table
        # and lands in stats["stale_acks"] instead of acking the wrong lease.
        self._epoch = 0
        self._tag_n = 0
        self.clock_fn = clock_fn or (lambda: 0.0)
        self.lease = lease
        self.requeue_front = requeue_front
        self.op_counts: Counter = Counter()          # per-op RPC accounting
        self.stats: Counter = Counter()              # expire_scanned/redelivered
        self._depth_dirty: set = set()
        self._published: Dict[str, Tuple[int, int]] = {}
        # durability: every state-changing op appends a WAL record (see the
        # replay table in _apply_replay); the composer group-commits per tick
        # and snapshots via snapshot_payload(). None => identical behavior.
        self._dur = durability
        self._shard = shard_name
        self.recovered_task_keys: set = set()
        # multi-master live migration (repro.core.shardmap): while frozen,
        # every state-changing op bounces with a stale-epoch hint (depth
        # reads keep serving); on_stale reports bounces to the coordinator.
        # ``recover=False`` builds an empty broker for ``install_payload``
        # (a live-migration import must not replay the WAL it is replacing).
        self.frozen = False
        self.on_stale = None
        if recover and durability is not None \
                and durability.has_data(shard_name):
            self.recover()

    # ------------------------------------------------------------------ leases
    def _expire(self) -> None:
        """Pop due leases off the min-heap and redeliver their messages.

        O(expired · log n): a peek when nothing is due — never a scan of the
        live ``inflight`` table. ``stats['expire_scanned']`` counts heap pops
        so tests can pin the no-scan property.
        """
        now = self.clock_fn()
        heap = self._expiry_heap
        while heap and heap[0][0] < now:
            _, tag = heapq.heappop(heap)
            self.stats["expire_scanned"] += 1
            rec = self.inflight.pop(tag, None)
            if rec is None:
                continue                     # stale entry (acked) — lazy delete
            queue, msg = rec[0], rec[1]
            self._requeue(queue, msg, self.requeue_front, redelivered=True)
            self.stats["redelivered"] += 1          # lease-expiry redelivery
            if self._dur is not None:
                self._dur.append(self._shard, ("exp", tag))

    def _requeue(self, queue: str, msg: dict, front: bool,
                 redelivered: bool = True) -> None:
        q = self.queues.setdefault(queue, deque())
        f = self._flags.setdefault(queue, deque())
        if front:
            q.appendleft(msg)
            f.appendleft(redelivered)
        else:
            q.append(msg)
            f.append(redelivered)
        self._inflight_count[queue] -= 1
        self._depth_dirty.add(queue)
        self._trace_push(msg, redelivered=True)   # second queue-wait segment

    # -------------------------------------------------------------- tracing
    def _trace_push(self, msg, redelivered: bool = False,
                    now=None) -> None:
        """Open the queue-wait span for a traced message (no-op otherwise).
        Keyed by (dag, task, try) so pull — or post-crash replay — closes the
        same span; re-pushing an already-open key reuses it (no orphans).
        Batch pushes pass ``now`` so the clock is read once per batch."""
        tr = self.tracer
        if tr is None or not isinstance(msg, dict) or "trace" not in msg:
            return
        tr.open_keyed(("queue", msg["dag"], msg["task"], msg["try"]),
                      "queue", "broker", parent=msg["trace"],
                      attrs={"redelivered": redelivered} if redelivered
                      else None, t0=now)

    def _trace_pull(self, msg, now=None) -> None:
        """Close the queue-wait span at lease time (no-op when untraced,
        already closed, or crash-truncated)."""
        if self.tracer is None or not isinstance(msg, dict) \
                or "trace" not in msg:
            return
        self.tracer.close_keyed(
            ("queue", msg["dag"], msg["task"], msg["try"]), t1=now)

    # ------------------------------------------------------------- op helpers
    def _next_tag(self) -> int:
        self._tag_n += 1
        return self._epoch * TAG_EPOCH_STRIDE + self._tag_n

    def _push(self, queue: str, msg: dict, redelivered: bool = False) -> None:
        self.queues.setdefault(queue, deque()).append(msg)
        self._flags.setdefault(queue, deque()).append(redelivered)
        self._depth_dirty.add(queue)
        self._trace_push(msg, redelivered)

    def _pull_one(self, queue: str,
                  trace: bool = True) -> Optional[Tuple[dict, int, bool]]:
        q = self.queues.get(queue)
        if not q:
            return None
        item = q.popleft()
        flag = self._flags[queue].popleft()
        now = self.clock_fn()
        if trace:                        # pull_many batch-closes instead
            self._trace_pull(item, now=now)
        tag = self._next_tag()
        expires = now + self.lease
        self.inflight[tag] = (queue, item, expires, flag)
        heapq.heappush(self._expiry_heap, (expires, tag))
        self._inflight_count[queue] += 1
        self._depth_dirty.add(queue)
        return item, tag, flag

    def _ack_one(self, tag) -> bool:
        rec = self.inflight.pop(tag, None)
        if rec is None:
            self.stats["stale_acks"] += 1    # idempotent: unknown/double ack
            return False
        self._inflight_count[rec[0]] -= 1
        self._depth_dirty.add(rec[0])
        if self._dur is not None:
            self._dur.append(self._shard, ("ack", tag))
        return True

    def _nack_one(self, tag, front) -> bool:
        """Explicit return of a leased message (idempotent like ack)."""
        rec = self.inflight.pop(tag, None)
        if rec is None:
            self.stats["stale_acks"] += 1
            return False
        self._requeue(rec[0], rec[1],
                      self.requeue_front if front is None else front,
                      redelivered=rec[3])
        self.stats["redelivered_nacked"] += 1
        if self._dur is not None:
            self._dur.append(self._shard, ("nack", tag, front))
        return True

    def _depth_of(self, queue: str) -> Tuple[int, int]:
        return (len(self.queues.get(queue) or ()),
                self._inflight_count.get(queue, 0))

    # ------------------------------------------------------------ service API
    def handle(self, msg: dict) -> dict:
        op = msg.get("op")
        self.op_counts[op] += 1
        if self.frozen and op not in ("depth", "depth_many"):
            # mid-migration: no state change may land behind the transferred
            # snapshot (not even a lease expiry). Callers bounce-and-retry —
            # the scheduler stashes its pushes, workers treat it like an
            # empty pull / unacked batch (lease redelivery + dedup probe).
            self.stats["frozen_bounced"] += 1
            if self.on_stale is not None:
                self.on_stale()
            return {"ok": False, "error": "broker shard frozen (migrating)",
                    "stale_epoch": True, "frozen": True}
        self._expire()
        if op == "push":
            redel = bool(msg.get("redelivered"))
            self._push(msg["queue"], msg["msg"], redel)
            if self._dur is not None:
                self._dur.append(self._shard,
                                 ("push", msg["queue"], msg["msg"], redel))
            return {"ok": True, "depth": len(self.queues[msg["queue"]])}
        if op == "push_many":
            redel = bool(msg.get("redelivered"))
            q = self.queues.setdefault(msg["queue"], deque())
            q.extend(msg["msgs"])
            self._flags.setdefault(msg["queue"], deque()).extend(
                redel for _ in msg["msgs"])
            self._depth_dirty.add(msg["queue"])
            if self.tracer is not None:
                ra = {"redelivered": True} if redel else None
                items = [(("queue", m["dag"], m["task"], m["try"]),
                          m["trace"], ra)
                         for m in msg["msgs"] if "trace" in m]
                if items:                # one call for the whole batch
                    self.tracer.open_keyed_many(items, "queue", "broker",
                                                self.clock_fn())
            if self._dur is not None:
                self._dur.append(self._shard,
                                 ("pushN", msg["queue"], msg["msgs"], redel))
            return {"ok": True, "depth": len(q)}
        if op == "pull":
            got = self._pull_one(msg["queue"])
            if got is None:
                return {"ok": True, "msg": None}
            if self._dur is not None:
                self._dur.append(self._shard,
                                 ("pullN", msg["queue"], [got[1]]))
            resp = {"ok": True, "msg": got[0], "tag": got[1]}
            if got[2]:
                resp["redelivered"] = True
            return resp
        if op == "pull_many":
            msgs: List[dict] = []
            tags: List[int] = []
            flags: List[bool] = []
            for _ in range(max(int(msg.get("max_n", 1)), 0)):
                got = self._pull_one(msg["queue"], trace=False)
                if got is None:
                    break
                msgs.append(got[0])
                tags.append(got[1])
                flags.append(got[2])
            if self.tracer is not None and msgs:
                keys = [("queue", m["dag"], m["task"], m["try"])
                        for m in msgs if "trace" in m]
                if keys:                 # one close for the whole batch
                    self.tracer.close_keyed_many(keys, self.clock_fn())
            if tags and self._dur is not None:
                self._dur.append(self._shard, ("pullN", msg["queue"], tags))
            resp = {"ok": True, "msgs": msgs, "tags": tags}
            if any(flags):
                # only present when something needs a dedup probe: the clean
                # path's response stays byte-identical to the flagless broker
                resp["redelivered"] = flags
            return resp
        if op == "ack":
            self._ack_one(msg.get("tag"))
            return {"ok": True}
        if op == "ack_many":
            tags = msg.get("tags", ())
            acked = sum(1 for t in tags if self._ack_one(t))
            return {"ok": True, "acked": acked}
        if op == "nack":
            self._nack_one(msg.get("tag"), msg.get("requeue_front"))
            return {"ok": True}
        if op == "nack_many":
            front = msg.get("requeue_front")
            nacked = sum(1 for t in msg.get("tags", ())
                         if self._nack_one(t, front))
            return {"ok": True, "nacked": nacked}
        if op == "depth":
            ready, inflight = self._depth_of(msg["queue"])
            return {"ok": True, "depth": ready,
                    "ready": ready, "inflight": inflight}
        if op == "depth_many":
            queues = msg.get("queues")
            families = msg.get("families")   # per-family filter (sharding)
            listing = queues is None
            if listing:
                queues = sorted(set(self.queues) | set(self._inflight_count))
            depths = {}
            for q in queues:
                if not _family_match(families, q):
                    continue
                ready, inflight = self._depth_of(q)
                if listing and not ready and not inflight:
                    continue            # drained queues drop out of listings
                depths[q] = {"ready": ready, "inflight": inflight}
            return {"ok": True, "depths": depths}
        return {"ok": False, "error": f"unknown op {op}"}

    # ------------------------------------------------------------- durability
    def snapshot_payload(self) -> dict:
        """Full broker state for snapshot+truncate compaction: ready queues
        with their redelivered flags, the in-flight lease table, and the tag
        epoch/counter. ``Broker.recover()`` rebuilds from this plus the
        post-snapshot WAL tail."""
        return {
            "epoch": self._epoch, "tag_n": self._tag_n,
            "queues": {q: [[m, f] for m, f in
                           zip(dq, self._flags.get(q, ()))]
                       for q, dq in self.queues.items() if dq},
            "inflight": [[tag, rec[0], rec[1], rec[2], rec[3]]
                         for tag, rec in self.inflight.items()],
        }

    def install_payload(self, payload: dict) -> None:
        """Live-migration import: the transferred ``snapshot_payload`` becomes
        this broker's state verbatim — ready queues with flags, the in-flight
        lease table (expiry heap rebuilt), and the tag epoch/counter. Leases
        and tags SURVIVE the handoff: a worker acking a pre-migration pull
        after the flip still lands it, so a migration costs zero redeliveries
        (failover uses ``recover()`` instead, which requeues + bumps the
        epoch because the old leases died with the master)."""
        self._epoch = payload["epoch"]
        self._tag_n = payload["tag_n"]
        self.queues = {}
        self._flags = {}
        for q, items in payload["queues"].items():
            dq = self.queues.setdefault(q, deque())
            fq = self._flags.setdefault(q, deque())
            for msg, flag in items:
                dq.append(msg)
                fq.append(flag)
        self.inflight = {}
        self._expiry_heap = []
        self._inflight_count = Counter()
        for tag, q, msg, expires, flag in payload["inflight"]:
            self.inflight[tag] = (q, msg, expires, flag)
            heapq.heappush(self._expiry_heap, (expires, tag))
            self._inflight_count[q] += 1
        self._depth_dirty = set(self.queues) | set(self._inflight_count)
        self._published = {}

    def held_task_keys(self) -> set:
        """Every (dag, task, try) this broker currently holds — ready OR
        leased out. The reseed-after-failover set: a queued/running taskdb
        row with no held message lost its message and must be re-pushed."""
        held = {(m["dag"], m["task"], m["try"])
                for dq in self.queues.values() for m in dq
                if isinstance(m, dict) and "dag" in m and "task" in m}
        for q, m, _expires, _flag in self.inflight.values():
            if isinstance(m, dict) and "dag" in m and "task" in m:
                held.add((m["dag"], m["task"], m["try"]))
        return held

    def _apply_replay(self, rec) -> None:
        """One WAL record. Types: ``push``/``pushN`` (queue, msg(s), flag),
        ``pullN`` (queue, tags — head messages move in-flight under the
        recorded tags), ``ack``/``nack`` (tag), ``exp`` (lease-expiry
        requeue), ``epoch``. Replay is NOT idempotent (pull/ack move state);
        the LogStore's LSN filtering guarantees each record applies exactly
        once, starting right after the snapshot."""
        kind = rec[0]
        if kind == "push":
            self._push(rec[1], rec[2], rec[3])
        elif kind == "pushN":
            for m in rec[2]:
                self._push(rec[1], m, rec[3])
        elif kind == "pullN":
            q = self.queues.get(rec[1])
            flags = self._flags.get(rec[1])
            for tag in rec[2]:
                if not q:
                    break
                m = q.popleft()
                self.inflight[tag] = (rec[1], m, 0.0, flags.popleft())
                self._inflight_count[rec[1]] += 1
                self._trace_pull(m)
        elif kind == "ack":
            self._ack_one(rec[1])
        elif kind == "nack":
            self._nack_one(rec[1], rec[2])
        elif kind == "exp":
            irec = self.inflight.pop(rec[1], None)
            if irec is not None:
                self._requeue(irec[0], irec[1], self.requeue_front,
                              redelivered=True)
        elif kind == "epoch":
            self._epoch = max(self._epoch, rec[1])

    def recover(self) -> None:
        """Rebuild from snapshot + WAL replay, then (1) requeue every
        recovered in-flight message — its pre-crash lease died with the
        worker RPCs — (2) mark every surviving ready message redelivered, so
        workers dedup-probe against the taskdb before executing (an ack the
        crash swallowed means the message may already have run), and (3) bump
        and immediately persist the tag epoch so stale acks can never land."""
        dur = self._dur
        self._dur = None                 # replay must not re-log itself
        try:
            payload, records = dur.load(self._shard)
            if payload:
                self._epoch = payload["epoch"]
                self._tag_n = payload["tag_n"]
                for q, items in payload["queues"].items():
                    dq = self.queues.setdefault(q, deque())
                    fq = self._flags.setdefault(q, deque())
                    for msg, flag in items:
                        dq.append(msg)
                        fq.append(flag)
                for tag, q, msg, expires, flag in payload["inflight"]:
                    self.inflight[tag] = (q, msg, expires, flag)
                    self._inflight_count[q] += 1
            for rec in records:
                self._apply_replay(rec)
            self.stats["recovery_replayed"] += len(records)
            for tag in sorted(self.inflight):
                irec = self.inflight.pop(tag)
                self._requeue(irec[0], irec[1], False, redelivered=True)
                self.stats["recovered_inflight"] += 1
            self._expiry_heap = []
            for q, flags in self._flags.items():
                self._flags[q] = deque(True for _ in flags)
            self.recovered_task_keys = {
                (m["dag"], m["task"], m["try"])
                for dq in self.queues.values() for m in dq
                if isinstance(m, dict) and "dag" in m and "task" in m}
        finally:
            self._dur = dur
        self._epoch += 1
        self._tag_n = 0
        dur.append(self._shard, ("epoch", self._epoch))
        dur.commit(self._shard)          # epoch durable before any new lease
        self._depth_dirty = set(self.queues) | set(self._inflight_count)
        self._published = {}

    # ------------------------------------------------------- depth publication
    def changed_depths(self, families: FamilyFilter = None) -> Dict[str, dict]:
        """(ready, inflight) for queues whose counts moved since the last call
        — the sweep-cadence feed a publisher writes under ``/queues/<name>``.
        Queues whose dirty ops netted out to the last-published counts are
        skipped, keeping the watch stream quiet on steady state.

        ``families`` (a container or predicate of queue names) restricts the
        report to the families this shard OWNS: a sharded composer publishes
        each family exactly once, from its owning shard. Non-owned dirty
        queues stay dirty — an unfiltered call (or the owner) still sees
        them, nothing is silently un-flagged.
        """
        self._expire()
        out: Dict[str, dict] = {}
        skipped = []
        for q in sorted(self._depth_dirty):
            if not _family_match(families, q):
                skipped.append(q)
                continue
            cur = self._depth_of(q)
            if self._published.get(q) != cur:
                self._published[q] = cur
                out[q] = {"ready": cur[0], "inflight": cur[1]}
        self._depth_dirty.clear()
        self._depth_dirty.update(skipped)
        return out
