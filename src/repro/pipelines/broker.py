"""Message broker (the redis of paper §5) — named FIFO queues with lease-style
redelivery: a pulled message is invisible until acked or its lease expires
(worker died mid-task -> the task instance is redelivered, not lost).
"""
from __future__ import annotations

import itertools
from collections import deque
from typing import Deque, Dict, Tuple


class Broker:
    def __init__(self, clock_fn=None, lease: float = 30.0):
        self.queues: Dict[str, Deque[dict]] = {}
        self.inflight: Dict[int, Tuple[str, dict, float]] = {}
        self._tag = itertools.count(1)
        self.clock_fn = clock_fn or (lambda: 0.0)
        self.lease = lease

    def _expire(self) -> None:
        now = self.clock_fn()
        for tag, (q, msg, t) in list(self.inflight.items()):
            if now - t > self.lease:
                del self.inflight[tag]
                self.queues.setdefault(q, deque()).appendleft(msg)

    def handle(self, msg: dict) -> dict:
        op = msg.get("op")
        self._expire()
        if op == "push":
            self.queues.setdefault(msg["queue"], deque()).append(msg["msg"])
            return {"ok": True, "depth": len(self.queues[msg["queue"]])}
        if op == "pull":
            q = self.queues.get(msg["queue"])
            if not q:
                return {"ok": True, "msg": None}
            item = q.popleft()
            tag = next(self._tag)
            self.inflight[tag] = (msg["queue"], item, self.clock_fn())
            return {"ok": True, "msg": item, "tag": tag}
        if op == "ack":
            self.inflight.pop(msg.get("tag"), None)
            return {"ok": True}
        if op == "nack":
            rec = self.inflight.pop(msg.get("tag"), None)
            if rec:
                self.queues.setdefault(rec[0], deque()).appendleft(rec[1])
            return {"ok": True}
        if op == "depth":
            return {"ok": True,
                    "depth": len(self.queues.get(msg["queue"], ()))}
        return {"ok": False, "error": f"unknown op {op}"}
