"""Gradient/delta compression for the thin cross-pod (DCN) boundary.

int8 symmetric quantization with per-tensor scales and error feedback (EF): the
quantization residual is carried to the next sync so the compressed local-SGD
trainer stays unbiased over time. This is the quantitative realization of the
paper's "occasional, small cross-boundary traffic" claim — 4x fewer bytes than f32
(16x vs f32 grads when combined with H-step local sync amortization).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32 scalar)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(tree: dict, ef: dict):
    """Quantize every leaf with error feedback. Returns ((q, scales), new_ef)."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    ef_flat = jax.tree_util.tree_leaves(ef)
    qs, scales, new_ef = [], [], []
    for x, e in zip(flat, ef_flat):
        v = x.astype(jnp.float32) + e
        q, s = quantize_int8(v)
        qs.append(q)
        scales.append(s)
        new_ef.append(v - dequantize_int8(q, s))
    unflat = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
    return (unflat(qs), unflat(scales)), unflat(new_ef)


def decompress_tree(qs: dict, scales: dict) -> dict:
    return tmap(dequantize_int8, qs, scales)


def init_error_feedback(params: dict) -> dict:
    return tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_bytes(tree: dict) -> int:
    """Bytes on the wire for the int8-compressed tree (payload + scales)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(l.size for l in leaves) + 4 * len(leaves)
