"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    """Linear warmup then cosine decay to ``floor * peak_lr``."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return peak_lr * jnp.where(step < warmup_steps, warm, cos)
