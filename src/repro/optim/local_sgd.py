"""Titchener local-sync trainer (DiLoCo-style local SGD over the pod boundary).

The paper's core systems insight — *most traffic stays local; only small, occasional
control traffic crosses the cloud boundary* — becomes a distributed-optimization
mode: each pod runs H AdamW steps on its own parameter copy with gradient reduction
confined to in-pod axes, then pods exchange int8-compressed (error-feedback)
parameter deltas once per round. An outer Nesterov-SGD step applies the pod-mean
delta. Cross-pod (DCN) bytes drop by 4x (int8) x H (amortization) vs per-step
synchronous data parallelism.

Mechanics: every per-pod tree carries a leading ``n_pods`` dim sharded on the "pod"
mesh axis; the model loss is ``jax.vmap(..., spmd_axis_name="pod")``-mapped over it,
which keeps gradients pod-local (no cross-pod reduction is ever emitted inside the
inner loop). The only pod-axis collective in the round is the delta mean.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.compression import compress_tree, dequantize_int8

tmap = jax.tree_util.tree_map


def pod_free_plan(plan):
    """A MeshPlan whose rules never touch the "pod" axis — required for the model
    called under ``vmap(..., spmd_axis_name="pod")`` (the vmapped dim owns pod)."""
    from repro.parallel.sharding import DEFAULT_RULES, MeshPlan
    base = dict(plan.rules or DEFAULT_RULES)
    rules = {k: tuple(a for a in v if a != "pod") for k, v in base.items()}
    return MeshPlan(mesh=plan.mesh, fsdp=plan.fsdp, sp=plan.sp, rules=rules)


@dataclasses.dataclass(frozen=True)
class LocalSGDConfig:
    inner_steps: int = 4          # H: pod-local steps per sync round
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    nesterov: bool = True
    compress: bool = True         # int8 + error feedback on the pod-axis exchange


def init_local_sgd_state(params: dict, n_pods: int) -> dict:
    """params: unstacked bf16 tree. Builds pod-stacked working copies."""
    stack = lambda p: jnp.broadcast_to(p[None], (n_pods,) + p.shape)
    pod_params = tmap(stack, params)
    pod_opt = {
        "m": tmap(lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params),
        "v": tmap(lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params),
        "master": tmap(lambda p: stack(p).astype(jnp.float32), params),
        "step": jnp.zeros((n_pods,), jnp.int32),
    }
    return {
        "pod_params": pod_params,
        "pod_opt": pod_opt,
        "master": tmap(lambda p: p.astype(jnp.float32), params),
        "momentum": tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "ef": tmap(lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params),
        "round": jnp.zeros((), jnp.int32),
    }


def _compress_stacked(delta: dict, ef: dict):
    """Per-pod int8+EF compression of pod-stacked trees (leaves [P, ...])."""
    def one_pod(d, e):
        (q, s), ne = compress_tree(d, e)
        return q, s, ne

    return jax.vmap(one_pod)(delta, ef)


def make_round_fn(loss_fn, inner_cfg: AdamWConfig, cfg: LocalSGDConfig,
                  spmd_axis: str = "pod", mesh=None):
    """Build the jitted one-round function.

    loss_fn(params, batch) -> (loss, metrics) for ONE pod's (unstacked) params and
    batch; it must carry only pod-free sharding constraints (the caller passes a
    MeshPlan whose "batch" rule excludes the pod axis). ``spmd_axis=None`` runs the
    pod dimension as a plain vmap (CPU tests / meshes without a pod axis).

    round_fn(state, batches) with batch leaves [H, n_pods, ...] -> (state, metrics).
    """
    grad_one = jax.grad(lambda p, b: loss_fn(p, b)[0])
    pod_vmap = lambda f: jax.vmap(f, spmd_axis_name=spmd_axis)

    def inner_step(carry, batch_h):
        pod_params, pod_opt = carry
        grads = pod_vmap(grad_one)(pod_params, batch_h)

        def upd(p, g, m, v, master, step):
            st = {"m": m, "v": v, "master": master, "step": step}
            np_, ns, _ = adamw_update(p, g, st, inner_cfg)
            return np_, ns["m"], ns["v"], ns["master"], ns["step"]

        new_p, m, v, master, step = pod_vmap(upd)(
            pod_params, grads, pod_opt["m"], pod_opt["v"], pod_opt["master"],
            pod_opt["step"])
        return (new_p, {"m": m, "v": v, "master": master, "step": step}), None

    def round_fn(state: dict, batches: dict):
        (pod_params, pod_opt), _ = jax.lax.scan(
            inner_step, (state["pod_params"], state["pod_opt"]), batches,
            length=cfg.inner_steps)

        # pod delta (pseudo-gradient): start-of-round master minus local result
        delta = tmap(lambda g, loc: g[None] - loc, state["master"],
                     pod_opt["master"])                        # [P, ...]

        if cfg.compress:
            q, s, new_ef = _compress_stacked(delta, state["ef"])
            if mesh is not None and "pod" in getattr(mesh, "shape", {}):
                # Put int8 on the DCN wire: all-gather the quantized deltas
                # pod-replicated and dequantize+mean LOCALLY. Without this,
                # XLA dequantizes before the pod-mean all-reduce and the wire
                # carries f32 (measured: compressed == uncompressed DCN bytes;
                # EXPERIMENTS.md §Perf cell 2 iteration 3).
                from jax.sharding import NamedSharding, PartitionSpec as P

                def rep(t):
                    spec = P(None, *([P.UNCONSTRAINED] * (t.ndim - 1)))
                    return jax.lax.with_sharding_constraint(
                        t, NamedSharding(mesh, spec))

                q = tmap(rep, q)
                s = tmap(rep, s)
            mean_delta = tmap(
                lambda qq, ss: jnp.mean(
                    qq.astype(jnp.float32)
                    * ss.reshape((-1,) + (1,) * (qq.ndim - 1)), axis=0),
                q, s)
        else:
            new_ef = state["ef"]
            mean_delta = tmap(lambda d: jnp.mean(d, axis=0), delta)

        # outer Nesterov SGD on the pseudo-gradient
        mu, lr = cfg.outer_momentum, cfg.outer_lr
        momentum = tmap(lambda mo, d: mu * mo + d, state["momentum"], mean_delta)
        if cfg.nesterov:
            update = tmap(lambda mo, d: mu * mo + d, momentum, mean_delta)
        else:
            update = momentum
        master = tmap(lambda gm, u: gm - lr * u, state["master"], update)

        # re-broadcast the synced master into every pod's working copies
        n_pods = jax.tree_util.tree_leaves(pod_params)[0].shape[0]
        stack = lambda p: jnp.broadcast_to(p[None], (n_pods,) + p.shape)
        new_pod_params = tmap(lambda gm, wp: stack(gm.astype(wp.dtype)),
                              master, pod_params)
        new_pod_master = tmap(stack, master)
        pod_opt = dict(pod_opt, master=new_pod_master)

        new_state = {
            "pod_params": new_pod_params, "pod_opt": pod_opt, "master": master,
            "momentum": momentum, "ef": new_ef, "round": state["round"] + 1,
        }
        delta_norm = jnp.sqrt(sum(
            jnp.sum(jnp.square(d)) for d in jax.tree_util.tree_leaves(mean_delta)))
        return new_state, {"delta_norm": delta_norm}

    return round_fn


def dcn_bytes_per_round(params: dict, cfg: LocalSGDConfig) -> Tuple[int, int]:
    """(local_sgd_bytes, sync_dp_bytes_over_H_steps) crossing the pod boundary.

    Sync-DP all-reduces bf16 gradients every step (ring: ~2x payload); local SGD
    exchanges one int8 delta (+f32 scale/leaf) per H steps.
    """
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    n_leaves = len(jax.tree_util.tree_leaves(params))
    payload = n_params + 4 * n_leaves if cfg.compress else 4 * n_params
    sync_dp = cfg.inner_steps * 2 * n_params * 2   # H steps x ring 2x x bf16
    return 2 * payload, sync_dp
