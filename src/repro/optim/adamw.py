"""AdamW with f32 master weights and ZeRO-sharded optimizer state.

Params stay in the model dtype (bf16) and are regenerated from the f32 master copy
every step; m/v/master carry the param's logical axes but are laid out with the
OPT_RULES sharding (the FSDP dim additionally spread over the "pod" axis), so the
three f32 trees shard 512-way on the production mesh (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import is_def, param_defs
from repro.parallel.sharding import MeshPlan

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params: dict) -> dict:
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "m": tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "master": tmap(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(cfg: ArchConfig) -> dict:
    defs = param_defs(cfg)
    f32 = tmap(lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32), defs,
               is_leaf=is_def)
    return {"m": f32, "v": f32, "master": f32,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_state_specs(cfg: ArchConfig, plan: MeshPlan) -> dict:
    """PartitionSpecs for the optimizer state (ZeRO rules, pod-spread)."""
    defs = param_defs(cfg)
    spec = tmap(lambda d: plan.opt_spec(d.logical, d.shape), defs, is_leaf=is_def)
    from jax.sharding import PartitionSpec as P
    return {"m": spec, "v": spec, "master": spec, "step": P()}


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params: dict, grads: dict, state: dict, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    from repro.optim.schedules import warmup_cosine

    step = state["step"] + 1
    if lr is None:
        lr = warmup_cosine(step, peak_lr=cfg.peak_lr,
                           warmup_steps=cfg.warmup_steps,
                           total_steps=cfg.total_steps)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p_master, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_master = p_master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                      + cfg.weight_decay * p_master)
        return new_master, m, v

    flat_m, treedef = jax.tree_util.tree_flatten(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    flat_master = jax.tree_util.tree_leaves(state["master"])
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_p = jax.tree_util.tree_leaves(params)

    new_master, new_m, new_v, new_p = [], [], [], []
    for p, g, m, v, mast in zip(flat_p, flat_g, flat_m, flat_v, flat_master):
        nm_master, nm, nv = upd(mast, g, m, v)
        new_master.append(nm_master)
        new_m.append(nm)
        new_v.append(nv)
        new_p.append(nm_master.astype(p.dtype))

    unflat = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
    new_state = {"m": unflat(new_m), "v": unflat(new_v),
                 "master": unflat(new_master), "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return unflat(new_p), new_state, metrics
