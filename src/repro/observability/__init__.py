"""Observability layer for the hybrid management plane.

The paper's global management plane "radically simplifies managing big data
applications" only if it can *see* them: this package is the plane-wide
flight recorder. ``trace`` carries a ``TraceContext`` across fabric hops and
gateway relays so a task's lifecycle (submit → dispatch → schedule → queue →
execute → commit) reconstructs as one tree with a critical-path breakdown;
``metrics`` unifies every component's ad-hoc stats behind stable dotted
names and per-queue-family service-time histograms, exported over the PR 7
replica delta feed at zero cross-boundary read cost.
"""
from .metrics import Histogram, MetricsRegistry
from .trace import (TRACE_KEY, Span, TraceContext, Tracer, critical_path,
                    format_trace_report, trace_report)

__all__ = ["TRACE_KEY", "Span", "TraceContext", "Tracer", "critical_path",
           "trace_report", "format_trace_report", "Histogram",
           "MetricsRegistry"]
