"""Distributed tracing for the hybrid plane (the flight recorder's first half).

The paper's global management plane exists to answer management questions
about pipelines running across clusters; the most basic one — *where did this
task's latency go?* — needs a causally-linked record of every lifecycle stage
a task instance passes through. This module provides it:

  * ``TraceContext`` — the compact ``"trace_id|span_id"`` string that rides
    inside message payloads (broker task messages, dispatch envelopes) under
    the ``TRACE_KEY`` field. The fabric propagates it across gateway relays
    and channel hops (``Fabric.current_trace``), so a handler many hops from
    the sender can still parent its spans correctly. One flat string — not a
    nested pair — so the fabric's byte accounting prices it with a single
    memoized lookup instead of a container walk, and child spans store the
    parent context verbatim (no parsing on the record path). Trace ids must
    not contain ``"|"``.
  * ``Span`` — one timed segment on the simulated fabric clock, with a
    component label, a status, and free-form attrs (wall-clock facts like a
    train step's EMA ride in as attrs, so reports mix both).
  * ``Tracer`` — the shared span recorder plus the keyed-open map that lets a
    span OPEN in one component and CLOSE in another (a queue span opens at
    broker push and closes at pull; a task's root span opens at scheduling
    and closes when the scheduler observes the terminal taskdb row).

Hot-path design: a recorded span is ONE tuple in a flat event log, and the
API is shaped so batch sites never pay a Python call per span:

  * ``rec`` — the log's raw bound ``append``. The two hottest loops (the
    scheduler's flush of staged schedule spans, the worker's post-ack sweep
    recording execute/commit pairs) build event tuples in place and append
    them directly; ``bound()`` afterwards enforces the log cap. Leaf events
    carry ``sid None`` — nothing ever parents under them, so span ids are
    assigned lazily at read time instead of costing a counter bump each.
  * ``open_keyed_many`` / ``close_keyed_many`` — the broker opens one batch
    of queue-wait spans per ``push_many`` and closes one batch per
    ``pull_many``, one clock read and one call for the whole batch.
  * every record call takes optional ``t0``/``t1`` so remaining loops read
    the simulated clock ONCE (within one tick the readings are identical
    anyway); parent contexts are stored verbatim and parsed only when
    ``Span`` objects are materialized for a reader.

The first cut kept live per-span objects, per-span clock reads, and a
nested-list wire context, and cost 1.7x on a pure control-plane workload;
this layout is gated at <= 1.05x by ``benchmarks/observability.py``, cheap
enough to leave sampling on.

Honesty note: trace *context* genuinely crosses the fabric inside
byte-accounted envelopes — sampling on/off changes the wire bytes and the
benchmarks price it. The event log is a shared in-process object (the
simulated stand-in for each component reporting spans to a collector);
nothing reads another component's spans on any hot path.

Crash semantics (the part production tracers get wrong): spans owned by
master-hosted components (scheduler/broker) are TRUNCATED at recovery —
recorded with ``status="truncated"`` at the recovery clock — never leaked
open and never double-closed; a task's root span survives the crash and
still closes when the task eventually commits. The accounting identity

    stats["opened"] == stats["closed"] + stats["truncated"] + open_count

holds at every instant and is gated (with ``stats["double_close"] == 0``)
by ``benchmarks/observability.py`` across an injected crash-restart.

Sampling is deterministic, so two runs of the same workload sample the same
task sets: the scheduler (the head-of-trace decision point) traces every
``round(1/sample)``-th staged task — one int op on the unsampled hot path —
while id-keyed call sites (dispatcher jobs) use ``Tracer.sampled`` (crc32 of
the trace id). ``sample=0`` records nothing and — because instrumented
sites only attach ``TRACE_KEY`` to sampled messages — leaves every fabric
payload byte-identical to an uninstrumented plane.
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, List, Optional, Sequence

# the payload field a trace context rides under; absent => untraced message
# (repro.core.transport reads the same literal on its delivery fast path)
TRACE_KEY = "trace"

TraceContext = str  # "trace_id|span_id" — one flat string on the wire

#: The recommended production sampling rate: the overhead-control knob every
#: production tracer ships (Dapper samples 1/1024; we can afford far more
#: because recording is a tuple append). Deterministic sampling (stride at
#: the scheduler, crc32 for id-keyed sites) means the same tenth of the
#: task population is fully traced on every run.
#: ``benchmarks/observability.py`` gates the plane at this rate at <= 1.05x
#: an untraced plane on an instant-handler DAG — the harshest denominator,
#: pure control-plane work — and reports the full-sampling (``sample=1.0``,
#: what the tests pin for exact span accounting) ratio alongside it.
DEFAULT_SAMPLE = 0.1


class Span:
    """One timed segment of a trace, materialized from the event log on
    read. ``start``/``end`` are simulated fabric clock (deterministic, what
    the benchmarks gate); host-time facts arrive as attrs (``wall_s``,
    ``step_ema_s``)."""

    __slots__ = ("span_id", "trace_id", "parent_id", "name", "component",
                 "start", "end", "status", "attrs")

    def __init__(self, span_id, trace_id, parent_id, name, component,
                 start, end, status, attrs):
        self.span_id = span_id
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.name = name
        self.component = component
        self.start = start
        self.end = end
        self.status = status
        self.attrs = attrs

    def ctx(self) -> TraceContext:
        """The wire form children parent under: ``"trace_id|span_id"``."""
        return f"{self.trace_id}|{self.span_id}"

    @property
    def open(self) -> bool:
        return self.end is None

    def duration(self) -> float:
        return 0.0 if self.end is None else self.end - self.start

    def __repr__(self) -> str:                              # pragma: no cover
        return (f"Span({self.span_id}, {self.trace_id!r}, {self.name!r}, "
                f"{self.status!r})")


class Tracer:
    """Flat-event-log span recorder + keyed-open map + deterministic sampler.

    One ``Tracer`` serves a whole plane (master components, agents, workers
    share it — see the module docstring's honesty note). Recorded (closed)
    spans are tuples ``(sid, origin, name, component, start, end, status,
    attrs)`` in ``_log``, where ``origin`` is the PARENT's wire context
    string stored verbatim (or the bare trace id for roots) and ``sid`` is
    ``None`` for leaf events appended via ``rec`` (span ids for those are
    assigned lazily at read time — nothing parents under a leaf). Spans
    still open live in ``_pending`` (opened by context, e.g. dispatch legs)
    or ``_keyed`` (opened under a cross-component key, e.g. task roots and
    queue waits). The log is bounded: past ``max_events`` the oldest
    fully-closed traces are compacted away (events dropped, the accounting
    counters kept), so a long-running plane never grows without bound while
    open spans are never lost.
    """

    def __init__(self, clock_fn=None, sample: float = 1.0,
                 max_events: int = 200_000):
        self.clock = clock_fn or (lambda: 0.0)
        self.sample = float(sample)
        self.max_events = max_events
        # (sid_or_None, origin, name, component, t0, t1, status, attrs)
        self._log: List[tuple] = []
        #: raw event append — THE fast path. Batch sites build event tuples
        #: in place (sid ``None``), append through this bound method, then
        #: call ``bound()`` once per batch. Layout is the ``_log`` tuple.
        self.rec = self._log.append
        self._n = 0                  # sids allocated (ctx-opened + keyed)
        # sid -> (origin, name, component, t0, attrs)         [ctx-opened]
        self._pending: Dict[int, tuple] = {}
        # key -> (origin, name, component, t0, attrs, sid, ctx) [key-opened]
        self._keyed: Dict[tuple, tuple] = {}
        self._truncated = 0
        self._double = 0
        self._evicted = 0
        # compacted-away event counts, by event class
        self._dropped_leaf = 0
        self._dropped_closed = 0
        self._dropped_trunc = 0

    # ------------------------------------------------------------- sampling
    def sampled(self, trace_id: str) -> bool:
        """Deterministic per-trace sampling decision (crc32 of the id):
        identical across runs, processes, and components — every site that
        asks about the same task gets the same answer."""
        s = self.sample
        if s >= 1.0:
            return True
        if s <= 0.0:
            return False
        return (zlib.crc32(trace_id.encode()) % 100_000) < int(s * 100_000)

    # ------------------------------------------------------------- hot path
    def bound(self) -> None:
        """Enforce the log cap — batch sites call this once after a loop of
        raw ``rec`` appends (keyed/complete methods call it themselves)."""
        if len(self._log) >= self.max_events:
            self._compact()

    def span_complete(self, parent: str, name: str, component: str,
                      t0: float, status: str = "ok",
                      attrs: Optional[dict] = None,
                      t1: Optional[float] = None) -> None:
        """Record one finished leaf span — the caller captured ``t0``
        (``tracer.clock()``) before the work and knows the outcome after.
        The parent context string is stored verbatim, never parsed here.
        Loops hotter than one call per span use ``rec`` directly."""
        self.rec((None, parent, name, component, t0,
                  self.clock() if t1 is None else t1, status, attrs))
        if len(self._log) >= self.max_events:
            self._compact()

    def open_span(self, name: str, component: str,
                  parent: Optional[str] = None,
                  trace_id: Optional[str] = None,
                  attrs: Optional[dict] = None,
                  t0: Optional[float] = None) -> TraceContext:
        """Open a span whose close happens elsewhere (possibly in another
        component); returns the wire context children parent under."""
        if parent is not None:
            origin = parent
            tid = parent[:parent.rindex("|")]
        else:
            if trace_id is None:
                raise ValueError("root span needs an explicit trace_id")
            tid = origin = trace_id
        n = self._n + 1
        self._n = n
        self._pending[n] = (origin, name, component,
                            self.clock() if t0 is None else t0, attrs)
        return f"{tid}|{n}"

    def end_span(self, ctx: str, status: str = "ok",
                 attrs: Optional[dict] = None,
                 t1: Optional[float] = None) -> Optional[int]:
        """Close a span by its context (first close wins; a second close is
        counted in ``stats["double_close"]`` and records nothing)."""
        sid = int(ctx[ctx.rindex("|") + 1:])
        p = self._pending.pop(sid, None)
        if p is None:
            self._double += 1
            return None
        a = p[4]
        if attrs:
            a = {**(a or {}), **attrs}
        self.rec((sid, p[0], p[1], p[2], p[3],
                  self.clock() if t1 is None else t1, status, a))
        if len(self._log) >= self.max_events:
            self._compact()
        return sid

    # ------------------------------------------------- cross-component opens
    def open_keyed(self, key: tuple, name: str, component: str,
                   parent: Optional[str] = None,
                   trace_id: Optional[str] = None,
                   attrs: Optional[dict] = None,
                   t0: Optional[float] = None) -> TraceContext:
        """Open a span another component will close by ``key``. If an open
        span already holds the key its context is returned unchanged (a
        retry re-stage reuses the task's root instead of forking a
        duplicate)."""
        rec = self._keyed.get(key)
        if rec is not None:
            return rec[6]
        if parent is not None:
            origin = parent
            tid = parent[:parent.rindex("|")]
        else:
            if trace_id is None:
                raise ValueError("root span needs an explicit trace_id")
            tid = origin = trace_id
        n = self._n + 1
        self._n = n
        ctx = f"{tid}|{n}"
        self._keyed[key] = (origin, name, component,
                            self.clock() if t0 is None else t0,
                            attrs, n, ctx)
        return ctx

    def open_keyed_many(self, items: Sequence[tuple], name: str,
                        component: str, t0: float) -> None:
        """Batch ``open_keyed`` — one call and one clock reading for a whole
        broker push batch. ``items`` are ``(key, parent_ctx, attrs)``; keys
        already open are left untouched (requeue reuses the open span). No
        contexts are returned: queue-wait spans never go on the wire."""
        kd = self._keyed
        n = self._n
        for key, parent, attrs in items:
            if key in kd:
                continue
            n += 1
            kd[key] = (parent, name, component, t0, attrs, n, None)
        self._n = n

    def close_keyed(self, key: tuple, status: str = "ok",
                    attrs: Optional[dict] = None,
                    t1: Optional[float] = None) -> Optional[int]:
        """Close the span registered under ``key``; ``None`` (and no effect)
        when no open span holds it — a crash-truncated key, an unsampled
        task, or a stage that already closed it: all silently fine, which is
        what makes close sites safe to call unconditionally."""
        p = self._keyed.pop(key, None)
        if p is None:
            return None
        a = p[4]
        if attrs:
            a = {**(a or {}), **attrs}
        sid = p[5]
        self.rec((sid, p[0], p[1], p[2], p[3],
                  self.clock() if t1 is None else t1, status, a))
        if len(self._log) >= self.max_events:
            self._compact()
        return sid

    def close_keyed_many(self, keys: Sequence[tuple], t1: float,
                         status: str = "ok") -> None:
        """Batch ``close_keyed`` — one call for a whole broker pull batch;
        unknown keys are skipped (same contract as ``close_keyed``)."""
        kd = self._keyed
        rec = self.rec
        for key in keys:
            p = kd.pop(key, None)
            if p is not None:
                rec((p[5], p[0], p[1], p[2], p[3], t1, status, p[4]))
        if len(self._log) >= self.max_events:
            self._compact()

    def ctx_for(self, key: tuple) -> Optional[TraceContext]:
        """Wire context of the open span under ``key`` (crash recovery uses
        this to re-attach reseeded messages to their surviving root);
        ``None`` for unknown keys and for batch-opened spans, which carry no
        context by design."""
        p = self._keyed.get(key)
        return p[6] if p is not None else None

    # ------------------------------------------------------ crash truncation
    def truncate_open(self, components: Optional[Sequence[str]] = None
                      ) -> int:
        """Record every open span owned by ``components`` (all when
        ``None``) with ``status="truncated"`` at the current clock — the
        crash-recovery contract: a master-hosted component's open spans died
        with it, so they are cut cleanly at the recovery epoch instead of
        leaking open (or being double-closed by a post-recovery pull that
        re-walks the same message). Truncated keys are dropped so recovery
        re-opens fresh spans under the same keys."""
        comp = None if components is None else set(components)
        now = self.clock()
        n = 0
        for sid in sorted(self._pending):
            p = self._pending[sid]
            if comp is not None and p[2] not in comp:
                continue
            del self._pending[sid]
            self.rec((sid, p[0], p[1], p[2], p[3], now, "truncated", p[4]))
            self._truncated += 1
            n += 1
        for key in sorted(self._keyed, key=repr):
            p = self._keyed[key]
            if comp is not None and p[2] not in comp:
                continue
            del self._keyed[key]
            self.rec((p[5], p[0], p[1], p[2], p[3], now, "truncated", p[4]))
            self._truncated += 1
            n += 1
        if len(self._log) >= self.max_events:
            self._compact()
        return n

    # ----------------------------------------------------------- observation
    @property
    def open_count(self) -> int:
        return len(self._pending) + len(self._keyed)

    @property
    def stats(self) -> Dict[str, int]:
        """Accounting counters (also a metrics-registry source): every
        span — counter-allocated or leaf-recorded — is exactly one of
        closed, truncated, or open."""
        leaf_in_log = sum(1 for ev in self._log if ev[0] is None)
        trunc_in_log = self._truncated - self._dropped_trunc
        leaf = leaf_in_log + self._dropped_leaf
        sid_closed = (len(self._log) - leaf_in_log - trunc_in_log
                      + self._dropped_closed)
        return {"opened": self._n + leaf, "closed": leaf + sid_closed,
                "truncated": self._truncated, "double_close": self._double,
                "evicted_traces": self._evicted}

    def accounting_ok(self) -> bool:
        """The gated invariant: every opened span is exactly one of closed,
        truncated, or still open — nothing lost, nothing counted twice.
        (Leaf events are closed by construction, so the identity reduces to
        the counter-allocated spans.)"""
        s = self.stats
        return (s["opened"] == s["closed"] + s["truncated"] + self.open_count
                and self._double == 0)

    @staticmethod
    def _parse_origin(origin: str):
        """``origin`` -> ``(trace_id, parent_sid_or_None)`` — the only place
        wire contexts are ever parsed."""
        tid, sep, ps = origin.rpartition("|")
        if not sep:
            return origin, None            # bare trace id: a root
        return tid, int(ps)

    def _materialize(self) -> Dict[int, Span]:
        out: Dict[int, Span] = {}
        leaf_id = self._n               # read-time ids for sid-less leaves
        for ev in self._log:
            sid = ev[0]
            if sid is None:
                leaf_id += 1
                sid = leaf_id
            tid, psid = self._parse_origin(ev[1])
            out[sid] = Span(sid, tid, psid, ev[2], ev[3], ev[4],
                            ev[5], ev[6], dict(ev[7] or {}))
        for sid in sorted(self._pending):
            p = self._pending[sid]
            tid, psid = self._parse_origin(p[0])
            out[sid] = Span(sid, tid, psid, p[1], p[2], p[3], None,
                            "open", dict(p[4] or {}))
        for p in self._keyed.values():
            tid, psid = self._parse_origin(p[0])
            out[p[5]] = Span(p[5], tid, psid, p[1], p[2], p[3], None,
                             "open", dict(p[4] or {}))
        return out

    @property
    def spans(self) -> Dict[int, Span]:
        """Materialized ``{span_id: Span}`` view (closed + still-open)."""
        return self._materialize()

    def trace(self, trace_id: str) -> List[Span]:
        return [s for s in self._materialize().values()
                if s.trace_id == trace_id]

    def trace_ids(self) -> List[str]:
        seen = dict.fromkeys(
            ev[1].rpartition("|")[0] or ev[1] for ev in self._log)
        for p in self._pending.values():
            seen.setdefault(p[0].rpartition("|")[0] or p[0], None)
        for p in self._keyed.values():
            seen.setdefault(p[0].rpartition("|")[0] or p[0], None)
        return list(seen)

    # ------------------------------------------------------------ compaction
    def _compact(self) -> None:
        """Bound the log: drop events of traces that are fully closed and
        not among the newest half, keeping the accounting counters exact."""
        def tid_of(origin: str) -> str:
            return origin.rpartition("|")[0] or origin

        keep_tids = {tid_of(p[0]) for p in self._pending.values()}
        keep_tids.update(tid_of(p[0]) for p in self._keyed.values())
        keep_tids.update(tid_of(ev[1])
                         for ev in self._log[len(self._log) // 2:])
        kept: List[tuple] = []
        dropped_tids = set()
        for ev in self._log:
            tid = tid_of(ev[1])
            if tid in keep_tids:
                kept.append(ev)
            else:
                if ev[0] is None:
                    self._dropped_leaf += 1
                elif ev[6] == "truncated":
                    self._dropped_trunc += 1
                else:
                    self._dropped_closed += 1
                dropped_tids.add(tid)
        self._evicted += len(dropped_tids)
        self._log = kept
        self.rec = self._log.append


# ----------------------------------------------------- critical-path analysis
def critical_path(tracer: Tracer, trace_id: str) -> Optional[dict]:
    """Reconstruct one trace's tree and account its latency by segment.

    Returns ``{"trace_id", "total", "status", "segments", "dominant",
    "path", "spans"}`` where ``segments`` sums duration per span NAME across
    the tree (for a task trace: schedule / queue / execute / commit — the
    placement, queue-wait, execution, and commit segments), ``dominant`` is
    the largest, and ``path`` is the greedy longest-child walk from the
    root. Durations are simulated-clock; host-time facts (``wall_s``,
    ``step_ema_s``) live in each span's attrs.
    """
    spans = tracer.trace(trace_id)
    if not spans:
        return None
    by_id = {s.span_id: s for s in spans}
    children: Dict[Optional[int], List[Span]] = {}
    roots: List[Span] = []
    for s in spans:
        if s.parent_id in by_id:
            children.setdefault(s.parent_id, []).append(s)
        else:
            roots.append(s)
    root = min(roots, key=lambda s: (s.start, s.span_id))
    segments: Dict[str, float] = {}
    for s in spans:
        if s is root:
            continue
        segments[s.name] = segments.get(s.name, 0.0) + s.duration()
    dominant = max(segments, key=segments.get) if segments else root.name
    path, node = [root.name], root
    while True:
        kids = children.get(node.span_id)
        if not kids:
            break
        node = max(kids, key=lambda s: (s.duration(), s.span_id))
        path.append(node.name)
    return {"trace_id": trace_id, "total": root.duration(),
            "status": root.status, "segments": segments,
            "dominant": dominant, "path": path, "spans": len(spans)}


def trace_report(tracer: Tracer, top_n: int = 10) -> List[dict]:
    """The top-N slowest completed traces (by simulated root duration), each
    with its critical-path breakdown — what ``make trace-report`` renders."""
    roots = [s for s in tracer.spans.values()
             if s.parent_id is None and s.end is not None]
    roots.sort(key=lambda s: (-s.duration(), s.trace_id))
    seen: set = set()
    out = []
    for s in roots:
        if s.trace_id in seen:
            continue
        seen.add(s.trace_id)
        cp = critical_path(tracer, s.trace_id)
        if cp is not None:
            out.append(cp)
        if len(out) >= top_n:
            break
    return out


def format_trace_report(tracer: Tracer, top_n: int = 10) -> str:
    rows = trace_report(tracer, top_n=top_n)
    if not rows:
        return "no completed traces"
    width = max(len(r["trace_id"]) for r in rows)
    lines = [f"{'trace':<{width}}  {'clock':>8}  {'dominant':<10}  segments",
             "-" * (width + 60)]
    for r in rows:
        segs = "  ".join(f"{n}={d:g}" for n, d in sorted(
            r["segments"].items(), key=lambda kv: -kv[1]))
        lines.append(f"{r['trace_id']:<{width}}  {r['total']:>8g}  "
                     f"{r['dominant']:<10}  {segs}")
    return "\n".join(lines)
