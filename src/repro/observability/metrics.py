"""Unified metrics for the hybrid plane (the flight recorder's second half).

Every component in the repo grew its own ad-hoc ``stats`` Counter/dict —
fabric byte ledgers, broker op counts, overwatch shard ops, replica watch
counters, autoscaler events, step-cache hit rates. They stay (cheap, and
tests read them), but management questions need one namespace and one
export path. ``MetricsRegistry`` provides both:

  * **Push primitives** — ``inc`` (counter), ``set_gauge``, ``observe``
    (bounded-bucket histogram with p50/p99 summaries, the per-queue-family
    service-time instrument the predictive autoscaler needs).
  * **Pull sources** — ``register_source(prefix, fn)`` adopts an existing
    legacy stats dict at zero hot-path cost: ``fn`` is only called at
    snapshot time, so components keep mutating their own Counters exactly
    as before and the registry reads them when someone asks.
  * **Stable dotted names** — ``snapshot()`` flattens everything to
    ``"broker.compute.ops.pushN"``-style keys; ``sections()`` groups by the
    first segment, which is the unit of export: each agent publishes one
    overwatch key ``/metrics/<cluster>/<section>`` per *changed* section
    per heartbeat, and those keys ride the PR 7 one-envelope-per-sweep
    replica delta feed — fleet-wide scrape via ``range_stale("/metrics/")``
    costs zero cross-boundary bytes (the paper's management plane monitors
    every cluster without a per-scrape RPC storm).

Histogram buckets are log-spaced over [1e-6 s, 1e3 s] (fixed count, so a
histogram's memory is bounded regardless of sample count); quantiles are
bucket-upper-edge estimates clamped to the observed [min, max].
"""
from __future__ import annotations

from collections import Counter
from typing import Callable, Dict, List, Optional

# log-spaced bucket upper bounds: 1e-6 .. 1e3 seconds, 4 buckets per decade
_BOUNDS: List[float] = []
for _exp in range(-6, 3):
    for _frac in (1.0, 1.8, 3.2, 5.6):
        _BOUNDS.append(_frac * (10.0 ** _exp))
_BOUNDS.append(10.0 ** 3)


class Histogram:
    """Bounded-bucket histogram: O(len(_BOUNDS)) memory forever, O(log n)
    per observe, p50/p99 from bucket edges (exact min/max kept)."""

    __slots__ = ("counts", "count", "total", "vmin", "vmax")

    def __init__(self):
        self.counts = [0] * (len(_BOUNDS) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def observe(self, value: float) -> None:
        lo, hi = 0, len(_BOUNDS)
        while lo < hi:
            mid = (lo + hi) // 2
            if value <= _BOUNDS[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.count += 1
        self.total += value
        if self.vmin is None or value < self.vmin:
            self.vmin = value
        if self.vmax is None or value > self.vmax:
            self.vmax = value

    def quantile(self, q: float) -> Optional[float]:
        if not self.count:
            return None
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                edge = _BOUNDS[i] if i < len(_BOUNDS) else self.vmax
                return min(max(edge, self.vmin), self.vmax)
        return self.vmax

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "sum": self.total,
                "mean": self.total / self.count,
                "min": self.vmin, "max": self.vmax,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99)}


class MetricsRegistry:
    """One cluster-local namespace over counters, gauges, histograms, and
    adopted legacy stats dicts. See the module docstring for the naming and
    export contract."""

    def __init__(self, cluster: str = ""):
        self.cluster = cluster
        self.counters: Counter = Counter()
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}
        self._sources: Dict[str, Callable[[], dict]] = {}
        self.source_errors: Counter = Counter()

    # ----------------------------------------------------------- push side
    def inc(self, name: str, value: float = 1) -> None:
        self.counters[name] += value

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        h.observe(value)

    # ----------------------------------------------------------- pull side
    def register_source(self, prefix: str, fn: Callable[[], dict]) -> None:
        """Adopt a legacy stats dict: ``fn()`` is called at snapshot time
        and its flat numeric dict lands under ``<prefix>.<key>``. Re-using a
        prefix replaces the source (recovery re-registers freely)."""
        self._sources[prefix] = fn

    # --------------------------------------------------------------- export
    def snapshot(self) -> Dict[str, float]:
        """Flat ``dotted.name -> number`` view of everything, pulled fresh.
        A failing source is skipped and counted (a half-constructed
        component during recovery must not take the whole scrape down)."""
        out: Dict[str, float] = {}
        out.update(self.counters)
        out.update(self.gauges)
        for name, h in self.histograms.items():
            for k, v in h.summary().items():
                out[f"{name}.{k}"] = v
        for prefix, fn in self._sources.items():
            try:
                vals = fn()
            except Exception:
                self.source_errors[prefix] += 1
                continue
            for k, v in vals.items():
                out[f"{prefix}.{k}"] = v
        return out

    def sections(self) -> Dict[str, Dict[str, float]]:
        """``snapshot()`` grouped by first dotted segment — the unit an
        agent publishes (one overwatch key per changed section). Fresh
        dicts every call, so callers may keep them for ==-comparison."""
        out: Dict[str, Dict[str, float]] = {}
        for name, v in self.snapshot().items():
            section, _, rest = name.partition(".")
            out.setdefault(section, {})[rest or section] = v
        return out
