"""Access-control layer (Algorithm 3 support).

The enforcement point is ``transport.AclTable`` (checked by the fabric at send
time, default-deny). This module adds the policy-level helpers used by tests and
the plane: compute the exact allowed flow set implied by an AppSpec, and audit a
cluster's installed table against it.
"""
from __future__ import annotations

from typing import List, Set, Tuple

from repro.core import gateways as GW
from repro.core.service_graph import AppSpec
from repro.core.transport import AclTable  # noqa: F401  (re-export)


def expected_flows(spec: AppSpec, state: "GW.GatewayState") -> Set[tuple]:
    """The set of (pod, dialed_addr) pairs Algorithm 3 must allow in a cluster."""
    out = set()
    for s in sorted(x.name for x in spec.services):
        svc = spec.service(s)
        rank = GW.service_rank(spec, s)
        external = spec.host_cluster(s) != state.cluster
        dialed = ((state.dummy_ip(rank), svc.port) if external
                  else (state.service_ip(rank), svc.port))
        for pod in spec.pods_needing(s):
            if spec.partition[pod] == state.cluster:
                out.add((pod, dialed))
                if external:
                    out.add((pod, (state.egw_ip, GW.EPORT_BASE + rank)))
    return out


def audit(spec: AppSpec, state: "GW.GatewayState") -> List[str]:
    """Violations between the installed ACL and the spec-implied flow set."""
    want = expected_flows(spec, state)
    have = state.acl.entries()
    missing = want - have
    extra = have - want
    problems = [f"missing allow: {m}" for m in sorted(missing)]
    problems += [f"unexpected allow: {e}" for e in sorted(extra)]
    return problems
