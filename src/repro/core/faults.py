"""Deterministic fault injection: scripted crash/partition plans + the
crash-restart harness that drives them.

Crash model (what a "master crash" means here)
    The GLOBAL plane's services die: overwatch shards, lease table,
    dispatcher, replica shipper, brokers, taskdb, scheduler, autoscaler.
    Everything cluster-local survives — control agents, workers mid-lease,
    local replicas, gateway state — exactly the paper's split: local planes
    keep their state through master loss and resync after it returns. A crash
    additionally drops every ``LogStore``'s uncommitted tail
    (``lose_uncommitted()``) and partitions the master cluster so the outage
    window is visible to heartbeats; restart heals the partition and rebuilds
    every service from WAL + snapshots (``ManagementPlane.
    recover_global_plane()`` then ``HybridComposer.recover()``).

``CrashError`` deliberately subclasses ``BaseException``: production code
catches ``Exception``/``RuntimeError``/``DeliveryError`` in several retry
paths, and an injected crash must never be swallowed by any of them — only
the harness catches it.

Scripting a ``FaultPlan``
    A plan is an ordered list of ``FaultPoint``s, consumed head-first; each
    fires once when its trigger is reached and the next becomes active.
    Triggers (first match wins, all counted deterministically):

      * ``at_op=N``       — the Nth fabric delivery on the master cluster
                            (every service RPC and recovery replay counts, so
                            a second point can land mid-recovery-storm);
      * ``op_kind="x", hit=K`` — just before the Kth master delivery whose
                            payload ``op`` field equals ``x`` (e.g. crash
                            between a worker's ``pull_many`` and its
                            ``upsert_many`` by arming ``op_kind=
                            "upsert_many"``);
      * ``site="commit:taskdb", hit=K`` — the Kth time that LogStore
                            commit/snapshot boundary is reached, *before* it
                            persists (crash-mid-sweep with the tail still
                            volatile);
      * ``site="migrate:<shard>:<step>"`` — the Kth time the shard-map
                            coordinator reaches that live-migration step
                            (``freeze``/``transfer``/``flip``/``replay``),
                            fired BEFORE the step executes — the seam for
                            killing a master or splitting the fabric at every
                            protocol boundary.

    Actions: ``crash`` (default — raise ``CrashError``), ``partition`` /
    ``heal`` (flip ``cluster``'s connectivity, for partition-then-crash
    scripts), ``kill_master`` (crash ONE master fault domain via the
    injector's ``kill_master_fn`` hook — ``cluster`` names the master, e.g.
    ``"m1"``; the multi-master plane keeps serving on the survivors instead
    of dying wholesale). ``FaultPlan.seeded(seed, crashes=k)`` derives a
    reproducible crash-only schedule from one integer — the chaos matrix is
    a list of seeds.

Example::

    plan = FaultPlan([
        FaultPoint(action="partition", cluster="cloud-a", at_op=300),
        FaultPoint(at_op=500),                      # crash master
        FaultPoint(action="heal", cluster="cloud-a", at_op=900),
    ])
    harness = ChaosHarness(plane, composer, plan)
    assert harness.run(until=lambda: scheduler.dag_done("etl"))

The harness ticks the pipeline, catches each ``CrashError``, models the loss
(uncommitted WAL tails dropped, master partitioned), restarts the plane, and
keeps going until ``until()`` holds; ``harness.recoveries`` records per-crash
replay/reseed/wall-time metrics for the durability benchmark.
"""
from __future__ import annotations

import dataclasses
import random
import time
from collections import Counter
from typing import Any, Callable, List, Optional


class CrashError(BaseException):
    """Injected process death. A BaseException so no service-level
    ``except Exception`` retry path can accidentally survive it."""


@dataclasses.dataclass
class FaultPoint:
    at_op: Optional[int] = None        # fire at the Nth master delivery
    op_kind: Optional[str] = None      # ...or before the Kth <op_kind> RPC
    site: Optional[str] = None         # ...or at a "commit:<shard>" boundary
    hit: int = 1                       # which occurrence (op_kind/site)
    action: str = "crash"              # crash | partition | heal | kill_master
    cluster: Optional[str] = None      # target for partition/heal/kill_master

    def describe(self) -> str:
        trig = (f"op>={self.at_op}" if self.at_op is not None else
                f"{self.op_kind or self.site}#{self.hit}")
        tgt = f" {self.cluster}" if self.cluster else ""
        return f"{self.action}{tgt}@{trig}"


class FaultPlan:
    """Ordered, single-shot fault schedule (head point is the armed one)."""

    def __init__(self, points: List[FaultPoint]):
        self.points: List[FaultPoint] = list(points)

    @classmethod
    def crash_at_ops(cls, *ops: int) -> "FaultPlan":
        return cls([FaultPoint(at_op=n) for n in sorted(ops)])

    @classmethod
    def crash_at_site(cls, site: str, hit: int = 1) -> "FaultPlan":
        return cls([FaultPoint(site=site, hit=hit)])

    @classmethod
    def seeded(cls, seed: int, crashes: int = 3, first: int = 200,
               span: int = 900) -> "FaultPlan":
        """Reproducible crash-only schedule: ``crashes`` points, the first in
        ``[first, first+span)``, each subsequent one a further ``[span/4,
        span)`` ops out — far enough apart to let recovery finish, close
        enough to hit different pipeline phases across seeds."""
        rng = random.Random(seed)
        ops, at = [], 0
        for i in range(crashes):
            lo = first if i == 0 else max(span // 4, 1)
            at += lo + rng.randrange(max(span - lo, 1))
            ops.append(at)
        return cls.crash_at_ops(*ops)


class FaultInjector:
    """Counts deterministic event streams and fires the plan's head point.

    Wired into two seams: ``fabric.on_deliver`` (every handler invocation on
    any cluster — only master-cluster deliveries advance the op counters) and
    ``LogStore.fault_hook`` (commit/snapshot boundaries). Both survive
    service rebuilds, so recovery traffic is counted too.
    """

    def __init__(self, plan: FaultPlan, fabric, master: str):
        self.plan = plan
        self.fabric = fabric
        self.master = master
        self.ops = 0                         # master-cluster deliveries
        self.op_kind_hits: Counter = Counter()
        self.site_hits: Counter = Counter()
        self.fired: List[tuple] = []
        # multi-master hook: set to ``plane.kill_master`` (or the
        # coordinator's) so ``action="kill_master"`` points can crash one
        # fault domain instead of the whole global plane
        self.kill_master_fn: Optional[Callable[[str], Any]] = None

    # ------------------------------------------------------------------ seams
    def on_deliver(self, cluster: str, addr, payload) -> None:
        if cluster != self.master:
            return
        self.ops += 1
        kind = payload.get("op") if isinstance(payload, dict) else None
        if kind:
            self.op_kind_hits[kind] += 1
        self._maybe_fire()

    def on_site(self, kind: str, shard: str) -> None:
        self.site_hits[f"{kind}:{shard}"] += 1
        self._maybe_fire()

    # ------------------------------------------------------------------ firing
    def _due(self, p: FaultPoint) -> bool:
        if p.at_op is not None:
            return self.ops >= p.at_op
        if p.op_kind is not None:
            return self.op_kind_hits[p.op_kind] >= p.hit
        if p.site is not None:
            return self.site_hits[p.site] >= p.hit
        return False

    def _maybe_fire(self) -> None:
        while self.plan.points and self._due(self.plan.points[0]):
            p = self.plan.points.pop(0)
            self.fired.append((p.describe(), self.ops))
            if p.action == "partition":
                self.fabric.partition_cluster(p.cluster)
            elif p.action == "heal":
                self.fabric.heal_cluster(p.cluster)
            elif p.action == "kill_master":
                if self.kill_master_fn is None:
                    raise CrashError(
                        f"injected {p.describe()} (no kill_master_fn wired)")
                self.kill_master_fn(p.cluster)
            else:
                raise CrashError(f"injected {p.describe()}")


class ChaosHarness:
    """Tick loop with scripted kill/restart of the global plane.

    ``plane`` must be durability-enabled (``ManagementPlane(durability=...)``)
    and ``composer`` (optional — control-plane-only scripts omit it) built
    over the same or its own ``LogStore``. ``downtime_ticks`` advances the
    fabric clock while the master is dead, so leases age and heartbeats miss
    realistically before recovery begins.
    """

    def __init__(self, plane, composer=None, plan: Optional[FaultPlan] = None,
                 downtime_ticks: int = 0):
        self.plane = plane
        self.composer = composer
        self.downtime_ticks = downtime_ticks
        self.injector = FaultInjector(plan or FaultPlan([]), plane.fabric,
                                      plane.master)
        plane.fabric.on_deliver = self.injector.on_deliver
        stores = [plane.durability]
        if composer is not None and composer.durability is not None \
                and composer.durability is not plane.durability:
            stores.append(composer.durability)
        self.logstores = [s for s in stores if s is not None]
        for s in self.logstores:
            s.fault_hook = self.injector.on_site
        co = getattr(plane, "coordinator", None)
        if co is not None:
            # multi-master plane: migration protocol steps become fault
            # sites, and kill_master points crash single fault domains
            co.fault_injector = self.injector
            self.injector.kill_master_fn = plane.kill_master
        self.crashed = False
        self.crashes = 0
        self.events: List[dict] = []
        self.recoveries: List[dict] = []

    # --------------------------------------------------------------- tick loop
    def run(self, until: Callable[[], Any], max_ticks: int = 10_000) -> bool:
        """Tick until ``until()`` holds, crash-restarting as the plan fires.
        Returns False if ``max_ticks`` elapse first."""
        ticks = 0
        while ticks < max_ticks:
            try:
                if self.crashed:
                    self.restart()
                self.tick()
                ticks += 1
                if until():
                    return True
            except CrashError:
                self.on_crash()
        return False

    def tick(self) -> None:
        if self.composer is not None:
            self.composer.tick()
        else:
            self.plane.tick()

    # ----------------------------------------------------------- crash/restart
    def on_crash(self) -> None:
        """Model the death: uncommitted WAL tails evaporate, the master
        cluster drops off the fabric."""
        self.crashes += 1
        lost = sum(s.lose_uncommitted() for s in self.logstores)
        self.plane.fabric.partition_cluster(self.plane.master)
        self.crashed = True
        self.events.append({"event": "crash", "n": self.crashes,
                            "at_op": self.injector.ops,
                            "lost_records": lost})

    def restart(self) -> None:
        """Heal + rebuild every global-plane service from WAL/snapshots. A
        ``CrashError`` fired mid-restart (a mid-recovery-storm point)
        propagates to ``run()``, which crashes and restarts again — recovery
        itself is restartable."""
        for _ in range(self.downtime_ticks):
            self.plane.fabric.tick(1.0)
        wal_len = sum(s.stats["committed"] for s in self.logstores)
        t0 = time.perf_counter()
        self.plane.recover_global_plane()
        rec = {"event": "recover", "after_crash": self.crashes,
               "wal_records": wal_len,
               "overwatch": dict(self.plane.overwatch.recovery_stats)}
        if self.composer is not None:
            self.composer.recover()
            rec["pipeline"] = dict(self.composer.recovery_stats)
        rec["wall_s"] = time.perf_counter() - t0
        pipe = rec.get("pipeline", {})
        rec["replayed"] = (rec["overwatch"].get("replayed", 0)
                           + pipe.get("taskdb_replayed", 0)
                           + pipe.get("broker_replayed", 0))
        self.crashed = False
        self.recoveries.append(rec)
        self.events.append(rec)
