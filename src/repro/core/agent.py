"""Control agent (paper §2.v + Algorithm 5): one per cluster/pod.

Responsibilities, mapped 1:1 from the paper:
  * configuration — run Algorithm 5 over a received AppSpec CRD: DNS entries,
    route reservation, access control for every service, then channels to the
    master (non-master clusters only);
  * job lifecycle — accept dispatched jobs, submit to the local control plane,
    track execution;
  * health/telemetry — lease-backed registration in the overwatch plus periodic
    heartbeats carrying load, job progress and step-rate telemetry.
  * replica hosting (the fan-out overhaul) — with ``enable_replica()`` the
    agent hosts a cluster-local ``LocalReplica`` fed by the master's shipped
    ``replica_batch`` envelopes; its overwatch client then serves
    ``range_stale`` reads (``fleet_telemetry``/``queue_depths`` below, worker
    depth gates, any telemetry consumer on this cluster) from local state —
    zero cross-boundary bytes per read while the ships keep it within bound.
  * cluster-local read service (the watch-plane overhaul) — the replica is
    also exposed as a service endpoint on ``REPLICA_PORT`` (``range_stale``
    + ``watch``/``watch_batch``) so worker pods, depth views, and autoscale
    observers on this cluster subscribe HERE instead of dialing the master:
    every watcher is fed from the one shipped envelope per sweep
    (``LocalReplica.watch``), so N watchers cost the cross-boundary bytes of
    zero. ``watch_local``/``local_view`` are the in-process fast path to the
    same plane; reads past the staleness bound transparently fall back to
    the primary (counted in ``fabric.stats["fallback_reads"]``).

The agent is an ordinary fabric endpoint: everything it says to the master-hosted
overwatch crosses the thin boundary and is byte-accounted. A partitioned cluster
stops heartbeating, its lease expires, and the dispatcher's failure detector sees
the tombstone — no extra machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core import gateways as GW
from repro.core.overwatch import OverwatchClient
from repro.core.service_graph import AppSpec
from repro.core.transport import Address, DeliveryError, Envelope, Fabric
from repro.observability.metrics import MetricsRegistry

AGENT_PORT = 6000
REPLICA_PORT = 6001           # the cluster-local read service (replica-fed)
AGENT_IP_SUFFIX = "0.20"
OW_TUNNEL_RANK = 9_999        # reserved gateway rank for the overwatch tunnel


@dataclasses.dataclass
class JobRecord:
    job: dict
    status: str = "accepted"     # accepted | running | done | failed
    progress: float = 0.0
    rate: float = 0.0


class ControlAgent:
    def __init__(self, fabric: Fabric, cluster: str, idx: int, master: str,
                 local_plane, heartbeat_interval: float = 1.0,
                 lease_ttl: float = 3.5, ow_shards: int = 1):
        self.fabric = fabric
        self.cluster = cluster
        self.idx = idx
        self.master = master
        self.local_plane = local_plane
        self.heartbeat_interval = heartbeat_interval
        self.lease_ttl = lease_ttl
        self.ow_shards = max(1, ow_shards)
        self.state = GW.GatewayState(cluster=cluster, idx=idx)
        self.spec: Optional[AppSpec] = None
        self.jobs: Dict[str, JobRecord] = {}
        self.lease: Optional[int] = None
        self.missed_heartbeats = 0
        self.agent_id = f"agent@{cluster}"
        self.addr: Address = (f"10.{idx}.{AGENT_IP_SUFFIX}", AGENT_PORT)
        fabric.register_handler(cluster, self.addr, self._handle)
        self.ow: Optional[OverwatchClient] = None
        self.replica = None                  # LocalReplica (fan-out mode)
        self.replica_addr: Optional[Address] = None   # read-service endpoint
        self._views: Dict[str, Any] = {}     # prefix -> cached ReplicaView
        # flight recorder: every agent owns its cluster's metrics registry
        # (components hosted here register sources on it); publication into
        # /metrics/<cluster>/ is OPT-IN via ``metrics_every`` — None keeps
        # the heartbeat byte-identical to the unmetered plane
        self.metrics = MetricsRegistry(cluster)
        self.metrics_every: Optional[float] = None
        self._metrics_published_at: Optional[float] = None
        self._published_metrics: Dict[str, dict] = {}
        # plane-shared tracer (set by ManagementPlane when tracing is on):
        # dispatch handling opens an "accept" span under the riding context
        self.tracer = None
        # telemetry envelope size is shape-constant (fixed keys, numeric
        # values): computed on the first heartbeat, reused forever after so
        # the fabric's byte accounting never re-walks the hottest message
        self._telemetry_nbytes: Optional[int] = None

    # -------------------------------------------------------------- bootstrapping
    def bootstrap(self, master_state: GW.GatewayState) -> None:
        """Initialization phase (paper §4.1): install the overwatch tunnel(s).

        Master-cluster agents talk to the overwatch directly; private agents
        get one bootstrap channel egw[i] -> igw[m] that forwards to the
        overwatch front-end. With a sharded overwatch, one additional tunnel
        per shard (ranks just below ``OW_TUNNEL_RANK``) lets the client route
        key ops straight to the owning shard's endpoint; the base tunnel keeps
        carrying lease traffic and fan-out ranges.
        """
        from repro.core.overwatch import OVERWATCH_IP, OVERWATCH_PORT
        n = self.ow_shards
        if self.cluster == self.master:
            shard_addrs = ([(OVERWATCH_IP, OVERWATCH_PORT + 1 + i)
                            for i in range(n)] if n > 1 else None)
            self.ow = OverwatchClient(self.fabric, self.cluster, self.agent_id,
                                      self.master, shard_addrs=shard_addrs,
                                      replica=self.replica)
            return
        eport = GW.EPORT_BASE + OW_TUNNEL_RANK
        iport = GW.IPORT_BASE + OW_TUNNEL_RANK
        self.fabric.add_forward(self.master, (master_state.igw_ip, iport),
                                (OVERWATCH_IP, OVERWATCH_PORT))
        self.fabric.create_channel(self.cluster, (self.state.egw_ip, eport),
                                   self.master, (master_state.igw_ip, iport))
        shard_vias = None
        if n > 1:
            shard_vias = []
            for i in range(n):
                rank = OW_TUNNEL_RANK - 1 - i
                s_eport = GW.EPORT_BASE + rank
                s_iport = GW.IPORT_BASE + rank
                self.fabric.add_forward(
                    self.master, (master_state.igw_ip, s_iport),
                    (OVERWATCH_IP, OVERWATCH_PORT + 1 + i))
                self.fabric.create_channel(
                    self.cluster, (self.state.egw_ip, s_eport),
                    self.master, (master_state.igw_ip, s_iport))
                shard_vias.append((self.state.egw_ip, s_eport))
        self.ow = OverwatchClient(self.fabric, self.cluster, self.agent_id,
                                  self.master, via=(self.state.egw_ip, eport),
                                  shard_vias=shard_vias,
                                  replica=self.replica)

    def enable_replica(self, prefixes=None):
        """Host a cluster-local overwatch replica (fan-out mode): shipped
        ``replica_batch`` deltas land here, and this agent's overwatch client
        serves in-bound ``range_stale`` reads from it without touching the
        fabric. Also registers the cluster-local read service on
        ``REPLICA_PORT`` so local pods consume the replica (reads + watches)
        as an ordinary service endpoint. Returns the replica (the shipper
        registers it master-side)."""
        from repro.core.replica import REPLICA_PREFIXES, LocalReplica
        self.replica = LocalReplica(prefixes or REPLICA_PREFIXES)
        self.metrics.register_source(
            "replica", lambda: dict(self.replica.stats))
        if self.ow is not None:
            self.ow.replica = self.replica
        self.replica_addr = (self.addr[0], REPLICA_PORT)
        self.fabric.register_handler(self.cluster, self.replica_addr,
                                     self._handle_replica_service)
        return self.replica

    # ------------------------------------------------- cluster-local read service
    def _handle_replica_service(self, msg: dict) -> dict:
        """The replica as a service endpoint for pods on THIS cluster: a
        ``range_stale`` answered from local state (primary fallback past the
        staleness bound, exactly like the in-process client path), and watch
        registration onto the replica-fed notify plane. Watch callbacks are
        in-process references — the simulated fabric's stand-in for a
        streaming subscription; what the byte ledger sees is the honest
        part: registering and feeding N watchers costs zero cross-boundary
        traffic."""
        op = msg.get("op")
        if op == "range_stale":
            items = self.ow.range_stale(msg["prefix"],
                                        msg.get("max_lag", 2.0))
            return {"ok": True, "items": items}
        if op in ("watch", "watch_batch"):
            try:
                self.watch_local(msg["prefix"], msg["cb"],
                                 batch=(op == "watch_batch"))
            except (RuntimeError, ValueError) as e:
                return {"ok": False, "error": str(e)}
            return {"ok": True}
        return {"ok": False, "error": f"unknown op {op}"}

    def watch_local(self, prefix: str, cb, batch: bool = False):
        """Subscribe to shipped deltas under ``prefix`` on this cluster's
        replica — the notify half of the local read service. Revision-ordered
        and coalesced exactly like the primary's watch buckets, fed from the
        one envelope per sweep: no per-watcher cross-boundary traffic."""
        if self.replica is None:
            raise RuntimeError(
                f"cluster {self.cluster} hosts no replica (fan-out off)")
        if batch:
            return self.replica.watch_batch(prefix, cb)
        return self.replica.watch(prefix, cb)

    def local_view(self, prefix: str):
        """A cached watch-materialized ``ReplicaView`` over ``prefix`` — the
        cluster-local twin of the dispatcher's master-side views (worker
        depth gates, fleet-state observers)."""
        if self.replica is None:
            raise RuntimeError(
                f"cluster {self.cluster} hosts no replica (fan-out off)")
        view = self._views.get(prefix)
        if view is None:
            from repro.core.replica import ReplicaView
            view = self._views[prefix] = ReplicaView(self.replica, prefix)
        return view

    def register(self) -> None:
        """Lease-backed registration (overwatch = discovery + failure detection)."""
        self.lease = self.ow.lease_grant(self.lease_ttl)
        self.ow.put(f"/clusters/{self.cluster}", {
            "idx": self.idx,
            "capabilities": self.local_plane.capabilities(),
            "agent_addr": list(self.addr),
        }, lease=self.lease)
        self._schedule_heartbeat()

    # ------------------------------------------------------------- Algorithm 5
    def configure_partition(self, spec: AppSpec,
                            master_state: GW.GatewayState) -> None:
        self.spec = spec
        svc_names = sorted(s.name for s in spec.services)
        for s in svc_names:
            GW.add_dns_entry(self.state, spec, s)
            GW.reserve_route(self.fabric, self.state, spec, s)
            GW.set_access_control(self.state, spec, s)
        if self.replica_addr is not None:
            # the cluster-local read service is default-deny like any other
            # service: rebuilt from scratch on every (re-)broadcast so only
            # the pods CURRENTLY partitioned onto this cluster may dial it
            self.state.acl.block_all(self.replica_addr)
            for pod, cl in spec.partition.items():
                if cl == self.cluster:
                    self.state.acl.allow(pod, self.replica_addr)
        GW.install_acl(self.fabric, self.state)
        if self.cluster != self.master:
            for s in svc_names:
                # iport[m, s] is estimated deterministically (sorted-rank ports)
                GW.create_channels(self.fabric, self.state, spec, s,
                                   self.master, master_state)

    # ------------------------------------------------------------- job lifecycle
    def _handle(self, msg: dict) -> dict:
        kind = msg.get("kind")
        if kind == "configure":
            self.configure_partition(msg["spec"], msg["master_state"])
            return {"ok": True}
        if kind == "dispatch":
            tr = self.tracer
            ctx = (self.fabric.current_trace() or msg.get("trace")) \
                if tr is not None else None
            if ctx is None:
                return self.accept_job(msg["job"])
            # the context rode the dispatch envelope across the relay hops;
            # the accept span records the remote-cluster half of submission
            t0 = tr.clock()
            try:
                resp = self.accept_job(msg["job"])
            except BaseException:
                tr.span_complete(ctx, "accept", "agent", t0, "failed",
                                 {"cluster": self.cluster})
                raise
            tr.span_complete(ctx, "accept", "agent", t0,
                             "ok" if resp.get("ok") else "failed",
                             {"cluster": self.cluster})
            return resp
        if kind == "cancel":
            return self.cancel_job(msg["job_id"])
        if kind == "retire":
            return self.retire_job(msg["job_id"])
        if kind == "drain":
            for jid in list(self.jobs):
                self.cancel_job(jid)
            return {"ok": True}
        if kind == "replica_batch":
            if self.replica is None:
                return {"ok": False, "error": "no replica hosted here"}
            applied = self.replica.apply_ship(msg["batch"])
            return {"ok": True, "applied_rev": applied}
        if kind == "replica_rev":
            # the recovering master's resume probe: how far this cluster's
            # replica had applied before the crash, so the rebuilt shipper can
            # resume the feed from that horizon instead of re-seeding
            rev = self.replica.applied_rev if self.replica is not None else 0
            return {"ok": True, "rev": rev}
        return {"ok": False, "error": f"unknown message {kind}"}

    def accept_job(self, job: dict) -> dict:
        """Job acceptance -> submission to the local control plane."""
        jid = job["job_id"]
        caps = set(self.local_plane.capabilities())
        needs = set(job.get("tags", {}).get("requires", ()))
        if not needs.issubset(caps):
            return {"ok": False, "error": f"missing capabilities {needs - caps}"}
        rec = JobRecord(job=job)
        self.jobs[jid] = rec
        try:
            self.local_plane.submit(job)
            rec.status = "running"
        except Exception as e:               # noqa: BLE001
            rec.status = "failed"
            return {"ok": False, "error": str(e)}
        self._report_job(jid)
        return {"ok": True}

    def cancel_job(self, job_id: str) -> dict:
        if job_id in self.jobs:
            self.local_plane.cancel(job_id)
            self.jobs[job_id].status = "failed"
        return {"ok": True}

    def retire_job(self, job_id: str) -> dict:
        """Graceful retirement (autoscaler scale-down): stop the job on the
        local plane and FORGET it — no failure recorded, no more heartbeat
        telemetry for it. The dispatcher tombstones the job's overwatch
        records in the same breath, so nothing anywhere still believes the
        pod exists."""
        rec = self.jobs.pop(job_id, None)
        if rec is not None:
            self.local_plane.cancel(job_id)
            rec.status = "done"
        return {"ok": True}

    # ------------------------------------------------------- heartbeat/telemetry
    def _schedule_heartbeat(self) -> None:
        self.fabric.call_later(self.heartbeat_interval, self.heartbeat)

    def heartbeat(self) -> None:
        try:
            self.ow.lease_keepalive(self.lease)
            # advance + track local jobs, then push telemetry
            for jid, rec in self.jobs.items():
                if rec.status != "running":
                    continue
                st = self.local_plane.poll(jid)
                rec.progress, rec.rate = st["progress"], st.get("rate", 0.0)
                if st["status"] in ("done", "failed"):
                    rec.status = st["status"]
                self._report_job(jid)
            req = Envelope({
                "op": "put", "key": f"/telemetry/{self.cluster}",
                "value": {
                    "clock": self.fabric.clock,
                    "load": self.local_plane.load(),
                    "running": sum(1 for r in self.jobs.values()
                                   if r.status == "running"),
                }, "lease": None,
            }, nbytes=self._telemetry_nbytes)
            self.ow.request(req)
            self._telemetry_nbytes = req.nbytes
            self.publish_metrics()
            self.missed_heartbeats = 0
        except (DeliveryError, RuntimeError):
            self.missed_heartbeats += 1
        self._schedule_heartbeat()

    def publish_metrics(self) -> None:
        """Export this cluster's metrics registry into the overwatch under
        ``/metrics/<cluster>/<section>`` — one put per CHANGED section, at
        most every ``metrics_every`` clock units (no-op when unset). The keys
        join the replica delta feed ("/metrics/" is a replicated prefix), so
        a fleet-wide scrape is a ``range_stale("/metrics/")`` against any
        replica: zero cross-boundary bytes per read. The publish itself rides
        this agent's existing overwatch tunnel and is priced like any put.
        The last-published cache updates only after a put LANDS — a
        partition-eaten publish retries on the next cadence."""
        if self.metrics_every is None or self.ow is None:
            return
        now = self.fabric.clock
        if (self._metrics_published_at is not None
                and now - self._metrics_published_at < self.metrics_every):
            return
        self._metrics_published_at = now
        for section, values in sorted(self.metrics.sections().items()):
            if self._published_metrics.get(section) == values:
                continue                     # unchanged: nothing to ship
            self.ow.put(f"/metrics/{self.cluster}/{section}", values)
            self._published_metrics[section] = values

    # ------------------------------------------------------ local-path reads
    def fleet_telemetry(self, max_lag: float = 2.0) -> Dict[str, dict]:
        """Every cluster's last telemetry row — served from the local replica
        when fan-out keeps it within ``max_lag``, primary round-trip
        otherwise (the remote telemetry probe of the locality benchmark)."""
        items = self.ow.range_stale("/telemetry/", max_lag=max_lag)
        return {k[len("/telemetry/"):]: v for k, v in items.items()}

    def queue_depths(self, max_lag: float = 2.0) -> Dict[str, dict]:
        """Published ``/queues/<name>`` depth view — the worker-side depth
        check, local under fan-out like ``fleet_telemetry``."""
        items = self.ow.range_stale("/queues/", max_lag=max_lag)
        return {k[len("/queues/"):]: v for k, v in items.items()}

    def fleet_states(self, max_lag: float = 2.0) -> Dict[str, dict]:
        """Published ``/autoscale/<family>`` fleet state — the remote
        autoscale observer's read surface, local under fan-out; pair with
        ``watch_local("/autoscale/", cb)`` for the notify side."""
        items = self.ow.range_stale("/autoscale/", max_lag=max_lag)
        return {k[len("/autoscale/"):]: v for k, v in items.items()}

    def _report_job(self, jid: str) -> None:
        rec = self.jobs[jid]
        self.ow.put(f"/jobs/{jid}/status", {
            "cluster": self.cluster, "status": rec.status,
            "progress": rec.progress, "rate": rec.rate,
            "clock": self.fabric.clock,
        })
