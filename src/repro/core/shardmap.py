"""Multi-master global plane: epoch-numbered shard map + live migration.

The paper's global plane is "highly available [and] public cloud hosted";
until now every overwatch shard and broker shard lived inside ONE simulated
master process, so a single crash site covered the whole global plane. This
module splits the plane into N independently crashable **master fault
domains** (``MasterNode``) over the existing fabric and coordinates shard
ownership through an **epoch-numbered shard map**:

  * ``MasterNode`` — a fault domain, not a scheduler: the shard OBJECTS stay
    where they are (this is a single-process simulation), but every fabric
    endpoint a master owns is registered through its ``guard`` wrapper, so
    crashing the node makes exactly its shards unreachable
    (``DeliveryError``) while the survivors keep serving. The front-end
    services the paper calls cloud-managed — the overwatch revision clock,
    lease table, watch delivery, taskdb, the coordinator itself — stay HA
    (they model Spanner/CloudSQL, not a master process).
  * ``ShardMap`` — ``epoch`` + ``shard name -> master name``. Shard
    ADDRESSES never change (clients derive routing from the consistent-hash
    ring alone); the map records which fault domain answers at each address,
    and the epoch fences writers: a request stamped with an old epoch bounces
    with ``{"stale_epoch": True, "epoch": <current>}`` and the client
    refreshes + retries (bounded) instead of double-applying against a moved
    shard. Every flip is WAL'd to the ``shardmap`` durability shard, so a
    whole-plane crash recovers the map (epoch included) before any client
    retry can land.
  * ``ShardMapCoordinator`` — drives **live migration** as a four-step
    protocol advanced ONE step per plane tick (so the freeze window spans
    real ticks and is measurable):

      freeze     writes to the shard bounce with a stale-epoch hint; reads
                 keep serving (the shard is a replica of itself until flip)
      transfer   commit the shard's WAL tail, export its snapshot payload,
                 and persist that exact payload as the durable snapshot —
                 the transferred state and the WAL can never diverge
      flip       epoch++, assignment updated, the flip WAL'd + committed,
                 the endpoint re-guarded under the target master
      replay     the target imports the payload (live) or rebuilds from
                 WAL (failover), then unfreezes

    Master **failover** is the same protocol minus the export: ``step()``
    notices a dead owner, enqueues a ``from_wal`` migration to the next
    alive master, and the rebuild path replays the shard's committed WAL —
    the dying master's uncommitted tail is exactly the loss window, and the
    overwatch's rebuild diffs lost in-memory state against durable state to
    emit watch-repair events at fresh revisions (the replica fan-out's
    rev-dedupe would silently drop reused ones). Master add / drain /
    rebalance are thin wrappers over the same primitive.

Chaos integration: every step fires ``on_site("migrate", "<shard>:<step>")``
BEFORE executing, so a ``FaultPlan`` can kill a master or partition the
fabric at each protocol boundary deterministically
(``site="migrate:<shard>:freeze"`` etc.). ``num_masters=1`` planes never
construct a coordinator and are behavior-identical to the single-process
seed.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.transport import Address, DeliveryError, Fabric

# protocol steps, in order; also the chaos site suffixes
MIGRATION_STEPS = ("freeze", "transfer", "flip", "replay")

# durability shard holding the map's flip log
SHARDMAP_WAL = "shardmap"

# overwatch key the coordinator serializes the map under after each
# migration (best-effort observability; the WAL is the durable copy)
SHARDMAP_KEY = "/sys/shardmap"


class MasterNode:
    """One crashable master fault domain. ``guard(addr, handler)`` registers
    the handler wrapped in a liveness check: a dead master's endpoints raise
    ``DeliveryError`` exactly like an unregistered address, while the shard
    objects (and every other master's endpoints) keep working."""

    def __init__(self, fabric: Fabric, cluster: str, name: str):
        self.fabric = fabric
        self.cluster = cluster
        self.name = name
        self.alive = True

    def guard(self, addr: Address,
              handler: Callable[[dict], dict]) -> None:
        def guarded(req, _h=handler):
            if not self.alive:
                raise DeliveryError(
                    f"master {self.name} is down ({self.cluster}{addr})")
            return _h(req)
        self.fabric.register_handler(self.cluster, addr, guarded)

    def crash(self) -> None:
        self.alive = False

    def restart(self) -> None:
        self.alive = True


@dataclasses.dataclass
class ShardMap:
    """Epoch-numbered shard -> master assignment. Addresses are derived from
    the hash ring and never move; the map says which fault domain ANSWERS."""
    epoch: int = 0
    assignment: Dict[str, str] = dataclasses.field(default_factory=dict)

    def to_payload(self) -> dict:
        return {"epoch": self.epoch, "assignment": dict(self.assignment)}


class _Managed:
    """Registration record for one migratable shard: its endpoint, the raw
    (unguarded) handler for re-guarding at flip, the store-specific migration
    ops, and the WAL shard(s) that die with its owner."""

    __slots__ = ("name", "addr", "handler", "ops", "wal_shards")

    def __init__(self, name: str, addr: Address, handler, ops: dict,
                 wal_shards: Tuple[str, ...]):
        self.name = name
        self.addr = addr
        self.handler = handler
        self.ops = ops
        self.wal_shards = tuple(wal_shards)


class _Migration:
    __slots__ = ("shard", "source", "target", "from_wal", "step", "payload",
                 "t0")

    def __init__(self, shard: str, source: Optional[str], target: str,
                 from_wal: bool, t0: float):
        self.shard = shard
        self.source = source
        self.target = target
        self.from_wal = from_wal
        self.step = 0
        self.payload = None
        self.t0 = t0


class ShardMapCoordinator:
    """Owns the map, the masters, and the migration state machine.

    HA by construction (it models the cloud-managed control service, like
    the overwatch front-end): it is never guarded by a ``MasterNode``, and a
    whole-plane crash rebuilds it with the map replayed from the
    ``shardmap`` WAL shard — epoch and assignment survive, so post-restart
    client retries still fence correctly.

    ``step()`` runs once per plane tick: it detects dead owners (enqueueing
    ``from_wal`` failover migrations), then advances every active migration
    exactly ONE protocol step — a migration therefore spans four ticks and
    its freeze window is a measurable number of ticks, during which writes
    bounce-and-retry rather than hang.
    """

    def __init__(self, fabric: Fabric, cluster: str, num_masters: int,
                 durability=None, tracer=None, fault_injector=None):
        self.fabric = fabric
        self.cluster = cluster
        self.masters: Dict[str, MasterNode] = {}
        self._order: List[str] = []
        for i in range(max(1, num_masters)):
            name = f"m{i}"
            self.masters[name] = MasterNode(fabric, cluster, name)
            self._order.append(name)
        self.map = ShardMap()
        self._managed: Dict[str, _Managed] = {}
        self._reg_n = 0                      # round-robin default placement
        self._active: List[_Migration] = []
        self._frozen: set = set()            # shard names mid-migration
        self._dur = durability
        self.tracer = tracer
        self.fault_injector = fault_injector
        # best-effort map serialization into the overwatch (set by the plane)
        self.publish: Optional[Callable[[dict], dict]] = None
        self.stats: Counter = Counter()
        self.migrations_by_shard: Counter = Counter()
        self.frozen_ticks_by_shard: Counter = Counter()
        self.stale_by_shard: Counter = Counter()
        # a whole-plane restart replays the flip log so the recovered map
        # (epoch included) matches what clients last saw
        if durability is not None and durability.has_data(SHARDMAP_WAL):
            payload, recs = durability.load(SHARDMAP_WAL)
            if payload:
                self.map.epoch = payload["epoch"]
                self.map.assignment.update(payload["assignment"])
            for rec in recs:
                if rec[0] == "flip":
                    self.map.epoch = max(self.map.epoch, rec[1])
                    self.map.assignment[rec[2]] = rec[3]
            self.stats["map_replayed_flips"] += len(recs)

    # ------------------------------------------------------------ registration
    def register_shard(self, name: str, addr: Address, handler,
                       ops: dict, wal_shards: Tuple[str, ...] = ()) -> str:
        """Place a shard under a master and guard its endpoint. Idempotent
        across service rebuilds: a WAL-recovered (or existing) assignment
        wins over the round-robin default, so recovery re-registers every
        shard under the owner clients last flipped to. ``ops`` is the
        store-specific migration vocabulary::

            freeze()          quiesce writes (may be a no-op if the host
                              consults ``coordinator.frozen()`` directly)
            unfreeze()
            export() -> dict  snapshot payload (live transfer)
            import_(payload)  install a transferred payload (live replay)
            rebuild()         rebuild from committed WAL (failover replay)
        """
        owner = self.map.assignment.get(name)
        if owner not in self.masters:
            owner = self._order[self._reg_n % len(self._order)]
            self.map.assignment[name] = owner
        self._reg_n += 1
        m = _Managed(name, addr, handler, ops, wal_shards)
        self._managed[name] = m
        self.masters[owner].guard(addr, handler)
        return owner

    # ---------------------------------------------------------------- queries
    @property
    def epoch(self) -> int:
        return self.map.epoch

    def frozen(self, name: str) -> bool:
        """True while writes to the shard must bounce: mid-migration freeze
        window, or its owning master is dead (the failover's implicit
        freeze — the coordinator notices on the next tick)."""
        if name in self._frozen:
            return True
        node = self.masters.get(self.map.assignment.get(name))
        return node is not None and not node.alive

    def frozen_names(self) -> List[str]:
        return sorted(n for n in self._managed if self.frozen(n))

    def note_stale(self, name: str) -> None:
        """A fenced write bounced off this shard (stale epoch or frozen)."""
        self.stale_by_shard[name] += 1
        self.stats["stale_epoch_rejections"] += 1

    def owner_of(self, name: str) -> Optional[str]:
        return self.map.assignment.get(name)

    def shards_of(self, master: str) -> List[str]:
        return sorted(n for n, o in self.map.assignment.items()
                      if o == master and n in self._managed)

    def wal_shards_of(self, master: str) -> List[str]:
        out: List[str] = []
        for name in self.shards_of(master):
            out.extend(self._managed[name].wal_shards)
        return out

    @property
    def busy(self) -> bool:
        return bool(self._active)

    # ------------------------------------------------------------- fault model
    def kill_master(self, name: str) -> List[str]:
        """Crash one fault domain: its endpoints start raising
        ``DeliveryError``, and its shards' uncommitted WAL tails evaporate
        (only ITS shards — the survivors' buffered records are untouched).
        Returns the shard names that now need failover."""
        node = self.masters[name]
        if not node.alive:
            return []
        node.crash()
        if self._dur is not None:
            self._dur.lose_shards(self.wal_shards_of(name))
        self.stats["master_kills"] += 1
        return self.shards_of(name)

    def restart_master(self, name: str) -> None:
        """Bring a crashed fault domain back empty-handed: its shards have
        (or will have) migrated away; it becomes a rebalance target."""
        self.masters[name].restart()
        self.stats["master_restarts"] += 1

    def add_master(self, name: str) -> MasterNode:
        node = MasterNode(self.fabric, self.cluster, name)
        self.masters[name] = node
        self._order.append(name)
        self.stats["masters_added"] += 1
        return node

    # -------------------------------------------------------------- migrations
    def migrate(self, shard: str, target: str) -> bool:
        """Enqueue a live migration (one protocol step per tick). Rejected if
        the shard is unknown, already migrating, or already owned there."""
        if shard not in self._managed or target not in self.masters:
            return False
        if not self.masters[target].alive:
            return False
        if self.map.assignment.get(shard) == target:
            return False
        if any(m.shard == shard for m in self._active):
            return False
        self._active.append(_Migration(shard, self.map.assignment.get(shard),
                                       target, False, self.fabric.clock))
        self.stats["migrations_started"] += 1
        return True

    def drain_master(self, name: str) -> int:
        """Move every shard off a master (decommission / maintenance): one
        live migration per shard, targets round-robin over the other alive
        masters. Returns how many migrations were enqueued."""
        moved = 0
        for shard in self.shards_of(name):
            target = self._pick_target(exclude=name, salt=moved)
            if target is not None and self.migrate(shard, target):
                moved += 1
        return moved

    def rebalance(self) -> int:
        """Round-robin the managed shards over the alive masters (sorted
        registration order) and migrate every mismatch — the hot-shard /
        new-master leveling primitive."""
        alive = [n for n in self._order if self.masters[n].alive]
        if not alive:
            return 0
        moved = 0
        for i, shard in enumerate(sorted(self._managed)):
            want = alive[i % len(alive)]
            if self.map.assignment.get(shard) != want:
                if self.migrate(shard, want):
                    moved += 1
        return moved

    def _pick_target(self, exclude: Optional[str],
                     salt: int = 0) -> Optional[str]:
        alive = [n for n in self._order
                 if n != exclude and self.masters[n].alive]
        if not alive:
            return None
        # spread consecutive picks (drain, multi-shard failover) round-robin
        return alive[(self.stats["targets_picked"] + salt) % len(alive)]

    # ------------------------------------------------------------------- tick
    def step(self) -> None:
        """One coordinator tick: detect dead owners, advance each active
        migration one protocol step, account frozen time."""
        # 1. failover detection — a shard whose owner died gets a from_wal
        #    migration to the next alive master (also covers killing the
        #    TARGET of an in-flight migration: once that migration finishes
        #    or the map flips, the dead owner is detected here again)
        for shard in sorted(self._managed):
            owner = self.map.assignment.get(shard)
            node = self.masters.get(owner)
            if node is not None and node.alive:
                continue
            if any(m.shard == shard for m in self._active):
                continue
            target = self._pick_target(exclude=owner)
            if target is None:
                self.stats["failover_stalled_ticks"] += 1
                continue
            self.stats["targets_picked"] += 1
            self._active.append(_Migration(shard, owner, target, True,
                                           self.fabric.clock))
            self.stats["failovers_started"] += 1
        # 2. frozen-window accounting: every shard unwritable this tick
        for name in self._managed:
            if self.frozen(name):
                self.frozen_ticks_by_shard[name] += 1
                self.stats["frozen_ticks"] += 1
        # 3. advance — one step per migration per tick, so freeze windows
        #    span real ticks and chaos can land between any two steps
        for mig in list(self._active):
            self._advance(mig)

    def _advance(self, mig: _Migration) -> None:
        step_name = MIGRATION_STEPS[mig.step]
        m = self._managed[mig.shard]
        if self.fault_injector is not None:
            # fires BEFORE the step executes: a crash here leaves the
            # protocol at a well-defined boundary (pre-flip: the old owner
            # still holds the shard; post-flip: the WAL'd map wins)
            self.fault_injector.on_site("migrate",
                                        f"{mig.shard}:{step_name}")
        if (not mig.from_wal and step_name in ("freeze", "transfer")
                and mig.source in self.masters
                and not self.masters[mig.source].alive):
            # the live source died before the export landed (possibly via
            # the fault hook just above): a dead master cannot be asked for
            # anything — degrade to a WAL failover (its committed log is the
            # transfer). A source dying AFTER transfer is fine: the payload
            # already left it and was persisted as the durable snapshot.
            mig.from_wal = True
            mig.payload = None
            self.stats["live_migrations_degraded"] += 1
        if step_name == "freeze":
            m.ops["freeze"]()
            self._frozen.add(mig.shard)
        elif step_name == "transfer":
            if not mig.from_wal:
                # live handoff: commit the tail, export the quiesced state,
                # and persist that exact payload as the durable snapshot so
                # the WAL and the in-flight transfer can never diverge
                if self._dur is not None:
                    for w in m.wal_shards:
                        self._dur.commit(w)
                mig.payload = m.ops["export"]()
                if self._dur is not None and len(m.wal_shards) == 1:
                    self._dur.snapshot(m.wal_shards[0], mig.payload)
            # failover: nothing to export — the committed WAL *is* the
            # transfer (the dead master cannot be asked for anything)
        elif step_name == "flip":
            self.map.epoch += 1
            self.map.assignment[mig.shard] = mig.target
            if self._dur is not None:
                self._dur.append(SHARDMAP_WAL,
                                 ("flip", self.map.epoch, mig.shard,
                                  mig.target, mig.from_wal))
                self._dur.commit(SHARDMAP_WAL)
            # the endpoint answers under the target fault domain from here on
            self.masters[mig.target].guard(m.addr, m.handler)
        elif step_name == "replay":
            if mig.from_wal:
                m.ops["rebuild"]()
            else:
                m.ops["import_"](mig.payload)
            m.ops["unfreeze"]()
            self._frozen.discard(mig.shard)
            self._active.remove(mig)
            self.migrations_by_shard[mig.shard] += 1
            self.stats["migrations"] += 1
            if mig.from_wal:
                self.stats["failovers"] += 1
            if self.tracer is not None:
                self.tracer.span_complete(
                    f"shardmap/{mig.shard}", "migrate", "shardmap", mig.t0,
                    attrs={"shard": mig.shard, "from": mig.source,
                           "to": mig.target, "failover": mig.from_wal,
                           "epoch": self.map.epoch})
            self._publish_map()
            return
        mig.step += 1

    def _publish_map(self) -> None:
        """Serialize the map into the overwatch (``/sys/shardmap``) so any
        client/replica can observe it. Best-effort: a bounce (the owning
        shard itself frozen or failing over) is counted, not raised — the
        WAL remains the authoritative copy."""
        if self.publish is None:
            return
        try:
            resp = self.publish(self.map.to_payload())
        except DeliveryError:
            resp = {"ok": False}
        if not (resp or {}).get("ok"):
            self.stats["map_publish_bounced"] += 1

    # ---------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, Any]:
        """Registry source for the ``shardmap`` section of the master
        agent's ``/metrics/<cluster>/`` feed."""
        out: Dict[str, Any] = {
            "epoch": self.map.epoch,
            "migrations": self.stats["migrations"],
            "failovers": self.stats["failovers"],
            "frozen_ticks": self.stats["frozen_ticks"],
            "stale_epoch_rejections": self.stats["stale_epoch_rejections"],
            "masters_alive": sum(1 for n in self.masters.values()
                                 if n.alive),
        }
        for shard, n in sorted(self.migrations_by_shard.items()):
            out[f"{shard}.migrations"] = n
        for shard, n in sorted(self.frozen_ticks_by_shard.items()):
            out[f"{shard}.frozen_ticks"] = n
        for shard, n in sorted(self.stale_by_shard.items()):
            out[f"{shard}.stale_epoch_rejections"] = n
        return out
