# Titchener management plane — the paper's primary contribution.
from repro.core.plane import ManagementPlane, SimLocalPlane  # noqa: F401
from repro.core.service_graph import AppSpec, Pod, Service  # noqa: F401
from repro.core.transport import AclTable, DeliveryError, Fabric  # noqa: F401
