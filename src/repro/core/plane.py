"""ManagementPlane — the single pane of glass (paper §2).

One object through which users do everything: register clusters, upload the
application CRD, submit jobs, read statuses, inject faults (tests), and read the
cross-boundary byte ledger. Internally it wires the fabric, the master cluster,
the overwatch, the dispatcher, and one control agent per cluster — users never
touch those directly, which is precisely the paper's UX claim.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional

from repro.core.agent import ControlAgent
from repro.core.dispatcher import Dispatcher, RoutingRule
from repro.core.overwatch import OverwatchService
from repro.core.service_graph import AppSpec
from repro.core.transport import Fabric


class SimLocalPlane:
    """Deterministic local control plane for management-plane tests: jobs advance
    ``rate`` progress-units per clock tick (no JAX). The runtime package provides
    the real JAX-executing local plane with the same interface."""

    def __init__(self, caps=("cpu",), rate: float = 1.0):
        self._caps = tuple(caps)
        self.rate = rate
        self.jobs: Dict[str, dict] = {}

    def capabilities(self):
        return self._caps

    def submit(self, job: dict) -> None:
        start = float(job.get("restore_from", {}).get("progress", 0.0) or 0.0)
        self.jobs[job["job_id"]] = {"job": job, "progress": start,
                                    "status": "running"}

    def cancel(self, job_id: str) -> None:
        if job_id in self.jobs:
            self.jobs[job_id]["status"] = "failed"

    def poll(self, job_id: str) -> dict:
        rec = self.jobs[job_id]
        if rec["status"] == "running":
            rec["progress"] += self.rate
            total = float(rec["job"].get("steps", 10))
            if rec["progress"] >= total:
                rec["progress"] = total
                rec["status"] = "done"
        return {"progress": rec["progress"], "status": rec["status"],
                "rate": self.rate if rec["status"] == "running" else 0.0}

    def load(self) -> float:
        return sum(1.0 for r in self.jobs.values() if r["status"] == "running")


class ManagementPlane:
    def __init__(self, master: str = "master",
                 message_log_limit: Optional[int] = 100_000,
                 op_log_limit: Optional[int] = None,
                 ow_shards: int = 1,
                 coalesce_watches: bool = False,
                 replica_fanout: bool = False,
                 replica_prefixes=None,
                 durability=None,
                 trace_sample: float = 0.0,
                 metrics_every: Optional[float] = None,
                 num_masters: int = 1):
        self.fabric = Fabric(message_log_limit=message_log_limit)
        self.master = master
        # flight recorder: trace_sample > 0 arms a plane-wide tracer shared
        # by the dispatcher, every agent, and any composer built on top
        # (sampling is per-trace-id deterministic); 0 keeps every payload
        # byte-identical. ``metrics_every`` turns on per-agent registry
        # export under /metrics/<cluster>/ at that clock cadence (None: no
        # publication — the default plane is unmetered on the wire).
        self.tracer = None
        if trace_sample > 0:
            from repro.observability.trace import Tracer
            self.tracer = Tracer(clock_fn=lambda: self.fabric.clock,
                                 sample=trace_sample)
        self.metrics_every = metrics_every
        self._idx = itertools.count(1)
        self.agents: Dict[str, ControlAgent] = {}
        self.ow_shards = max(1, ow_shards)
        # durability (repro.core.durability.LogStore): WAL + snapshots for the
        # global-plane services; None => byte-identical in-memory-only plane.
        # Kept public: the chaos harness reaches it to model commit-loss.
        self.durability = durability
        self._op_log_limit = op_log_limit
        self._coalesce_watches = coalesce_watches
        self._replica_fanout = replica_fanout
        self.overwatch = OverwatchService(self.fabric, master,
                                          op_log_limit=op_log_limit,
                                          num_shards=self.ow_shards,
                                          coalesce_watches=coalesce_watches,
                                          durability=durability)
        self.dispatcher = Dispatcher(self.fabric, master, self.overwatch)
        self.dispatcher.tracer = self.tracer
        # replica fan-out (off by default — behavior-identical without it):
        # every non-master cluster hosts a LocalReplica fed by one coalesced
        # delta envelope per sweep, and remote range_stale reads go local
        self.shipper = None
        self._replica_prefixes = replica_prefixes
        if replica_fanout:
            from repro.core.replica import REPLICA_PREFIXES, ReplicaShipper
            self._replica_prefixes = tuple(replica_prefixes
                                           or REPLICA_PREFIXES)
            self.shipper = ReplicaShipper(self.overwatch,
                                          self.dispatcher.send_agent,
                                          prefixes=self._replica_prefixes)
            # a tombstoned cluster stops accumulating ship backlog
            self.dispatcher.on_cluster_down(self.shipper.unregister)
        self.spec: Optional[AppSpec] = None
        self._job_ids = itertools.count(1)
        # master hosts its own agent (idx 0)
        self._master_agent = None
        # multi-master split (repro.core.shardmap): N crashable fault domains
        # own the overwatch/broker shards behind an epoch-fenced shard map.
        # num_masters=1 (default) builds no coordinator and stays
        # behavior-identical to the single-process seed plane.
        self.num_masters = max(1, num_masters)
        self.coordinator = None
        if self.num_masters > 1:
            self._build_coordinator()

    # --------------------------------------------------------------- multi-master
    def _build_coordinator(self, fault_injector=None) -> None:
        """(Re)build the shard-map coordinator and place every overwatch
        shard under a master fault domain: the per-shard endpoints are
        re-registered through each owner's liveness guard, and the overwatch
        arms its fence. Assignment honors the WAL-recovered map, so a
        post-crash rebuild lands every shard with the owner clients last
        flipped to. Called from ``__init__`` and ``recover_global_plane``."""
        from repro.core.shardmap import ShardMapCoordinator
        prior = self.coordinator
        co = ShardMapCoordinator(
            self.fabric, self.master, self.num_masters,
            durability=self.durability, tracer=self.tracer,
            fault_injector=fault_injector or (
                prior.fault_injector if prior is not None else None))
        ow = self.overwatch
        for i, name in enumerate(ow._shard_names):
            addr = (ow.addr[0], ow.addr[1] + 1 + i)
            co.register_shard(
                name, addr,
                # index closures: a migration's shard swap re-points the
                # endpoint with no re-registration
                lambda req, _i=i: ow._dispatch(req, ow.shards[_i]),
                ops={
                    # the overwatch consults the coordinator's frozen()
                    # directly, so freeze/unfreeze carry no store-side state
                    "freeze": lambda: None,
                    "unfreeze": lambda: None,
                    "export": lambda _i=i: ow._shard_snapshot(_i),
                    "import_": lambda p, _i=i: ow.install_shard(_i, p),
                    "rebuild": lambda _i=i: ow.rebuild_shard(_i),
                },
                wal_shards=(name,))
        ow.set_fence(co)
        co.publish = lambda payload: self.overwatch.handle(
            {"op": "put", "key": "/sys/shardmap", "value": payload})
        self.coordinator = co

    def kill_master(self, name: str):
        """Crash one master fault domain (multi-master planes only): its
        endpoints die, its WAL tails are lost, and the coordinator fails its
        shards over to survivors across the next ticks."""
        return self.coordinator.kill_master(name)

    def restart_master(self, name: str) -> None:
        self.coordinator.restart_master(name)

    # ------------------------------------------------------------------- clusters
    def add_cluster(self, name: str, local_plane=None,
                    is_master: bool = False) -> ControlAgent:
        if local_plane is None:
            local_plane = SimLocalPlane()
        idx = 0 if is_master else next(self._idx)
        agent = ControlAgent(self.fabric, name, idx, self.master, local_plane,
                             ow_shards=self.ow_shards)
        agent.tracer = self.tracer
        agent.metrics_every = self.metrics_every
        self.agents[name] = agent
        if is_master:
            self._master_agent = agent
            self._register_master_metrics(agent)
        master_state = (self._master_agent.state if self._master_agent
                        else agent.state)
        agent.bootstrap(master_state)
        if self.coordinator is not None:
            # epoch fencing: the agent's overwatch client stamps writes with
            # its map epoch and refreshes off stale-epoch bounces
            agent.ow.fenced = True
        agent.register()
        if self.shipper is not None and not is_master:
            # master-cluster reads are already fabric-local; remote clusters
            # get a replica seeded by the first ship (next tick)
            agent.enable_replica(self._replica_prefixes)
            self.shipper.register(name)
        return agent

    @property
    def master_agent(self) -> ControlAgent:
        return self._master_agent

    def _register_master_metrics(self, agent: ControlAgent) -> None:
        """The master agent's registry adopts the global-plane stats dicts:
        the fabric's byte/operational ledgers (``fallback_reads`` et al. —
        the same numbers ``boundary_report`` prints), the replica shipper,
        and per-overwatch-shard op counts. Sources late-bind through
        ``self``, so ``recover_global_plane``'s rebuilt services are picked
        up without re-registration."""
        def fabric_stats():
            f = self.fabric
            out = {"cross_cluster_bytes": f.cross_cluster_bytes(),
                   "local_bytes": sum(f.local_bytes.values())}
            out.update(f.stats)
            return out

        def shipper_stats():
            return dict(self.shipper.stats) if self.shipper is not None \
                else {}

        def overwatch_stats():
            ow = self.overwatch
            out = {f"ops.{k}": v for k, v in ow.op_counts.items()}
            for i, shard in enumerate(ow.shards):
                out.update({f"s{i}.ops.{k}": v
                            for k, v in shard.op_counts.items()})
            return out

        agent.metrics.register_source("fabric", fabric_stats)
        agent.metrics.register_source("shipper", shipper_stats)
        agent.metrics.register_source("overwatch", overwatch_stats)
        if self.coordinator is not None:
            # shardmap.epoch / per-shard migrations / frozen_ticks /
            # stale_epoch_rejections ride the same /metrics/<cluster>/ feed
            agent.metrics.register_source(
                "shardmap", lambda: self.coordinator.metrics())

    # ------------------------------------------------------------------ app config
    def upload_spec(self, spec: AppSpec) -> None:
        """Validate + broadcast the CRD to every agent (configuration phase)."""
        spec.validate(list(self.agents))
        self.spec = spec
        self.overwatch.handle({"op": "put", "key": "/config/appspec",
                               "value": {"services": len(spec.services),
                                         "pods": len(spec.pods)}})
        self.dispatcher.broadcast_spec(spec, self._master_agent.state)

    # ------------------------------------------------------------------ job surface
    def _build_job(self, kind: str, *, arch: str = "", steps: int = 10,
                   tags: Optional[dict] = None, job_id: Optional[str] = None,
                   payload: Optional[dict] = None) -> dict:
        jid = job_id or f"job-{next(self._job_ids):04d}"
        return {"job_id": jid, "kind": kind, "arch": arch, "steps": steps,
                "tags": tags or {}, "payload": payload or {}}

    def submit_job(self, kind: str, **kw) -> str:
        job = self._build_job(kind, **kw)
        self.dispatcher.submit(job)
        return job["job_id"]

    def submit_jobs(self, jobs: List[dict]) -> List[str]:
        """Batched admission: each item is a dict of ``submit_job`` keyword
        arguments (``kind`` required). The dispatcher amortizes placement over
        the whole batch (one min-load probe, round-robin across the tie block)
        instead of re-picking per job. Returns the job ids in order."""
        built = [self._build_job(**spec) for spec in jobs]
        self.dispatcher.submit_many(built)
        return [job["job_id"] for job in built]

    def job_status(self, job_id: str) -> Optional[dict]:
        return self.overwatch.handle(
            {"op": "get", "key": f"/jobs/{job_id}/status"})["value"]

    def retire_job(self, job_id: str) -> bool:
        """Gracefully stop a placed job and tombstone its store records —
        never recorded as a failure, never resurrected by recovery; the
        management-plane surface the autoscaler uses to return worker pods
        (see ``Dispatcher.retire``)."""
        return self.dispatcher.retire(job_id)

    def add_routing_rule(self, rule: RoutingRule) -> None:
        self.dispatcher.add_rule(rule)

    # --------------------------------------------------------------- crash recovery
    def recover_global_plane(self) -> dict:
        """Rebuild the crashed global-plane services in place (the master
        process restarting on the same addresses): a fresh ``OverwatchService``
        whose constructor replays snapshot + WAL, a fresh ``Dispatcher`` whose
        constructor re-seeds its materialized views from the recovered store,
        and (fan-out mode) a fresh ``ReplicaShipper`` that resumes each
        surviving cluster's feed from its replica's cumulative-ack horizon —
        full reseed (with a reset marker) only when the horizon predates the
        oldest replayable event. Control agents, workers, and the fabric
        survive a master crash and are never touched; ``register_handler``
        overwrites, so the rebuilt services answer on the exact addresses the
        survivors already talk to. Returns the overwatch recovery stats."""
        self.fabric.heal_cluster(self.master)
        self.overwatch = OverwatchService(self.fabric, self.master,
                                          op_log_limit=self._op_log_limit,
                                          num_shards=self.ow_shards,
                                          coalesce_watches=self._coalesce_watches,
                                          durability=self.durability)
        self.dispatcher = Dispatcher(self.fabric, self.master, self.overwatch)
        self.dispatcher.tracer = self.tracer
        if self.coordinator is not None:
            # the whole plane restarted: every master restarts empty-handed,
            # the map (epoch + assignment) replays from the shardmap WAL,
            # and the fresh overwatch's shard endpoints are re-guarded under
            # their WAL-recorded owners before any client retry lands
            self._build_coordinator()
        self.shipper = None
        if self._replica_fanout:
            from repro.core.replica import ReplicaShipper
            from repro.core.transport import DeliveryError
            self.shipper = ReplicaShipper(self.overwatch,
                                          self.dispatcher.send_agent,
                                          prefixes=self._replica_prefixes)
            self.dispatcher.on_cluster_down(self.shipper.unregister)
            tail = self.overwatch.recovery_tail
            tail_base = self.overwatch.recovery_base_rev
            for name in sorted(self.agents):
                agent = self.agents[name]
                if name == self.master or agent.replica is None:
                    continue
                try:
                    resp = self.dispatcher.send_agent(
                        name, {"kind": "replica_rev"})
                    applied = int(resp.get("rev", 0))
                except (DeliveryError, KeyError):
                    # unreachable (partitioned) or not yet re-registered:
                    # bootstrap-seed the feed with a RESET marker — the
                    # replica's horizon is unknowable and its snapshot may
                    # hold keys whose deletion the fresh seed cannot
                    # tombstone; ships fail harmlessly until the cluster
                    # heals or its lease tombstones it, and the first ship
                    # after heal re-converges the replica (and its watchers,
                    # deletions included) from scratch
                    self.shipper.register(name, reset=True)
                    continue
                self.shipper.register_resume(name, applied, tail, tail_base)
        # a cluster whose registration (lease grant + /clusters/ put) was
        # still in the uncommitted tail is unknown to the recovered store and
        # its surviving heartbeat can only keepalive a dead lease id: re-grant
        # and re-put for it here, WITHOUT re-scheduling its heartbeat timer
        # (the original timer never stopped). Partitioned clusters are skipped
        # and re-register by hand (or stay tombstoned) after they heal.
        from repro.core.transport import DeliveryError
        for name in sorted(self.agents):
            agent = self.agents[name]
            try:
                known = agent.ow.get(f"/clusters/{name}")
                if known is None:
                    agent.lease = agent.ow.lease_grant(agent.lease_ttl)
                    agent.ow.put(f"/clusters/{name}", {
                        "idx": agent.idx,
                        "capabilities": agent.local_plane.capabilities(),
                        "agent_addr": list(agent.addr),
                    }, lease=agent.lease)
            except DeliveryError:
                continue
        return dict(self.overwatch.recovery_stats)

    # -------------------------------------------------------------------- operation
    def tick(self, dt: float = 1.0, n: int = 1) -> None:
        for _ in range(n):
            self.fabric.tick(dt)
            if self.coordinator is not None:
                # before the sweep: failover repairs emitted by a rebuild
                # flush to watchers/replicas within the same tick
                self.coordinator.step()
            self.overwatch.sweep()
            if self.shipper is not None:
                self.shipper.ship_all()      # one delta envelope per cluster

    def run_until_done(self, job_ids: List[str], max_ticks: int = 200) -> bool:
        for _ in range(max_ticks):
            self.tick()
            st = [self.job_status(j) for j in job_ids]
            if all(s and s["status"] == "done" for s in st):
                return True
        return False

    # ------------------------------------------------------------------ observation
    def boundary_report(self) -> dict:
        f = self.fabric
        out = {
            "cross_cluster_bytes": f.cross_cluster_bytes(),
            "local_bytes": sum(f.local_bytes.values()),
            "locality_ratio": f.locality_ratio(),
            "per_edge": dict(f.cross_bytes),
            "fabric_stats": dict(f.stats),
        }
        if self.shipper is not None:
            out["replica_ships"] = dict(self.shipper.stats)
        return out
