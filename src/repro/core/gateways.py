"""Algorithms 1-4 of the paper: DNS entries, route reservation, access control,
and cross-cluster channels — executed by each control agent against the fabric.

Port determinism: every agent allocates gateway ports for services in sorted
service-name order, so ``eport[i, s]`` / ``iport[i, s]`` are identical functions
of S in every cluster. This realizes Algorithm 5's "Estimate iport[m, s]" exactly
(the paper's agents can predict master-side ports without asking).

Topology is the paper's hub: private clusters tunnel to the master; a service
hosted on a private cluster is reached from another private cluster via a master
relay port (an extension of Algorithm 4's two cases, flagged in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.service_graph import AppSpec
from repro.core.transport import AclTable, Address, Fabric

EPORT_BASE = 20_000      # egress gateway ports
IPORT_BASE = 30_000      # ingress gateway ports
RPORT_BASE = 40_000      # master relay ports (hub extension)
SVC_IP_BASE = 1          # 10.<idx>.1.<k> real service IPs
DUMMY_IP_BASE = 1        # 10.<idx>.2.<k> dummy DNS IPs


@dataclasses.dataclass
class GatewayState:
    """Per-cluster gateway + DNS + port tables (one per control agent)."""
    cluster: str
    idx: int                                   # cluster ordinal (subnet)
    dns: Dict[str, Address] = dataclasses.field(default_factory=dict)
    eport: Dict[str, int] = dataclasses.field(default_factory=dict)
    iport: Dict[str, int] = dataclasses.field(default_factory=dict)
    acl: AclTable = dataclasses.field(default_factory=AclTable)

    @property
    def igw_ip(self) -> str:
        return f"10.{self.idx}.0.10"

    @property
    def egw_ip(self) -> str:
        return f"10.{self.idx}.0.11"

    def service_ip(self, rank: int) -> str:
        return f"10.{self.idx}.1.{SVC_IP_BASE + rank}"

    def dummy_ip(self, rank: int) -> str:
        return f"10.{self.idx}.2.{DUMMY_IP_BASE + rank}"


def service_rank(spec: AppSpec, name: str) -> int:
    return sorted(s.name for s in spec.services).index(name)


# ------------------------------------------------------------------- Algorithm 1
def add_dns_entry(state: GatewayState, spec: AppSpec, s: str) -> None:
    """DNS for service s in this cluster: real IP if native, dummy IP otherwise."""
    svc = spec.service(s)
    rank = service_rank(spec, s)
    if spec.host_cluster(s) != state.cluster:
        state.dns[s] = (state.dummy_ip(rank), svc.port)
    else:
        state.dns[s] = (state.service_ip(rank), svc.port)


# ------------------------------------------------------------------- Algorithm 2
def reserve_route(fabric: Fabric, state: GatewayState, spec: AppSpec,
                  s: str) -> None:
    """External: dialed dummy addr forwards to egw[i]:eport. Native: igw[i]:iport
    forwards to the service pods."""
    svc = spec.service(s)
    rank = service_rank(spec, s)
    if spec.host_cluster(s) != state.cluster:
        eport = EPORT_BASE + rank
        state.eport[s] = eport
        fabric.add_forward(state.cluster, state.dns[s],
                           (state.egw_ip, eport))
    else:
        iport = IPORT_BASE + rank
        state.iport[s] = iport
        fabric.add_forward(state.cluster, (state.igw_ip, iport),
                           (state.service_ip(rank), svc.port))


# ------------------------------------------------------------------- Algorithm 3
def set_access_control(state: GatewayState, spec: AppSpec, s: str) -> None:
    """Default-deny; allow only pods with f[p, s] = 1, plus the gateway hop when
    the service is consumed from external clusters."""
    svc = spec.service(s)
    rank = service_rank(spec, s)
    external = spec.host_cluster(s) != state.cluster
    dialed = state.dns[s]
    state.acl.block_all(dialed)
    if external:
        # the egress-gateway hop is an allowed address too: clear it as well,
        # so a re-broadcast (elastic pod churn) is a true default-deny
        # rebuild — a removed pod loses BOTH its dialed and egress entries,
        # and the table never accretes stale tuples
        state.acl.block_all((state.egw_ip, state.eport[s]))
    for pod in spec.pods_needing(s):
        if spec.partition[pod] != state.cluster:
            continue
        if external:
            state.acl.allow(pod, dialed)
            state.acl.allow(pod, (state.egw_ip, state.eport[s]))
        else:
            state.acl.allow(pod, dialed)
    # (gateway-originated hops are exempt in AclTable — the paper's
    #  allow-access(igw -> service) rule.)


# ------------------------------------------------------------------- Algorithm 4
def create_channels(fabric: Fabric, state: GatewayState, spec: AppSpec, s: str,
                    master: str, master_state: GatewayState) -> None:
    """Interconnect this (non-master) cluster with the master for service s.

    h == master : local-forward channel  egw[i]:eport  ->  igw[m]:iport[m,s]
    h == i      : remote-forward channel egw[m]:eport[m,s] -> igw[i]:iport[i,s]
    h elsewhere : consumer side tunnels to a master relay port which forwards to
                  the master's own egress entry for s (hub transit, extension).
    """
    h = spec.host_cluster(s)
    rank = service_rank(spec, s)
    i = state.cluster
    # Idempotent under re-configuration: an AppSpec re-broadcast (elastic
    # fleets add/remove pods at runtime) re-runs this algorithm on every
    # agent; a tunnel that already terminates at the endpoint is kept —
    # including a deliberately killed one, so fault injection survives
    # reconfiguration — instead of stacking a duplicate channel.
    if h == master and s in state.eport:
        if fabric.channel_at(i, (state.egw_ip, state.eport[s])) is None:
            fabric.create_channel(
                i, (state.egw_ip, state.eport[s]),
                master, (master_state.igw_ip, IPORT_BASE + rank))
    elif h == i and spec.external_consumers(s):
        if fabric.channel_at(master,
                             (master_state.egw_ip, EPORT_BASE + rank)) is None:
            fabric.create_channel(
                master, (master_state.egw_ip, EPORT_BASE + rank),
                i, (state.igw_ip, state.iport[s]))
    elif h not in (master, i) and s in state.eport:
        relay = RPORT_BASE + rank
        fabric.add_forward(master, (master_state.igw_ip, relay),
                           (master_state.egw_ip, EPORT_BASE + rank))
        if fabric.channel_at(i, (state.egw_ip, state.eport[s])) is None:
            fabric.create_channel(i, (state.egw_ip, state.eport[s]),
                                  master, (master_state.igw_ip, relay))


def install_acl(fabric: Fabric, state: GatewayState) -> None:
    fabric.set_acl(state.cluster, state.acl)
