"""Strongly-consistent overwatch service (paper §2.iii) — sharded edition.

A linearizable, versioned KV store with CAS, prefix ranges, leases and watches —
the in-process stand-in for the cloud-managed RDBMS the paper assumes (Spanner/
CloudSQL behind the same interface). Every mutation gets a monotonically
increasing revision and lands on an op-log, so reads are trivially serializable
and tests can assert linearizability.

It is HOSTED on the master cluster: remote control agents reach it through the
fabric (gateway channels), so overwatch traffic is part of the measured
cross-boundary byte budget and cluster partitions make it unreachable — exactly
the failure mode the lease-based failure detector exists for.

Leases: registration keys attach to a lease; heartbeats are keepalives. A lease
that misses its TTL expires, its keys are deleted, and watchers (the dispatcher's
failure detector) see the tombstones.

Architecture (the sharding overhaul):

  * ``OverwatchShard`` — one slice of the keyspace: a ``_kv`` dict, a sorted
    ``_keys`` index (``range(prefix)`` is O(log n + |result|) after a lazy
    compaction step — mutations record index edits in O(1) sets and the next
    ``range`` folds them in, so put-heavy workloads never pay the O(n) sorted
    insert), per-shard op counters and first-segment watch buckets. This is
    the old single-store logic, extracted.
  * ``ShardRouter`` — a consistent-hash ring (crc32 over routing segments,
    ``vnodes`` virtual nodes per shard), so each shard owns a contiguous slice
    of the ring and adding shards moves only ~1/N of the segments. The routing
    segment is the first path segment (``/clusters/a`` -> ``clusters``),
    extended to two segments for per-entity namespaces (``/jobs/job-7/status``
    -> ``jobs/job-7``) so the dominant ``/jobs`` keyspace spreads across
    shards instead of hotspotting one. A prefix that pins a complete routing
    segment (``/clusters/...``, ``/jobs/job-7/...``) is served by exactly one
    shard; anything shorter (``/jobs/``) fans out and merges.
  * ``OverwatchService`` — the front-end. It preserves the exact
    ``handle()``/``watch()`` API of the unsharded store (``num_shards=1`` is
    behavior-compatible with the pre-shard implementation), owns the shared
    revision clock, op-log, and lease table, and registers one fabric endpoint
    per shard at ``(ip, port + 1 + shard)`` so clients can route around the
    front-end hop.
  * ``OverwatchReplica`` — a bounded-staleness read replica for telemetry
    consumers: a revision-tagged snapshot maintained from the watch event
    stream. ``range_stale(prefix, max_lag)`` serves from it whenever the
    replica lags the primary by at most ``max_lag`` fabric-clock units and
    catches up (one flush) otherwise; linearizable reads stay on the primary.
    The snapshot machinery lives in ``ReplicaState``, shared with the
    per-cluster ``repro.core.replica.LocalReplica`` (the fan-out overhaul):
    the master ships each cluster one coalesced delta envelope per sweep and
    ``OverwatchClient.range_stale`` serves in-bound reads from the local
    snapshot with ZERO fabric traffic, falling back to this primary-side
    replica only when the local one is out of bound or absent.

Coalesced watch delivery (``coalesce_watches=True``): mutations enqueue
``(event, key, value, rev)`` into per-shard batches instead of firing callbacks
synchronously. Batches flush once per fabric tick (``sweep()``), and on the
dispatcher's read barriers, so a 5k-job recovery storm delivers O(watchers)
batched callbacks instead of O(mutations) synchronous ones. ``watch_batch``
subscribers receive the whole revision-ordered event list in one call;
legacy ``watch`` subscribers still get per-event callbacks (deferred to the
flush). With coalescing off (the default) both kinds fire synchronously per
mutation, exactly like the pre-batching implementation.

Choosing shard counts: shards only pay off once a single store object is both
hot and large — each shard adds one fabric endpoint and (for remote clusters)
one gateway tunnel. 1 shard up to ~100 clusters, 4 shards for the
1024-cluster/50k-job regime benchmarked in ``benchmarks/control_plane.py``;
more than 8 is wasted until multiple masters serve shards from separate
processes (the ROADMAP's multi-master step this refactor enables).
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
import zlib
from collections import Counter
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.transport import (Address, Envelope, Fabric, RingLog,
                                  StaleEpochError)

OVERWATCH_PORT = 7000
OVERWATCH_IP = "10.0.0.2"

# key ops route by req["key"]; everything else is front-end logic
_KEY_OPS = ("put", "get", "delete", "cas")


@dataclasses.dataclass
class Lease:
    lease_id: int
    ttl: float
    expires_at: float
    keys: set


def _first_segment(path: str) -> Optional[str]:
    """``/clusters/onprem-a`` -> ``clusters``; None when there is no full
    leading segment (e.g. ``""`` or ``"/clu"``) and the watcher must stay in
    the catch-all bucket."""
    if not path.startswith("/"):
        return None
    end = path.find("/", 1)
    if end < 0:
        return None
    return path[1:end]


def _sorted_insert(keys: List[str], key: str) -> None:
    i = bisect.bisect_left(keys, key)
    if i == len(keys) or keys[i] != key:
        keys.insert(i, key)


def _sorted_discard(keys: List[str], key: str) -> None:
    i = bisect.bisect_left(keys, key)
    if i < len(keys) and keys[i] == key:
        del keys[i]


# below this many pending index edits, patch the sorted list in place
# (O(t log n + t·n) memmove); above it, one re-sort is cheaper
_COMPACT_THRESHOLD = 32


def _fold_index_edits(keys: List[str], added: set, removed: set) -> List[str]:
    """Fold deferred index edits into a sorted key list (shared by the shard
    and the replica: mutations stay O(1), readers pay amortized compaction).
    Returns the compacted list and clears the edit sets."""
    if added or removed:
        if len(added) + len(removed) <= _COMPACT_THRESHOLD:
            for k in removed:
                _sorted_discard(keys, k)
            for k in added:
                _sorted_insert(keys, k)
        else:
            live = set(keys)
            live -= removed
            live |= added
            keys = sorted(live)
        added.clear()
        removed.clear()
    return keys


def _prefix_slice(keys: List[str], prefix: str) -> Tuple[int, int]:
    """[lo, hi) slice of a sorted key list covered by ``prefix`` (the
    successor-prefix upper bound; empty prefix spans everything)."""
    lo = bisect.bisect_left(keys, prefix)
    if prefix:
        hi = bisect.bisect_left(keys, prefix[:-1] + chr(ord(prefix[-1]) + 1),
                                lo)
    else:
        hi = len(keys)
    return lo, hi


# Namespaces whose second segment joins the routing key: /jobs/<id> is the
# dominant, per-entity keyspace (a placement + status row per job), so routing
# it as one unit would hotspot a single shard with ~98% of the keys. Routing
# /jobs/<id>/... by "jobs/<id>" spreads jobs across shards while keeping each
# job's keys (and any /jobs/<id>/ prefix range or watch) on one shard.
# Part of the client/server wire contract, like the ring parameters.
_DEEP_NAMESPACES = frozenset({"jobs"})


def _route_segment(key: str) -> str:
    """Total routing function: the first path segment — extended to the second
    for ``_DEEP_NAMESPACES`` — or the whole key when it has no internal
    structure (``/cfg`` -> ``cfg``, ``cfg`` -> ``cfg``)."""
    if not key.startswith("/"):
        return key
    end = key.find("/", 1)
    if end < 0:
        return key[1:]
    seg = key[1:end]
    if seg in _DEEP_NAMESPACES:
        end2 = key.find("/", end + 1)
        return key[1:end2] if end2 > 0 else key[1:]
    return seg


class ShardRouter:
    """Consistent-hash ring over first path segments.

    Each shard contributes ``vnodes`` virtual nodes; a segment hashes to the
    next vnode clockwise, so every shard owns a set of contiguous hash-ring
    slices and resizing moves only ~1/N of the segments. crc32 keeps placement
    deterministic across processes (clients compute the same routing without
    asking the server). ``seed`` namespaces the ring so other sharded planes
    (the per-family broker router) reuse the same discipline without their
    placements being correlated with the overwatch's.
    """

    def __init__(self, num_shards: int, vnodes: int = 32,
                 seed: str = "overwatch-shard"):
        # ring parameters are part of the wire contract: OverwatchClient
        # rebuilds this ring from the shard COUNT alone (no topology
        # exchange), so the vnode count and seed-string format below must
        # change in lockstep on both sides — see OverwatchClient.__init__
        self.num_shards = num_shards
        ring: List[Tuple[int, int]] = []
        for s in range(num_shards):
            for v in range(vnodes):
                h = zlib.crc32(f"{seed}-{s}/vnode-{v}".encode())
                ring.append((h & 0xFFFFFFFF, s))
        ring.sort()
        self._ring = ring
        self._hashes = [h for h, _ in ring]
        self._seg_cache: Dict[str, int] = {}

    def shard_for_segment(self, seg: str) -> int:
        s = self._seg_cache.get(seg)
        if s is None:
            if self.num_shards == 1:
                s = 0
            else:
                h = zlib.crc32(seg.encode()) & 0xFFFFFFFF
                i = bisect.bisect_right(self._hashes, h)
                s = self._ring[i % len(self._ring)][1]
            if len(self._seg_cache) < 65536:
                self._seg_cache[seg] = s
        return s

    def shard_for_key(self, key: str) -> int:
        return self.shard_for_segment(_route_segment(key))

    def shard_for_prefix(self, prefix: str) -> Optional[int]:
        """Owning shard when the prefix pins a complete routing segment
        (``/clusters/...``, or ``/jobs/<id>/...`` for deep namespaces); None
        when it straddles shards and must fan out (e.g. ``/jobs/``)."""
        seg = _first_segment(prefix)
        if seg is None:
            return None
        if seg in _DEEP_NAMESPACES:
            end = prefix.find("/", 1)
            end2 = prefix.find("/", end + 1)
            if end2 < 0:
                return None              # e.g. "/jobs/" spans every job shard
            return self.shard_for_segment(prefix[1:end2])
        return self.shard_for_segment(seg)


class OverwatchShard:
    """One slice of the keyspace: kv + sorted index + watch buckets.

    Mutations ``emit`` watch events through the host: synchronously when
    coalescing is off, into ``_pending`` batches when it is on. Watch entries
    are ``(seq, prefix, cb, is_batch)`` — ``seq`` is the host-global
    registration counter, preserving callback order across shards within each
    subscriber kind (see ``OverwatchService.flush_watches`` for the coalesced
    cross-kind ordering).
    """

    def __init__(self, host: "OverwatchService", shard_id: int):
        self.host = host
        self.shard_id = shard_id
        self._kv: Dict[str, Tuple[Any, int]] = {}
        self._keys: List[str] = []           # sorted index over _kv (compacted)
        self._added: set = set()             # index edits since last compaction
        self._removed: set = set()
        self.op_counts: Counter = Counter()  # ops executed on this shard
        self._watch_buckets: Dict[str, List[tuple]] = {}
        self._watch_catchall: List[tuple] = []
        self._pending: List[tuple] = []      # (rev, event, key, value)
        # bound-method table: the hot path skips per-call getattr/concat
        self._ops: Dict[str, Callable[[dict], dict]] = {
            "put": self._op_put, "get": self._op_get,
            "delete": self._op_delete, "cas": self._op_cas,
            "range": self._op_range,
        }

    # ----------------------------------------------------------------- plumbing
    def apply(self, op: str, req: dict) -> dict:
        self.op_counts[op] += 1
        return self._ops[op](req)

    def _index_add(self, key: str) -> None:
        """O(1): mutations never touch the sorted list; ``range`` compacts."""
        self._added.add(key)
        self._removed.discard(key)

    def _index_discard(self, key: str) -> None:
        self._removed.add(key)
        self._added.discard(key)

    def _compact_index(self) -> None:
        self._keys = _fold_index_edits(self._keys, self._added, self._removed)

    # ------------------------------------------------------------------ watches
    def add_watch(self, entry: tuple) -> None:
        seg = _first_segment(entry[1])
        if seg is not None:
            # any key matching this prefix must start with "/<seg>/", so the
            # bucket lookup is exhaustive for it
            self._watch_buckets.setdefault(seg, []).append(entry)
        else:
            self._watch_catchall.append(entry)

    def matched_watchers(self, key: str) -> List[tuple]:
        seg = _first_segment(key)
        matched = [w for w in self._watch_catchall if key.startswith(w[1])]
        if seg is not None:
            matched += [w for w in self._watch_buckets.get(seg, ())
                        if key.startswith(w[1])]
        matched.sort(key=lambda w: w[0])     # registration order, as before
        return matched

    def emit(self, event: str, key: str, value: Any, rev: int) -> None:
        host = self.host
        if host.coalesce_watches:
            self._pending.append((rev, event, key, value))
            host._note_pending()
            return
        stats = host.watch_stats
        for _, _, cb, is_batch in self.matched_watchers(key):
            stats["callbacks"] += 1
            stats["events"] += 1
            if is_batch:
                cb([(event, key, value, rev)])
            else:
                cb(event, key, value, rev)

    def expire_key(self, key: str) -> None:
        """Lease-expiry tombstone: delete + emit, bumped on the shared clock."""
        if key in self._kv:
            del self._kv[key]
            self._index_discard(key)
            rev = self.host._bump("expire", key)
            if self.host._dur is not None:
                self.host._dur.append(self.host._shard_names[self.shard_id],
                                      ("del", key, rev))
            self.emit("delete", key, None, rev)

    # --------------------------------------------------------------------- ops
    def _op_put(self, req: dict) -> dict:
        key, value = req["key"], req["value"]
        lease = None
        if req.get("lease"):
            # validate BEFORE mutating: a rejected put must leave no trace in
            # the kv/revision clock, or the store and the watch-derived views
            # would diverge forever (the error path emits no event)
            lease = self.host._leases.get(req["lease"])
            if lease is None:
                return {"ok": False, "error": "lease expired or unknown"}
        rev = self.host._bump("put", key, value)
        if key not in self._kv:
            self._index_add(key)
        self._kv[key] = (value, rev)
        if lease is not None:
            lease.keys.add(key)
        if self.host._dur is not None:
            self.host._dur.append(self.host._shard_names[self.shard_id],
                                  ("put", key, value, rev, req.get("lease")))
        self.emit("put", key, value, rev)
        return {"ok": True, "revision": rev}

    def _op_get(self, req: dict) -> dict:
        ent = self._kv.get(req["key"])
        if ent is None:
            return {"ok": True, "value": None, "revision": None}
        return {"ok": True, "value": ent[0], "revision": ent[1]}

    def _op_delete(self, req: dict) -> dict:
        key = req["key"]
        if key in self._kv:
            del self._kv[key]
            self._index_discard(key)
            rev = self.host._bump("delete", key)
            if self.host._dur is not None:
                self.host._dur.append(self.host._shard_names[self.shard_id],
                                      ("del", key, rev))
            self.emit("delete", key, None, rev)
            return {"ok": True, "revision": rev}
        return {"ok": True, "revision": None}

    def _op_cas(self, req: dict) -> dict:
        """Compare-and-swap on revision (None => create-if-absent)."""
        key, expect = req["key"], req["expect_revision"]
        ent = self._kv.get(key)
        cur = ent[1] if ent else None
        if cur != expect:
            return {"ok": True, "swapped": False, "revision": cur}
        rev = self.host._bump("cas", key, req["value"])
        if key not in self._kv:
            self._index_add(key)
        self._kv[key] = (req["value"], rev)
        if self.host._dur is not None:
            self.host._dur.append(self.host._shard_names[self.shard_id],
                                  ("put", key, req["value"], rev, None))
        self.emit("put", key, req["value"], rev)
        return {"ok": True, "swapped": True, "revision": rev}

    def _op_range(self, req: dict) -> dict:
        items = self.range_items(req["prefix"])
        return {"ok": True, "items": items}

    def range_items(self, prefix: str) -> Dict[str, Any]:
        self._compact_index()
        lo, hi = _prefix_slice(self._keys, prefix)
        return {k: self._kv[k][0] for k in self._keys[lo:hi]}


class ReplicaState:
    """A revision-tagged snapshot maintained from a watch event stream — the
    shared substrate of the master-side ``OverwatchReplica`` and the
    per-cluster ``repro.core.replica.LocalReplica``. Applying events is O(1)
    per event (the sorted read index folds lazily, like the shard's); applying
    an already-applied event is idempotent, so cumulative re-delivery after a
    channel heal converges without deduplication."""

    def __init__(self):
        self._kv: Dict[str, Any] = {}
        self._keys: List[str] = []
        self._added: set = set()             # lazy index edits, like the shard
        self._removed: set = set()
        self.applied_rev = 0

    def apply_events(self, events: List[tuple]) -> None:
        # O(1) per event: a 100k-event catch-up batch must not pay a sorted
        # insert (O(n) memmove) per key inside the read barrier
        for event, key, value, rev in events:
            if event == "delete":
                if key in self._kv:
                    del self._kv[key]
                    self._removed.add(key)
                    self._added.discard(key)
            else:
                if key not in self._kv:
                    self._added.add(key)
                    self._removed.discard(key)
                self._kv[key] = value
            if rev > self.applied_rev:
                self.applied_rev = rev

    def get(self, key: str) -> Any:
        """Point read off the snapshot (the worker depth-gate path)."""
        return self._kv.get(key)

    def range_items(self, prefix: str) -> Dict[str, Any]:
        self._keys = _fold_index_edits(self._keys, self._added, self._removed)
        lo, hi = _prefix_slice(self._keys, prefix)
        return {k: self._kv[k] for k in self._keys[lo:hi]}


class OverwatchReplica(ReplicaState):
    """Master-side bounded-staleness read replica: kept current by subscribing
    a batch watcher to every shard. With coalescing on it lags the primary by
    at most one flush interval; ``range_stale`` decides whether that lag is
    acceptable or forces a catch-up."""

    def __init__(self, host: "OverwatchService"):
        super().__init__()
        for shard in host.shards:            # host flushed pending beforehand
            for k, (v, rev) in shard._kv.items():
                self._kv[k] = v
        self._keys = sorted(self._kv)
        self.applied_rev = host._rev
        host._register(("", self.apply_events), batch=True)


class OverwatchService:
    """The sharded store's front-end (runs on the master cluster).

    Owns the shared revision clock, op-log, lease table, and watch delivery;
    routes key ops to shards. ``num_shards=1`` with ``coalesce_watches=False``
    (the defaults) reproduces the unsharded, synchronous-notify store exactly.
    """

    def __init__(self, fabric: Fabric, cluster: str,
                 addr: Address = (OVERWATCH_IP, OVERWATCH_PORT),
                 op_log_limit: Optional[int] = None,
                 num_shards: int = 1,
                 coalesce_watches: bool = False,
                 durability=None, snapshot_every: int = 4096):
        self.fabric = fabric
        self.cluster = cluster
        self.addr = addr
        self.coalesce_watches = coalesce_watches
        self._rev = 0
        self.op_log: RingLog = RingLog(op_log_limit)
        self.op_counts: Counter = Counter()  # every handled op, reads included
        self._leases: Dict[int, Lease] = {}
        self._lease_n = 0                    # last granted lease id
        self._expiry_heap: List[Tuple[float, int]] = []
        self._sweeping = False
        # durability (repro.core.durability.LogStore): one WAL shard per kv
        # shard (kv mutations, rev-stamped) plus a meta shard (lease table,
        # lease-id clock). Group commit rides sweep(); snapshot+truncate when
        # a shard's log passes snapshot_every records. None => byte-identical
        # to the in-memory-only store.
        self._dur = durability
        self.snapshot_every = snapshot_every
        self._shard_names = [f"ow-shard-{i}" for i in range(max(1, num_shards))]
        self._meta_name = "ow-meta"
        self.recovery_tail: List[tuple] = []   # replayed events, rev-ordered
        self.recovery_base_rev = 0             # max shard-snapshot rev
        self.recovery_stats: Dict[str, Any] = {}
        # watch registrations: seq preserves global callback ordering across
        # shards and buckets; per-shard buckets bound how many registrations a
        # mutation consults
        self._watch_seq = itertools.count()
        self.watch_stats: Counter = Counter()   # callbacks + events delivered
        self.router = ShardRouter(max(1, num_shards))
        self.shards: List[OverwatchShard] = [
            OverwatchShard(self, i) for i in range(self.router.num_shards)]
        self._pending_since: Optional[float] = None
        self._delivering = False
        self._replica: Optional[OverwatchReplica] = None
        # multi-master fencing: when armed (set_fence), writes to a frozen /
        # failed-over shard bounce with a stale-epoch hint and epoch-stamped
        # requests are checked against the shard map. None (single-master)
        # keeps every path byte-identical to the seed plane.
        self._fence = None
        fabric.register_handler(cluster, addr, self.handle)
        # one endpoint per shard, so shard-aware clients skip the front-end hop
        for i in range(len(self.shards)):
            fabric.register_handler(
                cluster, (addr[0], addr[1] + 1 + i),
                lambda req, _i=i: self._dispatch(req, self.shards[_i]))
        if self._dur is not None and (
                self._dur.has_data(self._meta_name)
                or any(self._dur.has_data(n) for n in self._shard_names)):
            self.recover()

    # ----------------------------------------------------------------- plumbing
    def handle(self, req: dict) -> dict:
        return self._dispatch(req, None)

    def _dispatch(self, req: dict, shard: Optional[OverwatchShard]) -> dict:
        self._sweep_leases()
        op = req["op"]
        self.op_counts[op] += 1
        try:
            if op in _KEY_OPS:
                target = shard if shard is not None else \
                    self.shards[self.router.shard_for_key(req["key"])]
                if self._fence is not None and op != "get":
                    bounce = self._fence_check(req, target)
                    if bounce is not None:
                        return bounce
                return target.apply(op, req)
            if op == "range":
                if shard is None:
                    sid = self.router.shard_for_prefix(req["prefix"])
                    shard = self.shards[sid] if sid is not None else None
                if shard is not None:
                    return shard.apply("range", req)
                return self._range_fanout(req)
            fn = getattr(self, "_op_" + op, None)
            if fn is None:
                return {"ok": False, "error": f"unknown op {op}"}
            return fn(req)
        except Exception as e:              # noqa: BLE001 - surfaced to caller
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}

    def _bump(self, op: str, key: str, value: Any = None) -> int:
        self._rev += 1
        self.op_log.append((self._rev, op, key, value))
        return self._rev

    def _range_fanout(self, req: dict) -> dict:
        """Prefix straddles shards: merge each shard's slice, re-sorted."""
        merged: Dict[str, Any] = {}
        for shard in self.shards:
            merged.update(shard.apply("range", req)["items"])
        return {"ok": True, "items": {k: merged[k] for k in sorted(merged)}}

    # ---------------------------------------------------------- epoch fencing
    def set_fence(self, coordinator) -> None:
        """Arm multi-master fencing (a ``repro.core.shardmap``
        ``ShardMapCoordinator``): writes consult the shard map before
        applying. Reads always serve — a frozen or failing-over shard acts
        as a replica of itself until the flip lands."""
        self._fence = coordinator

    def _fence_check(self, req, shard: "OverwatchShard") -> Optional[dict]:
        """None to proceed, or the bounce response: the shard is frozen
        (mid-migration / owner dead), or the request carries a stale map
        epoch. The bounce piggybacks the CURRENT epoch — the client's map
        refresh costs zero extra round-trips."""
        fence = self._fence
        name = self._shard_names[shard.shard_id]
        cur = fence.map.epoch
        if fence.frozen(name):
            fence.note_stale(name)
            return {"ok": False, "error": "shard frozen (migrating)",
                    "stale_epoch": True, "frozen": True, "epoch": cur}
        e = req.get("epoch")
        if e is not None and e != cur:
            fence.note_stale(name)
            return {"ok": False,
                    "error": f"stale epoch {e} (current {cur})",
                    "stale_epoch": True, "frozen": False, "epoch": cur}
        return None

    # -------------------------------------------------------------------- leases
    def _sweep_leases(self) -> None:
        # watch callbacks can re-enter handle() -> _sweep_leases(); pop each
        # expired lease BEFORE notifying so reentrant sweeps never double-free.
        if self._sweeping:
            return
        now = self.fabric.clock
        heap = self._expiry_heap
        if not heap or heap[0][0] > now:
            return
        self._sweeping = True
        try:
            while heap and heap[0][0] <= now:
                expires_at, lid = heapq.heappop(heap)
                lease = self._leases.get(lid)
                if lease is None or lease.expires_at != expires_at:
                    continue                 # stale entry (keepalive or gone)
                if self._fence is not None and any(
                        self._fence.frozen(self._shard_names[
                            self.router.shard_for_key(k)])
                        for k in lease.keys):
                    # a key's shard is mid-migration: expiring now would
                    # mutate state behind its transferred snapshot. Defer the
                    # WHOLE lease one clock unit (a short grace) — expiry is
                    # delayed past the flip, never lost or half-applied.
                    lease.expires_at = now + 1.0
                    heapq.heappush(heap, (lease.expires_at, lid))
                    continue
                del self._leases[lid]
                if self._dur is not None:
                    self._dur.append(self._meta_name, ("lx", lid))
                for key in sorted(lease.keys):
                    self.shards[self.router.shard_for_key(key)].expire_key(key)
        finally:
            self._sweeping = False

    def _op_lease_grant(self, req: dict) -> dict:
        self._lease_n += 1
        lid = self._lease_n
        ttl = float(req["ttl"])
        expires = self.fabric.clock + ttl
        self._leases[lid] = Lease(lid, ttl, expires, set())
        heapq.heappush(self._expiry_heap, (expires, lid))
        if self._dur is not None:
            self._dur.append(self._meta_name, ("lg", lid, ttl, expires))
        return {"ok": True, "lease": lid}

    def _op_lease_keepalive(self, req: dict) -> dict:
        lease = self._leases.get(req["lease"])
        if lease is None:
            return {"ok": False, "error": "lease expired or unknown"}
        lease.expires_at = self.fabric.clock + lease.ttl
        heapq.heappush(self._expiry_heap, (lease.expires_at, lease.lease_id))
        if self._dur is not None:
            self._dur.append(self._meta_name,
                             ("lk", lease.lease_id, lease.expires_at))
        return {"ok": True}

    # ----------------------------------------------------- topology / replica ops
    def _op_shard_map(self, req: dict) -> dict:
        resp = {"ok": True, "num_shards": len(self.shards),
                "ports": [self.addr[1] + 1 + i
                          for i in range(len(self.shards))]}
        if self._fence is not None:
            resp["epoch"] = self._fence.map.epoch
            resp["assignment"] = dict(self._fence.map.assignment)
            resp["frozen"] = self._fence.frozen_names()
        return resp

    def _op_range_stale(self, req: dict) -> dict:
        """Bounded-staleness range off the replica snapshot. Serves the current
        replica state when its lag is within ``max_lag`` fabric-clock units;
        otherwise catches up (one flush) first. The bound is never silently
        violated: if the catch-up flush cannot run (the caller sits inside an
        active flush, where nested barriers are no-ops) the read falls back to
        the linearizable primary — fresher than asked, never staler."""
        max_lag = float(req.get("max_lag", 0.0))
        prefix = req["prefix"]
        if self._replica is None:
            self.flush_watches()             # snapshot from a quiesced stream
            self._replica = OverwatchReplica(self)
        lag = self._replica_lag()
        if lag > max_lag:
            self.flush_watches()
            lag = self._replica_lag()
        if lag > max_lag:
            sid = self.router.shard_for_prefix(prefix)
            shards = self.shards if sid is None else [self.shards[sid]]
            merged: Dict[str, Any] = {}
            for shard in shards:
                merged.update(shard.range_items(prefix))
            return {"ok": True,
                    "items": {k: merged[k] for k in sorted(merged)},
                    "lag": 0.0, "replica_rev": self._rev}
        items = self._replica.range_items(prefix)
        return {"ok": True, "items": items, "lag": lag,
                "replica_rev": self._replica.applied_rev}

    def _replica_lag(self) -> float:
        if self._pending_since is None:
            return 0.0
        return self.fabric.clock - self._pending_since

    # ------------------------------------------------------------- local watches
    def watch(self, prefix: str, cb: Callable[[str, str, Any, int], None]) -> None:
        """Master-side components subscribe to per-event key callbacks."""
        self._register((prefix, cb), batch=False)

    def watch_batch(self, prefix: str,
                    cb: Callable[[List[tuple]], None]) -> None:
        """Batch subscription: one callback per flush with the revision-ordered
        ``[(event, key, value, rev), ...]`` list (singleton lists when
        coalescing is off)."""
        self._register((prefix, cb), batch=True)

    def _register(self, prefix_cb: tuple, batch: bool) -> None:
        prefix, cb = prefix_cb
        entry = (next(self._watch_seq), prefix, cb, batch)
        sid = self.router.shard_for_prefix(prefix)
        targets = [self.shards[sid]] if sid is not None else self.shards
        for shard in targets:
            shard.add_watch(entry)

    # --------------------------------------------------------- coalesced delivery
    def _note_pending(self) -> None:
        if self._pending_since is None:
            self._pending_since = self.fabric.clock

    def flush_watches(self) -> None:
        """Deliver coalesced batches; the read barrier for view consumers.

        Loops until quiescent: callbacks that mutate (the dispatcher's recovery
        storm) enqueue fresh events that flush in the next round — so a storm
        costs O(watchers x rounds) invocations, not O(mutations). No-op when
        coalescing is off, nothing is pending, or a flush is already running
        (nested barriers fold into the outer loop).

        Delivery order within a round: per-event (legacy ``watch``) subscribers
        fire during the revision-ordered walk, in (rev, seq) order; batch
        subscribers then fire once each, in registration (seq) order, with
        their full event lists. A raising callback does NOT lose events — the
        round finishes delivering to everyone else and the first exception
        re-raises at the barrier (synchronous notify lost at most the
        remaining watchers of one event; losing a whole round would leave the
        watch-derived views divergent forever).
        """
        if not self.coalesce_watches or self._delivering:
            return
        if self._pending_since is None:
            return
        self._delivering = True
        stats = self.watch_stats
        errors: List[BaseException] = []
        try:
            while True:
                merged: List[tuple] = []
                for shard in self.shards:
                    if shard._pending:
                        pend, shard._pending = shard._pending, []
                        for ev in pend:
                            merged.append((ev[0], shard, ev))
                if not merged:
                    self._pending_since = None
                    break
                merged.sort(key=lambda x: x[0])      # global revision order
                batches: Dict[int, Tuple[Callable, list]] = {}
                for rev, shard, (_, event, key, value) in merged:
                    for seq, _, cb, is_batch in shard.matched_watchers(key):
                        if is_batch:
                            if seq not in batches:
                                batches[seq] = (cb, [])
                            batches[seq][1].append((event, key, value, rev))
                        else:
                            stats["callbacks"] += 1
                            stats["events"] += 1
                            try:
                                cb(event, key, value, rev)
                            except Exception as e:   # noqa: BLE001
                                errors.append(e)
                for seq in sorted(batches):
                    cb, events = batches[seq]
                    stats["callbacks"] += 1
                    stats["events"] += len(events)
                    try:
                        cb(events)
                    except Exception as e:           # noqa: BLE001
                        errors.append(e)
        finally:
            self._delivering = False
        if errors:
            if len(errors) > 1:
                raise RuntimeError(
                    f"{len(errors)} watch subscribers failed during flush; "
                    f"first: {errors[0]!r}, also: "
                    f"{[repr(e) for e in errors[1:]]}") from errors[0]
            raise errors[0]

    def sweep(self) -> None:
        self._sweep_leases()
        self.flush_watches()
        if self._dur is not None:
            self._commit_durability()

    # ------------------------------------------------------------- durability
    def _commit_durability(self) -> None:
        """Group commit (once per sweep) + snapshot/truncate compaction when a
        shard's replay tail passes ``snapshot_every`` records."""
        dur = self._dur
        for i, name in enumerate(self._shard_names):
            dur.commit(name)
            if dur.records_since_snapshot(name) >= self.snapshot_every:
                dur.snapshot(name, self._shard_snapshot(i))
        dur.commit(self._meta_name)
        if dur.records_since_snapshot(self._meta_name) >= self.snapshot_every:
            dur.snapshot(self._meta_name, self._meta_snapshot())

    def _shard_snapshot(self, i: int) -> dict:
        """Full shard state: kv with revs, plus this shard's slice of the
        lease->key attachments (kept here, not in the meta snapshot, so a
        fresher shard snapshot never loses attachments recorded only in kv
        records the truncation just dropped)."""
        shard = self.shards[i]
        lease_of = {}
        for lid, lease in self._leases.items():
            for k in lease.keys:
                if k in shard._kv:
                    lease_of[k] = lid
        return {"rev": self._rev,
                "kv": {k: [v, rev] for k, (v, rev) in shard._kv.items()},
                "lease_of": lease_of}

    def _meta_snapshot(self) -> dict:
        return {"rev": self._rev, "next_lease": self._lease_n,
                "leases": {str(lid): [l.ttl, l.expires_at]
                           for lid, l in self._leases.items()}}

    # ------------------------------------------------------- shard migration
    def _carry_over(self, i: int, fresh: "OverwatchShard") -> None:
        """Swap ``shards[i]`` for a rebuilt shard object, carrying the parts
        that belong to the FRONT-END's contract rather than the shard's
        state: watch registrations, undelivered coalesced events, and op
        counters (metrics continuity). The per-shard fabric endpoint closes
        over ``self.shards[i]``, so the swap re-points it automatically."""
        old = self.shards[i]
        fresh._watch_buckets = old._watch_buckets
        fresh._watch_catchall = old._watch_catchall
        fresh._pending = old._pending
        fresh.op_counts = old.op_counts
        self.shards[i] = fresh

    def install_shard(self, i: int, payload: dict) -> None:
        """Live-migration import: a fresh shard built from the transferred
        snapshot payload (``_shard_snapshot`` format). The shard was frozen
        between export and install, so the payload IS the current state —
        watchers see nothing, revisions are unchanged, and lease->key
        attachments are restored from the payload."""
        shard = OverwatchShard(self, i)
        for k, ent in payload["kv"].items():
            shard._kv[k] = (ent[0], ent[1])
        shard._keys = sorted(shard._kv)
        for k, lid in payload["lease_of"].items():
            lease = self._leases.get(int(lid))
            if lease is not None:
                lease.keys.add(k)
        self._carry_over(i, shard)

    def rebuild_shard(self, i: int) -> int:
        """Failover rebuild: the owning master died and its uncommitted WAL
        tail is gone. Rebuild the shard from committed snapshot + records,
        then diff the dying shard's in-memory kv — everything watchers were
        already told — against the durable truth and emit repair events at
        FRESH revisions: a lost put becomes a delete tombstone, a lost
        delete (or lost overwrite) becomes a re-put of the durable value.
        Fresh revs are load-bearing — the replica fan-out dedupes on
        ``rev > applied_rev``, so repairs at reused revisions would be
        silently dropped and cluster replicas would diverge forever. The
        repairs are WAL-appended and committed immediately, so a SECOND
        failover replays a state that already includes them. Returns the
        number of repaired keys."""
        old = self.shards[i]
        name = self._shard_names[i]
        shard = OverwatchShard(self, i)
        kv: Dict[str, Tuple[Any, int]] = {}
        payload, recs = self._dur.load(name)
        if payload:
            for k, ent in payload["kv"].items():
                kv[k] = (ent[0], ent[1])
            for k, lid in payload["lease_of"].items():
                lease = self._leases.get(int(lid))
                if lease is not None:
                    lease.keys.add(k)
        for rec in recs:
            if rec[0] == "put":
                kv[rec[1]] = (rec[2], rec[3])
                if rec[4] is not None:
                    lease = self._leases.get(rec[4])
                    if lease is not None:
                        lease.keys.add(rec[1])
            elif rec[0] == "del":
                kv.pop(rec[1], None)
        shard._kv = kv
        shard._keys = sorted(kv)
        self._carry_over(i, shard)
        repaired = 0
        for key in sorted(set(old._kv) | set(kv)):
            durable = kv.get(key)
            seen = old._kv.get(key)
            if durable is None:
                if seen is None:
                    continue
                # watchers saw a put whose record died with the master
                rev = self._bump("expire", key)
                shard.emit("delete", key, None, rev)
                repaired += 1
            elif seen is None or seen[0] != durable[0]:
                # watchers saw a delete/overwrite the WAL never captured:
                # re-assert the durable value at a fresh revision
                rev = self._bump("put", key, durable[0])
                shard._kv[key] = (durable[0], rev)
                self._dur.append(name, ("put", key, durable[0], rev, None))
                shard.emit("put", key, durable[0], rev)
                repaired += 1
        self._dur.commit(name)
        return repaired

    def recover(self) -> None:
        """Rebuild kv, key indexes, lease table, and the revision clock as
        snapshot + WAL replay. LSN filtering in the LogStore guarantees replay
        starts exactly after each shard's snapshot. Recovered leases get a
        grace extension to ``now + ttl`` so surviving agents (whose heartbeat
        timers never stopped) can keep alive before any expiry sweep runs.
        ``recovery_tail`` keeps the replayed events in revision order — the
        replica shipper's resume feed for clusters whose cumulative-ack
        horizon is at or above ``recovery_base_rev``."""
        dur = self._dur
        replayed = 0
        snapshots = 0
        max_rev = 0
        lease_n = 0
        leases: Dict[int, Lease] = {}
        meta_p, meta_recs = dur.load(self._meta_name)
        if meta_p:
            snapshots += 1
            max_rev = meta_p["rev"]
            lease_n = meta_p["next_lease"]
            for lid, (ttl, exp) in meta_p["leases"].items():
                lid = int(lid)
                leases[lid] = Lease(lid, ttl, exp, set())
        for rec in meta_recs:
            replayed += 1
            tag = rec[0]
            if tag == "lg":
                lid = rec[1]
                leases[lid] = Lease(lid, rec[2], rec[3], set())
                lease_n = max(lease_n, lid)
            elif tag == "lk":
                lease = leases.get(rec[1])
                if lease is not None:
                    lease.expires_at = rec[2]
            elif tag == "lx":
                leases.pop(rec[1], None)
        tail: List[tuple] = []
        base_rev = 0
        for i, name in enumerate(self._shard_names):
            shard = self.shards[i]
            payload, recs = dur.load(name)
            if payload:
                snapshots += 1
                base_rev = max(base_rev, payload["rev"])
                max_rev = max(max_rev, payload["rev"])
                for k, ent in payload["kv"].items():
                    shard._kv[k] = (ent[0], ent[1])
                for k, lid in payload["lease_of"].items():
                    lease = leases.get(int(lid))
                    if lease is not None:
                        lease.keys.add(k)
            for rec in recs:
                replayed += 1
                if rec[0] == "put":
                    _, key, value, rev, lid = rec
                    shard._kv[key] = (value, rev)
                    if lid is not None:
                        lease = leases.get(lid)
                        if lease is not None:
                            lease.keys.add(key)
                    tail.append(("put", key, value, rev))
                    max_rev = max(max_rev, rev)
                elif rec[0] == "del":
                    _, key, rev = rec
                    shard._kv.pop(key, None)
                    tail.append(("delete", key, None, rev))
                    max_rev = max(max_rev, rev)
            shard._keys = sorted(shard._kv)
            shard._added.clear()
            shard._removed.clear()
        self._rev = max(self._rev, max_rev)
        self._lease_n = max(self._lease_n, lease_n)
        now = self.fabric.clock
        for lease in leases.values():
            lease.expires_at = max(lease.expires_at, now + lease.ttl)
            heapq.heappush(self._expiry_heap, (lease.expires_at,
                                               lease.lease_id))
        self._leases = leases
        tail.sort(key=lambda ev: ev[3])
        self.recovery_tail = tail
        self.recovery_base_rev = base_rev
        self.recovery_stats = {"replayed": replayed, "snapshots": snapshots,
                               "leases": len(leases), "rev": self._rev}


class OverwatchClient:
    """RPC stub: every call crosses the fabric from ``src_cluster`` to master.

    Shard-aware when given per-shard targets: key ops and single-segment prefix
    ranges go straight to the owning shard's endpoint (``shard_addrs``, for
    master-local clients) or tunnel (``shard_vias``, for remote clusters);
    lease ops and fan-out ranges use the front-end. Without shard targets the
    client behaves exactly like the unsharded original.

    Replica-aware when given a per-cluster ``replica`` (the fan-out overhaul):
    ``range_stale`` is served straight from the local snapshot — zero fabric
    traffic — whenever the replica covers the prefix and its shipped-batch lag
    is within the caller's ``max_lag``; otherwise the read falls back to the
    primary round-trip exactly as before. All other ops (linearizable reads,
    every mutation, leases) always cross to the primary.
    """

    def __init__(self, fabric: Fabric, src_cluster: str, src_id: str,
                 master_cluster: str,
                 addr: Address = (OVERWATCH_IP, OVERWATCH_PORT),
                 via: Optional[Address] = None,
                 shard_addrs: Optional[List[Address]] = None,
                 shard_vias: Optional[List[Address]] = None,
                 replica=None):
        self.fabric = fabric
        self.src_cluster = src_cluster
        self.src_id = src_id
        self.master_cluster = master_cluster
        self.addr = addr
        # remote agents reach the overwatch through their egress gateway mapping
        self.via = via
        self.shard_addrs = shard_addrs
        self.shard_vias = shard_vias
        self.replica = replica          # repro.core.replica.LocalReplica
        # default ring parameters MUST match the service's (wire contract —
        # the client derives placement from the shard count alone)
        n = len(shard_addrs or shard_vias or ())
        self._router = ShardRouter(n) if n > 1 else None
        # multi-master epoch fencing (armed by the plane when a shard-map
        # coordinator exists): writes carry the client's map epoch; a bounce
        # piggybacks the current epoch (the "map refresh") and the write
        # retries once — unless the shard is FROZEN, where an in-instant
        # retry cannot succeed (the simulation is synchronous) and the
        # caller gets a StaleEpochError to retry next tick.
        self.fenced = False
        self._epoch = 0
        self.stats: Counter = Counter()

    def _route(self, req: dict) -> Tuple[str, Address]:
        """(dest_cluster, dest_addr) for this request — shard endpoint for key
        ops when shard routing is configured, front-end otherwise."""
        local = self.src_cluster == self.master_cluster
        if self._router is not None:
            op = req["op"]
            sid: Optional[int] = None
            if op in _KEY_OPS:
                sid = self._router.shard_for_key(req["key"])
            elif op == "range":
                sid = self._router.shard_for_prefix(req["prefix"])
            if sid is not None:
                if local and self.shard_addrs:
                    return self.master_cluster, self.shard_addrs[sid]
                if not local and self.shard_vias:
                    return self.src_cluster, self.shard_vias[sid]
        if local:
            return self.master_cluster, self.addr
        if self.via is None:
            raise RuntimeError(
                "remote overwatch access requires a gateway route (via=)")
        return self.src_cluster, self.via

    # bounded fence retries: stamp -> bounce -> refresh -> restamp -> retry.
    # Two refreshes cover a flip landing between the retry's send and apply.
    _FENCE_ATTEMPTS = 3

    def _call(self, req: dict) -> dict:
        if not self.fenced:
            dst_cluster, dst_addr = self._route(req)
            resp = self.fabric.send(self.src_cluster, self.src_id,
                                    dst_cluster, dst_addr, req)
            if not resp.get("ok", False):
                raise RuntimeError(f"overwatch: {resp.get('error')}")
            return resp
        # epoch-stamp plain-dict writes only: prebuilt Envelopes cache their
        # byte size and must not be mutated (they rely on the server-side
        # frozen check alone — a bounce surfaces as StaleEpochError and the
        # caller's next-tick retry rebuilds the request)
        stamped = (not isinstance(req, Envelope)
                   and req.get("op") in ("put", "delete", "cas"))
        if stamped:
            req["epoch"] = self._epoch
        for _ in range(self._FENCE_ATTEMPTS):
            dst_cluster, dst_addr = self._route(req)
            resp = self.fabric.send(self.src_cluster, self.src_id,
                                    dst_cluster, dst_addr, req)
            if resp.get("ok", False):
                return resp
            if not resp.get("stale_epoch"):
                raise RuntimeError(f"overwatch: {resp.get('error')}")
            self.stats["stale_epoch_bounces"] += 1
            self._epoch = int(resp.get("epoch", self._epoch))
            if resp.get("frozen") or not stamped:
                break            # frozen shards only thaw on a later tick
            req["epoch"] = self._epoch
            self.stats["stale_epoch_retries"] += 1
        raise StaleEpochError(
            f"overwatch {req.get('op')}: fenced at epoch {self._epoch} "
            f"(shard frozen or map moved); retry next tick")

    def request(self, req: dict) -> dict:
        """Send a pre-built request — the hook for hot callers that reuse a
        precomputed ``Envelope`` size (e.g. the agent's fixed-shape telemetry
        heartbeat) so the fabric never re-walks the value dict."""
        return self._call(req)

    def put(self, key: str, value: Any, lease: Optional[int] = None) -> int:
        return self._call({"op": "put", "key": key, "value": value,
                           "lease": lease})["revision"]

    def get(self, key: str) -> Any:
        return self._call({"op": "get", "key": key})["value"]

    def get_with_revision(self, key: str):
        r = self._call({"op": "get", "key": key})
        return r["value"], r["revision"]

    def delete(self, key: str) -> None:
        self._call({"op": "delete", "key": key})

    def cas(self, key: str, value: Any, expect_revision) -> bool:
        return self._call({"op": "cas", "key": key, "value": value,
                           "expect_revision": expect_revision})["swapped"]

    def range(self, prefix: str) -> Dict[str, Any]:
        return self._call({"op": "range", "prefix": prefix})["items"]

    def range_stale(self, prefix: str, max_lag: float) -> Dict[str, Any]:
        """Bounded-staleness range (telemetry path): the local per-cluster
        replica when it covers the prefix within ``max_lag``, else the
        primary's read replica over the fabric. A read that had a covering
        replica but found it out of bound (ships stopped) is counted in
        ``fabric.stats["fallback_reads"]`` — the locality benchmark asserts
        these stay rare instead of letting them hide in total cross-bytes."""
        rep = self.replica
        if rep is not None and rep.covers(prefix):
            if rep.lag(self.fabric.clock) <= max_lag:
                return rep.range_items(prefix)
            self.fabric.stats["fallback_reads"] += 1
        return self._call({"op": "range_stale", "prefix": prefix,
                           "max_lag": max_lag})["items"]

    def lease_grant(self, ttl: float) -> int:
        return self._call({"op": "lease_grant", "ttl": ttl})["lease"]

    def lease_keepalive(self, lease: int) -> None:
        self._call({"op": "lease_keepalive", "lease": lease})
