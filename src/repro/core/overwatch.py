"""Strongly-consistent overwatch service (paper §2.iii).

A linearizable, versioned KV store with CAS, prefix ranges, leases and watches —
the in-process stand-in for the cloud-managed RDBMS the paper assumes (Spanner/
CloudSQL behind the same interface). Every mutation gets a monotonically
increasing revision and lands on an op-log, so reads are trivially serializable
and tests can assert linearizability.

It is HOSTED on the master cluster: remote control agents reach it through the
fabric (gateway channels), so overwatch traffic is part of the measured
cross-boundary byte budget and cluster partitions make it unreachable — exactly
the failure mode the lease-based failure detector exists for.

Leases: registration keys attach to a lease; heartbeats are keepalives. A lease
that misses its TTL expires, its keys are deleted, and watchers (the dispatcher's
failure detector) see the tombstones.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.transport import Address, Fabric

OVERWATCH_PORT = 7000
OVERWATCH_IP = "10.0.0.2"


@dataclasses.dataclass
class Lease:
    lease_id: int
    ttl: float
    expires_at: float
    keys: set


class OverwatchService:
    """The store itself (runs on the master cluster)."""

    def __init__(self, fabric: Fabric, cluster: str,
                 addr: Address = (OVERWATCH_IP, OVERWATCH_PORT)):
        self.fabric = fabric
        self.cluster = cluster
        self.addr = addr
        self._kv: Dict[str, Tuple[Any, int]] = {}
        self._rev = 0
        self.op_log: List[tuple] = []
        self._leases: Dict[int, Lease] = {}
        self._lease_ids = itertools.count(1)
        self._watches: List[Tuple[str, Callable]] = []
        fabric.register_handler(cluster, addr, self.handle)

    # ----------------------------------------------------------------------- plumbing
    def handle(self, req: dict) -> dict:
        self._sweep_leases()
        op = req["op"]
        fn = getattr(self, "_op_" + op, None)
        if fn is None:
            return {"ok": False, "error": f"unknown op {op}"}
        try:
            return fn(req)
        except Exception as e:              # noqa: BLE001 - surfaced to caller
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}

    def _bump(self, op: str, key: str, value: Any = None) -> int:
        self._rev += 1
        self.op_log.append((self._rev, op, key, value))
        return self._rev

    def _notify(self, event: str, key: str, value: Any, rev: int) -> None:
        for prefix, cb in self._watches:
            if key.startswith(prefix):
                cb(event, key, value, rev)

    def _sweep_leases(self) -> None:
        # _notify callbacks can re-enter handle() -> _sweep_leases(); pop each
        # expired lease BEFORE notifying so reentrant sweeps never double-free.
        if getattr(self, "_sweeping", False):
            return
        self._sweeping = True
        try:
            now = self.fabric.clock
            for lid in list(self._leases):
                lease = self._leases.get(lid)
                if lease is None or lease.expires_at > now:
                    continue
                del self._leases[lid]
                for key in sorted(lease.keys):
                    if key in self._kv:
                        del self._kv[key]
                        rev = self._bump("expire", key)
                        self._notify("delete", key, None, rev)
        finally:
            self._sweeping = False

    # --------------------------------------------------------------------------- ops
    def _op_put(self, req: dict) -> dict:
        key, value = req["key"], req["value"]
        rev = self._bump("put", key, value)
        self._kv[key] = (value, rev)
        if "lease" in req and req["lease"]:
            lease = self._leases.get(req["lease"])
            if lease is None:
                return {"ok": False, "error": "lease expired or unknown"}
            lease.keys.add(key)
        self._notify("put", key, value, rev)
        return {"ok": True, "revision": rev}

    def _op_get(self, req: dict) -> dict:
        ent = self._kv.get(req["key"])
        if ent is None:
            return {"ok": True, "value": None, "revision": None}
        return {"ok": True, "value": ent[0], "revision": ent[1]}

    def _op_delete(self, req: dict) -> dict:
        key = req["key"]
        if key in self._kv:
            del self._kv[key]
            rev = self._bump("delete", key)
            self._notify("delete", key, None, rev)
            return {"ok": True, "revision": rev}
        return {"ok": True, "revision": None}

    def _op_cas(self, req: dict) -> dict:
        """Compare-and-swap on revision (None => create-if-absent)."""
        key, expect = req["key"], req["expect_revision"]
        ent = self._kv.get(key)
        cur = ent[1] if ent else None
        if cur != expect:
            return {"ok": True, "swapped": False, "revision": cur}
        rev = self._bump("cas", key, req["value"])
        self._kv[key] = (req["value"], rev)
        self._notify("put", key, req["value"], rev)
        return {"ok": True, "swapped": True, "revision": rev}

    def _op_range(self, req: dict) -> dict:
        prefix = req["prefix"]
        items = {k: v for k, (v, _) in sorted(self._kv.items())
                 if k.startswith(prefix)}
        return {"ok": True, "items": items}

    def _op_lease_grant(self, req: dict) -> dict:
        lid = next(self._lease_ids)
        ttl = float(req["ttl"])
        self._leases[lid] = Lease(lid, ttl, self.fabric.clock + ttl, set())
        return {"ok": True, "lease": lid}

    def _op_lease_keepalive(self, req: dict) -> dict:
        lease = self._leases.get(req["lease"])
        if lease is None:
            return {"ok": False, "error": "lease expired or unknown"}
        lease.expires_at = self.fabric.clock + lease.ttl
        return {"ok": True}

    # ------------------------------------------------------------- local-side watches
    def watch(self, prefix: str, cb: Callable[[str, str, Any, int], None]) -> None:
        """Master-side components (dispatcher) subscribe to key events."""
        self._watches.append((prefix, cb))

    def sweep(self) -> None:
        self._sweep_leases()


class OverwatchClient:
    """RPC stub: every call crosses the fabric from ``src_cluster`` to master."""

    def __init__(self, fabric: Fabric, src_cluster: str, src_id: str,
                 master_cluster: str,
                 addr: Address = (OVERWATCH_IP, OVERWATCH_PORT),
                 via: Optional[Address] = None):
        self.fabric = fabric
        self.src_cluster = src_cluster
        self.src_id = src_id
        self.master_cluster = master_cluster
        self.addr = addr
        # remote agents reach the overwatch through their egress gateway mapping
        self.via = via

    def _call(self, req: dict) -> dict:
        if self.src_cluster == self.master_cluster:
            resp = self.fabric.send(self.src_cluster, self.src_id,
                                    self.master_cluster, self.addr, req)
        else:
            if self.via is None:
                raise RuntimeError(
                    "remote overwatch access requires a gateway route (via=)")
            resp = self.fabric.send(self.src_cluster, self.src_id,
                                    self.src_cluster, self.via, req)
        if not resp.get("ok", False):
            raise RuntimeError(f"overwatch: {resp.get('error')}")
        return resp

    def put(self, key: str, value: Any, lease: Optional[int] = None) -> int:
        return self._call({"op": "put", "key": key, "value": value,
                           "lease": lease})["revision"]

    def get(self, key: str) -> Any:
        return self._call({"op": "get", "key": key})["value"]

    def get_with_revision(self, key: str):
        r = self._call({"op": "get", "key": key})
        return r["value"], r["revision"]

    def delete(self, key: str) -> None:
        self._call({"op": "delete", "key": key})

    def cas(self, key: str, value: Any, expect_revision) -> bool:
        return self._call({"op": "cas", "key": key, "value": value,
                           "expect_revision": expect_revision})["swapped"]

    def range(self, prefix: str) -> Dict[str, Any]:
        return self._call({"op": "range", "prefix": prefix})["items"]

    def lease_grant(self, ttl: float) -> int:
        return self._call({"op": "lease_grant", "ttl": ttl})["lease"]

    def lease_keepalive(self, lease: int) -> None:
        self._call({"op": "lease_keepalive", "lease": lease})
