"""Strongly-consistent overwatch service (paper §2.iii).

A linearizable, versioned KV store with CAS, prefix ranges, leases and watches —
the in-process stand-in for the cloud-managed RDBMS the paper assumes (Spanner/
CloudSQL behind the same interface). Every mutation gets a monotonically
increasing revision and lands on an op-log, so reads are trivially serializable
and tests can assert linearizability.

It is HOSTED on the master cluster: remote control agents reach it through the
fabric (gateway channels), so overwatch traffic is part of the measured
cross-boundary byte budget and cluster partitions make it unreachable — exactly
the failure mode the lease-based failure detector exists for.

Leases: registration keys attach to a lease; heartbeats are keepalives. A lease
that misses its TTL expires, its keys are deleted, and watchers (the dispatcher's
failure detector) see the tombstones.

Hot-path data structures (the scaling overhaul):
  * ``_keys`` — a sorted list of live keys maintained with ``bisect``, so
    ``range(prefix)`` is O(log n + |result|) instead of sorting the whole
    keyspace per call;
  * watch buckets — watchers are indexed by the first path segment of their
    prefix, so a mutation only consults the watchers that could possibly match
    instead of scanning every registration;
  * ``_expiry_heap`` — a lazy-deletion min-heap of (expires_at, lease_id), so
    the per-``handle()`` lease sweep is O(1) when nothing is due instead of
    O(#leases).
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
from collections import Counter
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.transport import Address, Fabric, RingLog

OVERWATCH_PORT = 7000
OVERWATCH_IP = "10.0.0.2"


@dataclasses.dataclass
class Lease:
    lease_id: int
    ttl: float
    expires_at: float
    keys: set


def _first_segment(path: str) -> Optional[str]:
    """``/clusters/onprem-a`` -> ``clusters``; None when there is no full
    leading segment (e.g. ``""`` or ``"/clu"``) and the watcher must stay in
    the catch-all bucket."""
    if not path.startswith("/"):
        return None
    end = path.find("/", 1)
    if end < 0:
        return None
    return path[1:end]


class OverwatchService:
    """The store itself (runs on the master cluster)."""

    def __init__(self, fabric: Fabric, cluster: str,
                 addr: Address = (OVERWATCH_IP, OVERWATCH_PORT),
                 op_log_limit: Optional[int] = None):
        self.fabric = fabric
        self.cluster = cluster
        self.addr = addr
        self._kv: Dict[str, Tuple[Any, int]] = {}
        self._keys: List[str] = []           # sorted index over _kv
        self._rev = 0
        self.op_log: RingLog = RingLog(op_log_limit)
        self.op_counts: Counter = Counter()  # every handled op, reads included
        self._leases: Dict[int, Lease] = {}
        self._lease_ids = itertools.count(1)
        self._expiry_heap: List[Tuple[float, int]] = []
        # watch registrations: seq preserves global callback ordering across
        # buckets, buckets bound how many registrations a mutation consults
        self._watch_seq = itertools.count()
        self._watch_buckets: Dict[str, List[Tuple[int, str, Callable]]] = {}
        self._watch_catchall: List[Tuple[int, str, Callable]] = []
        fabric.register_handler(cluster, addr, self.handle)

    # ----------------------------------------------------------------------- plumbing
    def handle(self, req: dict) -> dict:
        self._sweep_leases()
        op = req["op"]
        self.op_counts[op] += 1
        fn = getattr(self, "_op_" + op, None)
        if fn is None:
            return {"ok": False, "error": f"unknown op {op}"}
        try:
            return fn(req)
        except Exception as e:              # noqa: BLE001 - surfaced to caller
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}

    def _bump(self, op: str, key: str, value: Any = None) -> int:
        self._rev += 1
        self.op_log.append((self._rev, op, key, value))
        return self._rev

    def _index_add(self, key: str) -> None:
        i = bisect.bisect_left(self._keys, key)
        if i == len(self._keys) or self._keys[i] != key:
            self._keys.insert(i, key)

    def _index_discard(self, key: str) -> None:
        i = bisect.bisect_left(self._keys, key)
        if i < len(self._keys) and self._keys[i] == key:
            del self._keys[i]

    def _notify(self, event: str, key: str, value: Any, rev: int) -> None:
        seg = _first_segment(key)
        matched = [w for w in self._watch_catchall if key.startswith(w[1])]
        if seg is not None:
            matched += [w for w in self._watch_buckets.get(seg, ())
                        if key.startswith(w[1])]
        matched.sort(key=lambda w: w[0])     # registration order, as before
        for _, _, cb in matched:
            cb(event, key, value, rev)

    def _sweep_leases(self) -> None:
        # _notify callbacks can re-enter handle() -> _sweep_leases(); pop each
        # expired lease BEFORE notifying so reentrant sweeps never double-free.
        if getattr(self, "_sweeping", False):
            return
        now = self.fabric.clock
        heap = self._expiry_heap
        if not heap or heap[0][0] > now:
            return
        self._sweeping = True
        try:
            while heap and heap[0][0] <= now:
                expires_at, lid = heapq.heappop(heap)
                lease = self._leases.get(lid)
                if lease is None or lease.expires_at != expires_at:
                    continue                 # stale entry (keepalive or gone)
                del self._leases[lid]
                for key in sorted(lease.keys):
                    if key in self._kv:
                        del self._kv[key]
                        self._index_discard(key)
                        rev = self._bump("expire", key)
                        self._notify("delete", key, None, rev)
        finally:
            self._sweeping = False

    # --------------------------------------------------------------------------- ops
    def _op_put(self, req: dict) -> dict:
        key, value = req["key"], req["value"]
        rev = self._bump("put", key, value)
        if key not in self._kv:
            self._index_add(key)
        self._kv[key] = (value, rev)
        if "lease" in req and req["lease"]:
            lease = self._leases.get(req["lease"])
            if lease is None:
                return {"ok": False, "error": "lease expired or unknown"}
            lease.keys.add(key)
        self._notify("put", key, value, rev)
        return {"ok": True, "revision": rev}

    def _op_get(self, req: dict) -> dict:
        ent = self._kv.get(req["key"])
        if ent is None:
            return {"ok": True, "value": None, "revision": None}
        return {"ok": True, "value": ent[0], "revision": ent[1]}

    def _op_delete(self, req: dict) -> dict:
        key = req["key"]
        if key in self._kv:
            del self._kv[key]
            self._index_discard(key)
            rev = self._bump("delete", key)
            self._notify("delete", key, None, rev)
            return {"ok": True, "revision": rev}
        return {"ok": True, "revision": None}

    def _op_cas(self, req: dict) -> dict:
        """Compare-and-swap on revision (None => create-if-absent)."""
        key, expect = req["key"], req["expect_revision"]
        ent = self._kv.get(key)
        cur = ent[1] if ent else None
        if cur != expect:
            return {"ok": True, "swapped": False, "revision": cur}
        rev = self._bump("cas", key, req["value"])
        if key not in self._kv:
            self._index_add(key)
        self._kv[key] = (req["value"], rev)
        self._notify("put", key, req["value"], rev)
        return {"ok": True, "swapped": True, "revision": rev}

    def _op_range(self, req: dict) -> dict:
        prefix = req["prefix"]
        lo = bisect.bisect_left(self._keys, prefix)
        if prefix:
            hi = bisect.bisect_left(self._keys, prefix[:-1] +
                                    chr(ord(prefix[-1]) + 1), lo)
        else:
            hi = len(self._keys)
        items = {k: self._kv[k][0] for k in self._keys[lo:hi]}
        return {"ok": True, "items": items}

    def _op_lease_grant(self, req: dict) -> dict:
        lid = next(self._lease_ids)
        ttl = float(req["ttl"])
        expires = self.fabric.clock + ttl
        self._leases[lid] = Lease(lid, ttl, expires, set())
        heapq.heappush(self._expiry_heap, (expires, lid))
        return {"ok": True, "lease": lid}

    def _op_lease_keepalive(self, req: dict) -> dict:
        lease = self._leases.get(req["lease"])
        if lease is None:
            return {"ok": False, "error": "lease expired or unknown"}
        lease.expires_at = self.fabric.clock + lease.ttl
        heapq.heappush(self._expiry_heap, (lease.expires_at, lease.lease_id))
        return {"ok": True}

    # ------------------------------------------------------------- local-side watches
    def watch(self, prefix: str, cb: Callable[[str, str, Any, int], None]) -> None:
        """Master-side components (dispatcher) subscribe to key events."""
        entry = (next(self._watch_seq), prefix, cb)
        seg = _first_segment(prefix)
        if seg is not None:
            # any key matching this prefix must start with "/<seg>/", so the
            # bucket lookup is exhaustive for it
            self._watch_buckets.setdefault(seg, []).append(entry)
        else:
            self._watch_catchall.append(entry)

    def sweep(self) -> None:
        self._sweep_leases()


class OverwatchClient:
    """RPC stub: every call crosses the fabric from ``src_cluster`` to master."""

    def __init__(self, fabric: Fabric, src_cluster: str, src_id: str,
                 master_cluster: str,
                 addr: Address = (OVERWATCH_IP, OVERWATCH_PORT),
                 via: Optional[Address] = None):
        self.fabric = fabric
        self.src_cluster = src_cluster
        self.src_id = src_id
        self.master_cluster = master_cluster
        self.addr = addr
        # remote agents reach the overwatch through their egress gateway mapping
        self.via = via

    def _call(self, req: dict) -> dict:
        if self.src_cluster == self.master_cluster:
            resp = self.fabric.send(self.src_cluster, self.src_id,
                                    self.master_cluster, self.addr, req)
        else:
            if self.via is None:
                raise RuntimeError(
                    "remote overwatch access requires a gateway route (via=)")
            resp = self.fabric.send(self.src_cluster, self.src_id,
                                    self.src_cluster, self.via, req)
        if not resp.get("ok", False):
            raise RuntimeError(f"overwatch: {resp.get('error')}")
        return resp

    def put(self, key: str, value: Any, lease: Optional[int] = None) -> int:
        return self._call({"op": "put", "key": key, "value": value,
                           "lease": lease})["revision"]

    def get(self, key: str) -> Any:
        return self._call({"op": "get", "key": key})["value"]

    def get_with_revision(self, key: str):
        r = self._call({"op": "get", "key": key})
        return r["value"], r["revision"]

    def delete(self, key: str) -> None:
        self._call({"op": "delete", "key": key})

    def cas(self, key: str, value: Any, expect_revision) -> bool:
        return self._call({"op": "cas", "key": key, "value": value,
                           "expect_revision": expect_revision})["swapped"]

    def range(self, prefix: str) -> Dict[str, Any]:
        return self._call({"op": "range", "prefix": prefix})["items"]

    def lease_grant(self, ttl: float) -> int:
        return self._call({"op": "lease_grant", "ttl": ttl})["lease"]

    def lease_keepalive(self, lease: int) -> None:
        self._call({"op": "lease_keepalive", "lease": lease})
