"""Pod-Service dependency model (paper §4): f : P x S -> {0, 1}, host_cluster[s].

The CRD the user uploads (paper: a Kubernetes CRD broadcast to every control agent)
is an ``AppSpec``: services with stable ports, pods with the services they must
reach, and a partition map pods -> cluster. Validation enforces the paper's
partitioning restriction: all pods backing a service land in one partition, i.e.
``host_cluster[s]`` is unique.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Service:
    name: str
    port: int
    backing_pods: Tuple[str, ...]          # pods that BACK (serve) this service


@dataclasses.dataclass(frozen=True)
class Pod:
    name: str
    needs: Tuple[str, ...]                 # services this pod must reach: f[p,s]=1


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """The CRD object: the full Pod-Service dependency graph + partitioning."""
    services: Tuple[Service, ...]
    pods: Tuple[Pod, ...]
    partition: Dict[str, str]              # pod name -> cluster name

    # ------------------------------------------------------------------ validation
    def validate(self, clusters: List[str]) -> None:
        pod_names = {p.name for p in self.pods}
        svc_names = {s.name for s in self.services}
        if len(pod_names) != len(self.pods):
            raise ValueError("duplicate pod names")
        if len(svc_names) != len(self.services):
            raise ValueError("duplicate service names")
        for p in self.pods:
            for s in p.needs:
                if s not in svc_names:
                    raise ValueError(f"pod {p.name} needs unknown service {s}")
        for s in self.services:
            for b in s.backing_pods:
                if b not in pod_names:
                    raise ValueError(f"service {s.name} backed by unknown pod {b}")
            hosts = {self.partition[b] for b in s.backing_pods}
            if len(hosts) != 1:
                raise ValueError(
                    f"service {s.name} backed from {sorted(hosts)}; the paper "
                    "requires a unique host_cluster[s]")
        for pod, cluster in self.partition.items():
            if pod not in pod_names:
                raise ValueError(f"partition names unknown pod {pod}")
            if cluster not in clusters:
                raise ValueError(f"partition places {pod} on unknown {cluster}")
        missing = pod_names - set(self.partition)
        if missing:
            raise ValueError(f"pods without a partition: {sorted(missing)}")

    # --------------------------------------------------------------------- queries
    def f(self, pod: str, service: str) -> bool:
        for p in self.pods:
            if p.name == pod:
                return service in p.needs
        return False

    def host_cluster(self, service: str) -> str:
        s = self.service(service)
        return self.partition[s.backing_pods[0]]

    def service(self, name: str) -> Service:
        for s in self.services:
            if s.name == name:
                return s
        raise KeyError(name)

    def pods_of_cluster(self, cluster: str) -> List[Pod]:
        return [p for p in self.pods if self.partition[p.name] == cluster]

    def pods_needing(self, service: str) -> List[str]:
        """P(s) — pods with f[p, s] = 1."""
        return [p.name for p in self.pods if service in p.needs]

    def external_consumers(self, service: str) -> FrozenSet[str]:
        """Clusters (other than the host) containing pods that need the service."""
        host = self.host_cluster(service)
        return frozenset(self.partition[p] for p in self.pods_needing(service)
                         if self.partition[p] != host)
