"""Durability layer: append-only per-shard write-ahead log + snapshots.

The global plane's stores (overwatch shards, lease table, broker shards,
taskdb) are in-process state — a master crash without this layer loses the
world. ``LogStore`` gives every store the same crash-survival contract:

  WAL record format
    A *record* is any JSON-able value (the stores append small tuples such as
    ``("put", key, value, rev, lease)`` or ``("pushN", queue, msgs, flag)``).
    The LogStore assigns each committed record a per-shard, monotonically
    increasing **LSN** starting at 1; the backend persists ``(lsn, record)``
    pairs. Records buffered by ``append()`` are NOT durable until
    ``commit(shard)`` runs — group commit: the overwatch commits on
    ``sweep()``, the composer commits once per tick (taskdb before brokers,
    so effects are always at least as durable as the acknowledgments that
    reference them). A crash loses exactly the uncommitted tail
    (``lose_uncommitted()`` models this in the chaos harness).

  Snapshot + truncate compaction
    ``snapshot(shard, payload)`` persists a full-state payload stamped with
    ``base_lsn`` = the shard's last committed LSN, then truncates every WAL
    record with ``lsn <= base_lsn``. ``load(shard)`` returns
    ``(payload | None, records)`` where *records* are exactly the committed
    records **after** the snapshot — replay is therefore never double-applied
    over snapshotted state, which keeps recovery correct even for stores
    whose replay is not idempotent (the broker's pull/ack stream).

  Recovery invariants
    1. Everything committed before the crash is visible after ``load()``.
    2. Nothing uncommitted survives: the loss window is exactly one group
       commit (one sweep / one composer tick).
    3. ``snapshot ∘ load`` is the identity on committed state: compaction
       never changes what recovery rebuilds, only how many records replay.

  Backends
    ``MemoryBackend`` (default) keeps everything in process — it survives a
    *simulated* crash (the chaos harness kills the services, not the Python
    process) and is what the deterministic tests/benchmarks use. Records are
    held by reference; the plane treats values as immutable after append,
    matching the overwatch's own value convention. ``DirBackend`` persists
    for real: one ``<shard>.wal`` JSONL file (fsync'd per group commit, torn
    trailing lines tolerated on load) plus one ``<shard>.snap.json`` written
    temp-then-atomic-rename. JSON round-trips tuples as lists, so recovery
    code treats record fields positionally and never by tuple identity.

  Fault injection
    ``fault_hook(site, shard)`` — when set (see ``repro.core.faults``) it is
    invoked at ``("commit", shard)`` / ``("snapshot", shard)`` boundaries
    *before* the persistence happens, so a scripted ``FaultPlan`` can crash
    the plane mid-sweep with that commit's tail still volatile.
"""
from __future__ import annotations

import json
import os
from collections import Counter
from typing import Any, Callable, Dict, List, Optional, Tuple


class MemoryBackend:
    """In-process backend: per-shard committed records + latest snapshot."""

    def __init__(self):
        self._shards: Dict[str, dict] = {}

    def _state(self, shard: str) -> dict:
        return self._shards.setdefault(
            shard, {"base_lsn": 0, "snapshot": None, "records": []})

    def persist(self, shard: str, lsn_records: List[Tuple[int, Any]]) -> None:
        self._state(shard)["records"].extend(lsn_records)

    def write_snapshot(self, shard: str, base_lsn: int, payload: Any) -> None:
        st = self._state(shard)
        st["snapshot"] = payload
        st["base_lsn"] = base_lsn
        st["records"] = [(l, r) for (l, r) in st["records"] if l > base_lsn]

    def load(self, shard: str) -> Tuple[int, Any, List[Tuple[int, Any]]]:
        st = self._state(shard)
        return st["base_lsn"], st["snapshot"], list(st["records"])

    def last_lsn(self, shard: str) -> int:
        st = self._state(shard)
        return st["records"][-1][0] if st["records"] else st["base_lsn"]

    def has_data(self, shard: str) -> bool:
        st = self._shards.get(shard)
        return bool(st and (st["snapshot"] is not None or st["records"]))


class DirBackend:
    """On-disk backend: ``<dir>/<shard>.wal`` (JSONL of ``[lsn, record]``,
    appended + fsync'd per group commit) and ``<dir>/<shard>.snap.json``
    (``{"base_lsn", "payload"}``, written temp-then-atomic-rename). A torn
    trailing WAL line (crash mid-write) is dropped on load; everything before
    it is intact because appends happen in commit order."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _wal_path(self, shard: str) -> str:
        return os.path.join(self.root, f"{shard}.wal")

    def _snap_path(self, shard: str) -> str:
        return os.path.join(self.root, f"{shard}.snap.json")

    def persist(self, shard: str, lsn_records: List[Tuple[int, Any]]) -> None:
        with open(self._wal_path(shard), "a", encoding="utf-8") as f:
            for lsn, rec in lsn_records:
                f.write(json.dumps([lsn, rec], separators=(",", ":")) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def write_snapshot(self, shard: str, base_lsn: int, payload: Any) -> None:
        snap = self._snap_path(shard)
        tmp = snap + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump({"base_lsn": base_lsn, "payload": payload}, f,
                      separators=(",", ":"))
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, snap)                      # commit point
        # truncate: rewrite the WAL keeping only post-snapshot records
        keep = [(l, r) for (l, r) in self._read_wal(shard) if l > base_lsn]
        wal, wtmp = self._wal_path(shard), self._wal_path(shard) + ".tmp"
        with open(wtmp, "w", encoding="utf-8") as f:
            for lsn, rec in keep:
                f.write(json.dumps([lsn, rec], separators=(",", ":")) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.rename(wtmp, wal)

    def _read_wal(self, shard: str) -> List[Tuple[int, Any]]:
        path = self._wal_path(shard)
        if not os.path.exists(path):
            return []
        out: List[Tuple[int, Any]] = []
        with open(path, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    lsn, rec = json.loads(line)
                except (json.JSONDecodeError, ValueError):
                    break                         # torn tail: stop, keep prefix
                out.append((lsn, rec))
        return out

    def _read_snap(self, shard: str) -> Tuple[int, Any]:
        path = self._snap_path(shard)
        if not os.path.exists(path):
            return 0, None
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
        return doc["base_lsn"], doc["payload"]

    def load(self, shard: str) -> Tuple[int, Any, List[Tuple[int, Any]]]:
        base, payload = self._read_snap(shard)
        records = [(l, r) for (l, r) in self._read_wal(shard) if l > base]
        return base, payload, records

    def last_lsn(self, shard: str) -> int:
        recs = self._read_wal(shard)
        if recs:
            return recs[-1][0]
        return self._read_snap(shard)[0]

    def has_data(self, shard: str) -> bool:
        return (os.path.exists(self._snap_path(shard))
                or os.path.exists(self._wal_path(shard)))


class LogStore:
    """Group-committed WAL + snapshot front-end shared by every durable store.

    One LogStore instance typically backs the whole plane (overwatch shards,
    meta/lease shard, broker shards, taskdb) — shard names are disjoint, and
    commit ordering across shards stays under the callers' control (the
    composer commits ``taskdb`` before broker shards every tick).
    """

    def __init__(self, backend=None,
                 fault_hook: Optional[Callable[[str, str], None]] = None):
        self.backend = backend if backend is not None else MemoryBackend()
        self.fault_hook = fault_hook
        self._buf: Dict[str, List[Any]] = {}      # shard -> uncommitted tail
        self._lsn: Dict[str, int] = {}            # shard -> last committed LSN
        self._snap_base: Dict[str, int] = {}      # shard -> snapshot base LSN
        self.stats: Counter = Counter()

    # ------------------------------------------------------------------ write
    def append(self, shard: str, record: Any) -> None:
        """Buffer a record; volatile until ``commit(shard)``."""
        self._buf.setdefault(shard, []).append(record)
        self.stats["appended"] += 1

    def commit(self, shard: str) -> int:
        """Persist the shard's buffered tail (group commit). Returns the
        number of records made durable."""
        if self.fault_hook is not None:
            self.fault_hook("commit", shard)
        buf = self._buf.pop(shard, None)
        if not buf:
            return 0
        start = self._last(shard)
        lsn_records = [(start + i + 1, rec) for i, rec in enumerate(buf)]
        self.backend.persist(shard, lsn_records)
        self._lsn[shard] = start + len(buf)
        self.stats["committed"] += len(buf)
        self.stats["commits"] += 1
        return len(buf)

    def commit_all(self) -> int:
        return sum(self.commit(s) for s in sorted(self._buf))

    def lose_uncommitted(self) -> int:
        """Crash model: drop every shard's uncommitted tail. Returns how many
        records were lost (the chaos harness records this per crash)."""
        lost = sum(len(b) for b in self._buf.values())
        self._buf.clear()
        self.stats["lost_records"] += lost
        return lost

    def lose_shards(self, shards) -> int:
        """Crash model for a single master fault domain: drop only the named
        shards' uncommitted tails (the dying master's stores), leaving the
        survivors' buffered records intact."""
        lost = 0
        for shard in shards:
            buf = self._buf.pop(shard, None)
            if buf:
                lost += len(buf)
        self.stats["lost_records"] += lost
        return lost

    # -------------------------------------------------------------- snapshot
    def snapshot(self, shard: str, payload: Any) -> None:
        """Persist a full-state payload at the current committed LSN and
        truncate the WAL behind it (snapshot+truncate compaction)."""
        if self.fault_hook is not None:
            self.fault_hook("snapshot", shard)
        base = self._last(shard)
        self.backend.write_snapshot(shard, base, payload)
        self._snap_base[shard] = base
        self.stats["snapshots"] += 1

    def records_since_snapshot(self, shard: str) -> int:
        """Committed WAL length past the last snapshot — the replay bound a
        caller compares against its ``snapshot_every`` policy."""
        return self._last(shard) - self._snap_base.get(shard, 0)

    # ------------------------------------------------------------------- read
    def load(self, shard: str) -> Tuple[Any, List[Any]]:
        """(snapshot payload | None, committed records after it) — the replay
        input for ``recover()``. Uncommitted appends are never returned."""
        base, payload, lsn_records = self.backend.load(shard)
        top = lsn_records[-1][0] if lsn_records else base
        self._lsn[shard] = max(self._lsn.get(shard, 0), top)
        self._snap_base[shard] = max(self._snap_base.get(shard, 0), base)
        self.stats["replayed"] += len(lsn_records)
        return payload, [rec for (_, rec) in lsn_records]

    def has_data(self, shard: str) -> bool:
        return self.backend.has_data(shard)

    # -------------------------------------------------------------- internals
    def _last(self, shard: str) -> int:
        if shard not in self._lsn:
            self._lsn[shard] = self.backend.last_lsn(shard)
        return self._lsn[shard]
