"""Per-cluster overwatch replica fan-out (the cross-boundary locality overhaul).

The paper's core scalability claim is that the hybrid plane keeps
cross-boundary traffic THIN: local control planes act on local state while the
global plane only ships deltas (§4). Before this module, every remote read —
an agent probing fleet telemetry, a worker checking queue depth, anything
calling ``range_stale`` from a private cluster — round-tripped through gateway
channels to the master-side overwatch, paying the full request+response byte
cost per read. Now the master ships each cluster ONE coalesced, revision-
tagged delta envelope per sweep, and remote reads are served from the local
snapshot for free.

Two halves:

  * ``LocalReplica`` — hosted by each control agent: a ``ReplicaState``
    snapshot (same apply/read machinery as the master-side read replica)
    restricted to a prefix set, plus the freshness bookkeeping
    (``synced_at``, the master clock of the last applied ship) that lets
    ``OverwatchClient.range_stale`` decide locally whether the caller's
    ``max_lag`` is satisfied. Within bound: a local dict read, zero fabric
    traffic. Out of bound (ships stopped — channel dead, cluster partitioned):
    transparent fallback to the primary round-trip, never a silently staler
    answer.

  * ``ReplicaShipper`` — master-side: subscribes one catch-all batch watcher
    to the overwatch and maintains ONE shared, key-coalesced delta log (only
    the latest state of a key matters to a snapshot) with a revision-ordered
    index, plus a per-cluster cumulative-ack horizon (``acked_rev``).
    Event intake is O(events) however many clusters are fed. ``ship_all()``
    — called on the plane's sweep cadence — sends each cluster one envelope
    carrying every log entry above ITS horizon, over the existing
    master->agent dispatch relay (the same gateway channel jobs ride); the
    horizon advances only on a confirmed apply, so a failed ship (channel
    death, partition) costs nothing and the first ship after heal carries
    everything missed — the replica converges from exactly where it left
    off. The log compacts below the minimum horizon across feeds, so an
    up-to-date fleet keeps it at roughly one sweep's churn. Empty ships
    still go out: they are the freshness beacon that distinguishes "nothing
    changed" from "cut off", and they cost a few dozen bytes.

Byte-ledger truth: shipped envelopes are the ONLY cross-boundary cost of the
fan-out (measured in ``Fabric.cross_bytes`` like all channel traffic); local
replica reads touch no fabric path at all. ``benchmarks/control_plane.py``'s
locality block gates the resulting cross-bytes-per-read win.
"""
from __future__ import annotations

import bisect
from collections import Counter
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.overwatch import OverwatchService, ReplicaState
from repro.core.transport import DeliveryError, Envelope

# The remote-read vocabulary: discovery, telemetry, queue depths, autoscaler
# fleet state. Deliberately excludes the high-churn per-entity ``/jobs/``
# keyspace — placements/statuses are the dispatcher's (master-local) concern,
# and shipping them to every cluster would be the fan-out's own traffic storm.
REPLICA_PREFIXES: Tuple[str, ...] = ("/clusters/", "/telemetry/", "/queues/",
                                     "/autoscale/")


class LocalReplica(ReplicaState):
    """A cluster-local, prefix-scoped overwatch snapshot fed by shipped
    deltas. ``lag`` is measured against the master clock stamped into the
    last applied ship — infinite until the first ship lands, so a replica
    that has never synced can never satisfy a staleness bound."""

    def __init__(self, prefixes: Tuple[str, ...] = REPLICA_PREFIXES):
        super().__init__()
        self.prefixes = tuple(prefixes)
        self.synced_at: Optional[float] = None
        self.stats: Counter = Counter()      # batches/events applied

    def covers(self, prefix: str) -> bool:
        """True when every key the prefix could match is inside the shipped
        set (a subscribed prefix of ``""`` covers everything)."""
        return any(prefix.startswith(p) for p in self.prefixes)

    def lag(self, now: float) -> float:
        if self.synced_at is None:
            return float("inf")
        return now - self.synced_at

    def apply_ship(self, batch: dict) -> int:
        """Apply one shipped delta envelope; returns the applied revision
        (the cumulative ack the shipper records). A ``reset`` batch (crash
        recovery re-seeded this feed from a state the replica's horizon
        predates) drops the local snapshot first: keys deleted between the
        horizon and the crash have no tombstone anywhere to ship, so only a
        clean re-apply converges."""
        if batch.get("reset"):
            self._kv.clear()
            self._keys = []
            self._added.clear()
            self._removed.clear()
            self.applied_rev = 0
        self.apply_events(batch["events"])
        if batch["rev"] > self.applied_rev:
            self.applied_rev = batch["rev"]
        self.synced_at = batch["clock"]
        self.stats["batches"] += 1
        self.stats["events"] += len(batch["events"])
        return self.applied_rev


class _Feed:
    """One cluster's feed state: the cumulative-ack horizon (every log entry
    above it is owed to this cluster) plus, until the first confirmed ship,
    the bootstrap snapshot of the shipped prefixes."""

    __slots__ = ("acked_rev", "seed", "reset")

    def __init__(self, acked_rev: int, seed: Dict[str, tuple],
                 reset: bool = False):
        self.acked_rev = acked_rev
        self.seed = seed                      # key -> (event, value, rev)
        # crash recovery re-seeded this feed from scratch: the first ship
        # carries a reset marker so the replica drops state the seed cannot
        # tombstone (cleared once a ship is confirmed)
        self.reset = reset


class ReplicaShipper:
    """Master-side fan-out publisher: one coalesced envelope per cluster per
    sweep, cumulative-ack resume across channel death and partition."""

    def __init__(self, overwatch: OverwatchService,
                 send_fn: Callable[[str, dict], dict],
                 prefixes: Tuple[str, ...] = REPLICA_PREFIXES):
        self.ow = overwatch
        self.send_fn = send_fn               # (cluster, msg) -> agent response
        self.prefixes = tuple(prefixes)
        self._feeds: Dict[str, _Feed] = {}
        # the shared delta log: latest state per key + a rev-ordered index so
        # each ship walks only the entries above that cluster's horizon.
        # Index entries whose key has since re-coalesced are skipped lazily.
        self._log: Dict[str, tuple] = {}     # key -> (event, value, rev)
        self._order: List[Tuple[int, str]] = []        # (rev, key), appended
        # highest revision the shipper has actually INGESTED — the ack
        # horizon may never pass it, or events still pending in a coalesced
        # watch queue would be skipped by every later ship
        self._seen_rev = 0
        self.stats: Counter = Counter()
        overwatch.watch_batch("", self._on_events)

    # ------------------------------------------------------------- membership
    def register(self, cluster: str) -> None:
        """Start feeding a cluster: snapshot the shipped prefixes at the
        current revision — the first successful ship bootstraps the replica
        from empty, everything after rides the shared log."""
        rev = self.ow._rev
        seed: Dict[str, tuple] = {}
        for p in self.prefixes:
            items = self.ow.handle({"op": "range", "prefix": p})["items"]
            for k, v in items.items():
                seed[k] = ("put", v, rev)
        self._feeds[cluster] = _Feed(acked_rev=rev, seed=seed)

    def unregister(self, cluster: str) -> None:
        """Stop feeding (cluster tombstoned): the next compaction is free to
        drop whatever only this cluster still owed."""
        self._feeds.pop(cluster, None)

    def register_resume(self, cluster: str, applied_rev: int,
                        tail: List[tuple], tail_base: int) -> bool:
        """Crash-recovery feed resume. ``tail`` is the recovered overwatch's
        replayed-event list (revision-ordered) and ``tail_base`` the highest
        shard-snapshot revision — everything at or below ``tail_base`` exists
        only as folded snapshot state, not as replayable events.

        If the cluster's replica horizon (``applied_rev``) is at or above
        ``tail_base``, every event it missed is in the tail: seed exactly the
        tail entries above its horizon and resume cumulatively — the replica
        never re-downloads state it already holds. A horizon below
        ``tail_base`` cannot be caught up by deltas (deletions between the
        horizon and the snapshot left no replayable tombstone), so the feed
        falls back to a full bootstrap seed with a reset marker. Returns True
        when the feed resumed from the horizon, False on full reseed."""
        if applied_rev < tail_base:
            self.register(cluster)
            self._feeds[cluster].reset = True
            return False
        seed: Dict[str, tuple] = {}
        for event, key, value, rev in tail:
            if rev > applied_rev and any(key.startswith(p)
                                         for p in self.prefixes):
                seed[key] = (event, value, rev)
        self._feeds[cluster] = _Feed(acked_rev=applied_rev, seed=seed)
        # the recovered primary's revision is fully covered by (replica state
        # up to applied_rev) + this seed: let ship revs advance to it even
        # before the first post-recovery mutation lands in the watch log
        self._seen_rev = max(self._seen_rev, self.ow._rev)
        return True

    # ----------------------------------------------------------- event intake
    def _on_events(self, events: List[tuple]) -> None:
        """O(matching events), independent of the cluster count."""
        prefixes = self.prefixes
        log, order = self._log, self._order
        for event, key, value, rev in events:
            if rev > self._seen_rev:
                self._seen_rev = rev
            if any(key.startswith(p) for p in prefixes):
                log[key] = (event, value, rev)
                order.append((rev, key))

    # --------------------------------------------------------------- shipping
    def _build_msg(self, feed: _Feed) -> Envelope:
        """One cluster's envelope: its bootstrap seed (if unconfirmed) plus
        every log delta above its horizon, revision-ordered."""
        merged: Dict[str, tuple] = dict(feed.seed) if feed.seed else {}
        log, order = self._log, self._order
        lo = bisect.bisect_right(order, (feed.acked_rev, "\U0010ffff"))
        for rev, key in order[lo:]:
            ent = log.get(key)
            if ent is not None and ent[2] == rev:    # else: re-coalesced later
                merged[key] = ent
        events = sorted(((event, key, value, rev)
                         for key, (event, value, rev) in merged.items()),
                        key=lambda ev: ev[3])
        # the ack horizon advances only to what this shipper has INGESTED
        # (or the seed's snapshot revision): stamping the primary's current
        # rev here would leap past events still pending in a coalesced
        # watch queue, and later ships would skip them forever
        batch = {"events": events,
                 "rev": max(feed.acked_rev, self._seen_rev),
                 "clock": self.ow.fabric.clock}
        if feed.reset:
            batch["reset"] = True
        return Envelope({"kind": "replica_batch", "batch": batch})

    def _ship_msg(self, cluster: str, feed: _Feed, msg: Envelope) -> bool:
        """Deliver one (possibly shared) envelope. On failure nothing moves —
        the horizon only advances on a confirmed apply (cumulative ack)."""
        batch = msg["batch"]
        try:
            resp = self.send_fn(cluster, msg)
        except (DeliveryError, KeyError):
            # channel dead / cluster partitioned or already forgotten:
            # nothing applied, nothing to restore — the horizon stands still
            self.stats["ship_failures"] += 1
            return False
        if not resp.get("ok"):
            self.stats["ship_rejected"] += 1
            return False
        feed.acked_rev = resp.get("applied_rev", batch["rev"])
        feed.seed = {}
        feed.reset = False
        self.stats["ships"] += 1
        self.stats["shipped_events"] += len(batch["events"])
        self.stats["shipped_bytes"] += msg.nbytes
        return True

    def ship(self, cluster: str) -> bool:
        """One envelope to one cluster (the single-cluster entry point)."""
        feed = self._feeds.get(cluster)
        if feed is None:
            return False
        return self._ship_msg(cluster, feed, self._build_msg(feed))

    def ship_all(self) -> int:
        """The sweep-cadence fan-out: one envelope per registered cluster,
        then compact the shared log below the laggiest confirmed horizon.
        Returns how many ships landed. Takes the watch barrier first so a
        direct caller (tests, an out-of-band flush) ships the log as of the
        primary's current state, not as of the last flush.

        Feeds sharing an ack horizon (the steady-state fleet: everyone
        confirmed last sweep's ship) share ONE built-and-sized envelope —
        the per-sweep build cost is O(distinct horizons x churn), not
        O(clusters x churn), and the envelope's byte walk happens once."""
        self.ow.flush_watches()
        shared: Dict[int, Envelope] = {}
        landed = 0
        for cluster in sorted(self._feeds):
            feed = self._feeds[cluster]
            if feed.seed or feed.reset:      # bootstrap: unique by definition
                msg = self._build_msg(feed)
            else:
                msg = shared.get(feed.acked_rev)
                if msg is None:
                    msg = shared[feed.acked_rev] = self._build_msg(feed)
            if self._ship_msg(cluster, feed, msg):
                landed += 1
        self._compact()
        return landed

    def _compact(self) -> None:
        """Drop log entries every feed has confirmed. With no feeds the log
        empties outright; with one partitioned cluster it grows only until
        the lease sweep tombstones (and unregisters) it."""
        if not self._feeds:
            if self._order:
                self._log.clear()
                self._order.clear()
            return
        min_acked = min(f.acked_rev for f in self._feeds.values())
        order = self._order
        hi = bisect.bisect_right(order, (min_acked, "\U0010ffff"))
        if not hi:
            return
        log = self._log
        for rev, key in order[:hi]:
            ent = log.get(key)
            if ent is not None and ent[2] == rev:
                del log[key]
        del order[:hi]
