"""Per-cluster overwatch replica fan-out: local reads AND local notify.

The paper's core scalability claim is that the hybrid plane keeps
cross-boundary traffic THIN: local control planes act on local state while the
global plane only ships deltas (§4). This module is that thin boundary for the
whole OBSERVATION plane — both halves of it:

  * the **read path** (PR 5): every remote ``range_stale`` — an agent probing
    fleet telemetry, a worker checking queue depth — used to round-trip
    through gateway channels to the master-side overwatch, paying the full
    request+response byte cost per read. The master instead ships each
    cluster ONE coalesced, revision-tagged delta envelope per sweep, and
    remote reads are served from the local snapshot for free.

  * the **notify path** (this PR): remote watch subscriptions used to be
    impossible without per-watcher cross-boundary traffic — every observer of
    ``/queues/``, ``/telemetry/`` or ``/autoscale/`` state on a private
    cluster had to poll the primary per tick. ``LocalReplica`` now exposes
    ``watch(prefix, cb)`` / ``watch_batch(prefix, cb)`` with the same
    revision-ordered, coalesced semantics as the primary's watch buckets, fed
    entirely from the SAME shipped envelope — so N watchers on a cluster cost
    exactly the cross-boundary bytes of zero watchers, and the agent can
    expose the replica as a cluster-local service endpoint (``range_stale`` +
    ``watch``, see ``ControlAgent.enable_replica``) that worker pods, depth
    views, and autoscale observers consume instead of dialing the master.

Three pieces:

  * ``LocalReplica`` — hosted by each control agent: a ``ReplicaState``
    snapshot restricted to a prefix set, plus freshness bookkeeping
    (``synced_at``, the master clock of the last applied ship) that lets
    ``OverwatchClient.range_stale`` decide locally whether the caller's
    ``max_lag`` is satisfied (out of bound: transparent fallback to the
    primary round trip, counted in ``fabric.stats["fallback_reads"]``) — and
    now the local watch plane. Watch delivery is exactly-once per key-state:
    cumulative redelivery after a failed ack is deduplicated by revision, and
    a ``reset`` batch (crash recovery re-seeded the feed) is DIFFED against
    the pre-reset snapshot so watchers see synthesized tombstones for keys
    deleted during the gap, puts only for keys that actually changed, and
    nothing at all for state they already hold. Per-watcher pending queues
    are bounded (RingLog discipline: drop-oldest + ``stats["watch_dropped"]``)
    so a stuck callback can't grow memory without bound; a raising callback
    keeps its queue and is retried on the next ship.

  * ``ReplicaView`` — a watch-materialized dict over one shipped prefix: the
    cluster-local analogue of the dispatcher's master-side views, used by the
    composer's worker depth gate and any fleet-state observer.

  * ``ReplicaShipper`` — master-side: subscribes one catch-all batch watcher
    to the overwatch and maintains ONE shared, key-coalesced delta log (only
    the latest state of a key matters to a snapshot) with a revision-ordered
    index, plus a per-cluster cumulative-ack horizon (``acked_rev``).
    Event intake is O(events) however many clusters are fed. ``ship_all()``
    — called on the plane's sweep cadence — sends each cluster one envelope
    carrying every log entry above ITS horizon, over the existing
    master->agent dispatch relay; the horizon advances only on a confirmed
    apply, so a failed ship costs nothing and the first ship after heal
    carries everything missed. Registration is idempotent for live feeds (a
    duplicate register after a timed-out ack neither re-ships the bootstrap
    seed nor resets the horizon). The log compacts below the minimum horizon
    across feeds. Empty ships still go out: they are the freshness beacon
    that distinguishes "nothing changed" from "cut off".

Byte-ledger truth: shipped envelopes are the ONLY cross-boundary cost of the
fan-out (measured in ``Fabric.cross_bytes`` like all channel traffic); local
replica reads and watch deliveries touch no fabric path at all.
``benchmarks/control_plane.py``'s locality + notify blocks gate both the
cross-bytes-per-read and the cross-bytes-per-notify win.
"""
from __future__ import annotations

import bisect
from collections import Counter, deque
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.overwatch import OverwatchService, ReplicaState
from repro.core.transport import DeliveryError, Envelope

# The remote-read vocabulary: discovery, telemetry, queue depths, autoscaler
# fleet state, and per-cluster metrics snapshots (the flight recorder's
# export — published only when ``metrics_every`` is set, so the prefix is
# free otherwise). Deliberately excludes the high-churn per-entity ``/jobs/``
# keyspace — placements/statuses are the dispatcher's (master-local) concern,
# and shipping them to every cluster would be the fan-out's own traffic storm.
REPLICA_PREFIXES: Tuple[str, ...] = ("/clusters/", "/telemetry/", "/queues/",
                                     "/autoscale/", "/metrics/", "/sys/")

# Per-watcher pending-queue cap (RingLog discipline): generous enough that a
# healthy watcher never sees it, small enough that a permanently raising
# callback bounds its own memory instead of the whole replica's.
WATCH_QUEUE_LIMIT = 4096


class _LocalWatch:
    """One replica watch subscription: a prefix, a callback, and a bounded
    pending queue that survives a raising callback (retried next ship)."""

    __slots__ = ("seq", "prefix", "cb", "batch", "pending", "dropped")

    def __init__(self, seq: int, prefix: str, cb: Callable, batch: bool,
                 limit: Optional[int]):
        self.seq = seq
        self.prefix = prefix
        self.cb = cb
        self.batch = batch
        self.pending: deque = deque(maxlen=limit)
        self.dropped = 0


class LocalReplica(ReplicaState):
    """A cluster-local, prefix-scoped overwatch snapshot fed by shipped
    deltas — both the bounded-staleness read surface and the local watch
    plane. ``lag`` is measured against the master clock stamped into the
    last applied ship — infinite until the first ship lands, so a replica
    that has never synced can never satisfy a staleness bound."""

    def __init__(self, prefixes: Tuple[str, ...] = REPLICA_PREFIXES,
                 watch_queue_limit: Optional[int] = WATCH_QUEUE_LIMIT):
        super().__init__()
        self.prefixes = tuple(prefixes)
        self.synced_at: Optional[float] = None
        self.watch_queue_limit = watch_queue_limit
        self.stats: Counter = Counter()      # batches/events/watch counters
        self._watches: List[_LocalWatch] = []
        self._watch_seq = 0

    def covers(self, prefix: str) -> bool:
        """True when every key the prefix could match is inside the shipped
        set (a subscribed prefix of ``""`` covers everything)."""
        return any(prefix.startswith(p) for p in self.prefixes)

    def lag(self, now: float) -> float:
        if self.synced_at is None:
            return float("inf")
        return now - self.synced_at

    # -------------------------------------------------------- the watch plane
    def watch(self, prefix: str,
              cb: Callable[[str, str, object, int], None]) -> _LocalWatch:
        """Per-event subscription: ``cb(event, key, value, rev)`` for every
        shipped delta under ``prefix``, in revision order — the replica-side
        twin of ``OverwatchService.watch``, at zero cross-boundary cost."""
        return self._register_watch(prefix, cb, batch=False)

    def watch_batch(self, prefix: str,
                    cb: Callable[[List[tuple]], None]) -> _LocalWatch:
        """Coalesced subscription: one ``cb(events)`` per applied ship with
        the revision-ordered ``(event, key, value, rev)`` deltas under
        ``prefix`` — the replica-side twin of ``watch_batch``."""
        return self._register_watch(prefix, cb, batch=True)

    def _register_watch(self, prefix: str, cb: Callable,
                        batch: bool) -> _LocalWatch:
        if not self.covers(prefix):
            raise ValueError(
                f"replica does not ship prefix {prefix!r} "
                f"(shipped: {self.prefixes})")
        self._watch_seq += 1
        w = _LocalWatch(self._watch_seq, prefix, cb, batch,
                        self.watch_queue_limit)
        self._watches.append(w)
        return w

    def unwatch(self, watch: _LocalWatch) -> None:
        try:
            self._watches.remove(watch)
        except ValueError:
            pass

    def _enqueue(self, events: List[tuple]) -> None:
        for w in self._watches:
            pend, limit = w.pending, w.pending.maxlen
            for ev in events:
                if ev[1].startswith(w.prefix):
                    if limit is not None and len(pend) == limit:
                        # RingLog discipline: the deque drops the OLDEST
                        # pending event; account for it before it vanishes
                        w.dropped += 1
                        self.stats["watch_dropped"] += 1
                    pend.append(ev)

    def _drain_watches(self) -> None:
        """Deliver pending events watcher-by-watcher in subscription order
        (events within a watcher are revision-ordered). A raising callback
        keeps its undelivered events queued — no event is lost to an
        exception, only (eventually) to the bounded queue."""
        for w in self._watches:
            if not w.pending:
                continue
            if w.batch:
                events = list(w.pending)
                try:
                    w.cb(events)
                except Exception:            # noqa: BLE001
                    self.stats["watch_errors"] += 1
                    continue
                w.pending.clear()
                self.stats["watch_callbacks"] += 1
                self.stats["watch_events"] += len(events)
            else:
                while w.pending:
                    event, key, value, rev = w.pending[0]
                    try:
                        w.cb(event, key, value, rev)
                    except Exception:        # noqa: BLE001
                        self.stats["watch_errors"] += 1
                        break
                    w.pending.popleft()
                    self.stats["watch_callbacks"] += 1
                    self.stats["watch_events"] += 1

    # ------------------------------------------------------------ feed intake
    def apply_ship(self, batch: dict) -> int:
        """Apply one shipped delta envelope; returns the applied revision
        (the cumulative ack the shipper records), then drives the local
        watch plane.

        Exactly-once notify: events at or below the previous horizon are
        cumulative redelivery (the ack for an applied ship was lost) — they
        re-apply harmlessly to the snapshot but are NOT re-delivered to
        watchers. A ``reset`` batch (crash recovery re-seeded this feed from
        a state the replica's horizon predates) drops the local snapshot
        first — keys deleted between the horizon and the crash have no
        tombstone anywhere to ship — and watcher delivery becomes the DIFF
        against the pre-reset snapshot: synthesized ``delete`` events for
        keys that vanished, puts only for keys whose value actually changed,
        silence for state the watcher already holds."""
        prior_rev = self.applied_rev
        events = batch["events"]
        if batch.get("reset"):
            old = dict(self._kv)
            self._kv.clear()
            self._keys = []
            self._added.clear()
            self._removed.clear()
            self.applied_rev = 0
            self.apply_events(events)
            fresh = []
            explicit_deletes = set()
            for event, key, value, rev in events:
                if event == "delete":
                    explicit_deletes.add(key)
                    if key in old:
                        fresh.append((event, key, None, rev))
                elif key not in old or old[key] != value:
                    fresh.append((event, key, value, rev))
            top = max(batch["rev"], self.applied_rev)
            for key in sorted(old):
                if key not in self._kv and key not in explicit_deletes:
                    fresh.append(("delete", key, None, top))
            self.stats["resets"] += 1
        else:
            self.apply_events(events)
            fresh = [ev for ev in events if ev[3] > prior_rev]
        if batch["rev"] > self.applied_rev:
            self.applied_rev = batch["rev"]
        self.synced_at = batch["clock"]
        self.stats["batches"] += 1
        self.stats["events"] += len(events)
        if fresh and self._watches:
            self._enqueue(fresh)
        # drain unconditionally: a watcher whose callback raised last ship
        # gets its retained queue retried even by an empty freshness beacon
        self._drain_watches()
        return self.applied_rev


class ReplicaView:
    """A watch-materialized dict over one shipped prefix: the cluster-local
    analogue of the dispatcher's master-side materialized views. Seeded from
    the replica snapshot at construction, then maintained purely from the
    local watch plane — reads never touch the fabric; freshness is the
    replica's own ship lag."""

    def __init__(self, replica: LocalReplica, prefix: str):
        self.replica = replica
        self.prefix = prefix
        self._items: Dict[str, object] = dict(replica.range_items(prefix))
        replica.watch_batch(prefix, self._ingest)

    def _ingest(self, events: List[tuple]) -> None:
        items = self._items
        for event, key, value, _rev in events:
            if event == "delete":
                items.pop(key, None)
            else:
                items[key] = value

    def fresh(self, now: float, max_lag: float) -> bool:
        return self.replica.lag(now) <= max_lag

    def get(self, key: str, default=None):
        return self._items.get(key, default)

    def items(self) -> Dict[str, object]:
        return dict(self._items)

    def __len__(self) -> int:
        return len(self._items)


class _Feed:
    """One cluster's feed state: the cumulative-ack horizon (every log entry
    above it is owed to this cluster) plus, until the first confirmed ship,
    the bootstrap snapshot of the shipped prefixes."""

    __slots__ = ("acked_rev", "seed", "reset")

    def __init__(self, acked_rev: int, seed: Dict[str, tuple],
                 reset: bool = False):
        self.acked_rev = acked_rev
        self.seed = seed                      # key -> (event, value, rev)
        # crash recovery re-seeded this feed from scratch: the first ship
        # carries a reset marker so the replica drops state the seed cannot
        # tombstone (cleared once a ship is confirmed)
        self.reset = reset


class ReplicaShipper:
    """Master-side fan-out publisher: one coalesced envelope per cluster per
    sweep, cumulative-ack resume across channel death and partition."""

    def __init__(self, overwatch: OverwatchService,
                 send_fn: Callable[[str, dict], dict],
                 prefixes: Tuple[str, ...] = REPLICA_PREFIXES):
        self.ow = overwatch
        self.send_fn = send_fn               # (cluster, msg) -> agent response
        self.prefixes = tuple(prefixes)
        self._feeds: Dict[str, _Feed] = {}
        # the shared delta log: latest state per key + a rev-ordered index so
        # each ship walks only the entries above that cluster's horizon.
        # Index entries whose key has since re-coalesced are skipped lazily.
        self._log: Dict[str, tuple] = {}     # key -> (event, value, rev)
        self._order: List[Tuple[int, str]] = []        # (rev, key), appended
        # highest revision the shipper has actually INGESTED — the ack
        # horizon may never pass it, or events still pending in a coalesced
        # watch queue would be skipped by every later ship
        self._seen_rev = 0
        self.stats: Counter = Counter()
        overwatch.watch_batch("", self._on_events)

    # ------------------------------------------------------------- membership
    def register(self, cluster: str, reset: bool = False) -> None:
        """Start feeding a cluster: snapshot the shipped prefixes at the
        current revision — the first successful ship bootstraps the replica
        from empty, everything after rides the shared log.

        Idempotent for a live feed: a duplicate registration (an agent
        retrying after a timed-out ack, a racing re-add) leaves the existing
        horizon and pending seed untouched — re-seeding here would re-ship
        the full bootstrap snapshot AND reset the cumulative-ack horizon,
        re-delivering everything the replica already applied. ``reset=True``
        (crash recovery with an unreachable replica whose horizon is
        unknowable) marks the first ship so the replica drops state the
        fresh seed cannot tombstone."""
        if cluster in self._feeds:
            self.stats["duplicate_registers"] += 1
            return
        rev = self.ow._rev
        seed: Dict[str, tuple] = {}
        for p in self.prefixes:
            items = self.ow.handle({"op": "range", "prefix": p})["items"]
            for k, v in items.items():
                seed[k] = ("put", v, rev)
        self._feeds[cluster] = _Feed(acked_rev=rev, seed=seed, reset=reset)

    def unregister(self, cluster: str) -> None:
        """Stop feeding (cluster tombstoned): the next compaction is free to
        drop whatever only this cluster still owed."""
        self._feeds.pop(cluster, None)

    def register_resume(self, cluster: str, applied_rev: int,
                        tail: List[tuple], tail_base: int) -> bool:
        """Crash-recovery feed resume. ``tail`` is the recovered overwatch's
        replayed-event list (revision-ordered) and ``tail_base`` the highest
        shard-snapshot revision — everything at or below ``tail_base`` exists
        only as folded snapshot state, not as replayable events.

        If the cluster's replica horizon (``applied_rev``) is at or above
        ``tail_base``, every event it missed is in the tail: seed exactly the
        tail entries above its horizon and resume cumulatively — the replica
        never re-downloads state it already holds. A horizon below
        ``tail_base`` cannot be caught up by deltas (deletions between the
        horizon and the snapshot left no replayable tombstone), and a horizon
        ABOVE the recovered store's revision means the replica applied ships
        the store then lost (should be impossible — ships run after the
        durability commit — but an anomaly must not poison the notify path's
        revision dedupe): both fall back to a full bootstrap seed with a
        reset marker. Returns True when the feed resumed from the horizon,
        False on full reseed."""
        if applied_rev < tail_base or applied_rev > self.ow._rev:
            self.register(cluster, reset=True)
            return False
        seed: Dict[str, tuple] = {}
        for event, key, value, rev in tail:
            if rev > applied_rev and any(key.startswith(p)
                                         for p in self.prefixes):
                seed[key] = (event, value, rev)
        self._feeds[cluster] = _Feed(acked_rev=applied_rev, seed=seed)
        # the recovered primary's revision is fully covered by (replica state
        # up to applied_rev) + this seed: let ship revs advance to it even
        # before the first post-recovery mutation lands in the watch log
        self._seen_rev = max(self._seen_rev, self.ow._rev)
        return True

    # ----------------------------------------------------------- event intake
    def _on_events(self, events: List[tuple]) -> None:
        """O(matching events), independent of the cluster count."""
        prefixes = self.prefixes
        log, order = self._log, self._order
        for event, key, value, rev in events:
            if rev > self._seen_rev:
                self._seen_rev = rev
            if any(key.startswith(p) for p in prefixes):
                log[key] = (event, value, rev)
                order.append((rev, key))

    # --------------------------------------------------------------- shipping
    def _build_msg(self, feed: _Feed) -> Envelope:
        """One cluster's envelope: its bootstrap seed (if unconfirmed) plus
        every log delta above its horizon, revision-ordered."""
        merged: Dict[str, tuple] = dict(feed.seed) if feed.seed else {}
        log, order = self._log, self._order
        lo = bisect.bisect_right(order, (feed.acked_rev, "\U0010ffff"))
        for rev, key in order[lo:]:
            ent = log.get(key)
            if ent is not None and ent[2] == rev:    # else: re-coalesced later
                merged[key] = ent
        events = sorted(((event, key, value, rev)
                         for key, (event, value, rev) in merged.items()),
                        key=lambda ev: ev[3])
        # the ack horizon advances only to what this shipper has INGESTED
        # (or the seed's snapshot revision): stamping the primary's current
        # rev here would leap past events still pending in a coalesced
        # watch queue, and later ships would skip them forever
        batch = {"events": events,
                 "rev": max(feed.acked_rev, self._seen_rev),
                 "clock": self.ow.fabric.clock}
        if feed.reset:
            batch["reset"] = True
        return Envelope({"kind": "replica_batch", "batch": batch})

    def _ship_msg(self, cluster: str, feed: _Feed, msg: Envelope) -> bool:
        """Deliver one (possibly shared) envelope. On failure nothing moves —
        the horizon only advances on a confirmed apply (cumulative ack)."""
        batch = msg["batch"]
        try:
            resp = self.send_fn(cluster, msg)
        except (DeliveryError, KeyError):
            # channel dead / cluster partitioned or already forgotten:
            # nothing applied, nothing to restore — the horizon stands still
            self.stats["ship_failures"] += 1
            return False
        if not resp.get("ok"):
            self.stats["ship_rejected"] += 1
            return False
        feed.acked_rev = resp.get("applied_rev", batch["rev"])
        feed.seed = {}
        feed.reset = False
        self.stats["ships"] += 1
        self.stats["shipped_events"] += len(batch["events"])
        self.stats["shipped_bytes"] += msg.nbytes
        return True

    def ship(self, cluster: str) -> bool:
        """One envelope to one cluster (the single-cluster entry point)."""
        feed = self._feeds.get(cluster)
        if feed is None:
            return False
        return self._ship_msg(cluster, feed, self._build_msg(feed))

    def ship_all(self) -> int:
        """The sweep-cadence fan-out: one envelope per registered cluster,
        then compact the shared log below the laggiest confirmed horizon.
        Returns how many ships landed. Takes the watch barrier first so a
        direct caller (tests, an out-of-band flush) ships the log as of the
        primary's current state, not as of the last flush.

        Feeds sharing an ack horizon (the steady-state fleet: everyone
        confirmed last sweep's ship) share ONE built-and-sized envelope —
        the per-sweep build cost is O(distinct horizons x churn), not
        O(clusters x churn), and the envelope's byte walk happens once."""
        self.ow.flush_watches()
        shared: Dict[int, Envelope] = {}
        landed = 0
        for cluster in sorted(self._feeds):
            feed = self._feeds[cluster]
            if feed.seed or feed.reset:      # bootstrap: unique by definition
                msg = self._build_msg(feed)
            else:
                msg = shared.get(feed.acked_rev)
                if msg is None:
                    msg = shared[feed.acked_rev] = self._build_msg(feed)
            if self._ship_msg(cluster, feed, msg):
                landed += 1
        self._compact()
        return landed

    def _compact(self) -> None:
        """Drop log entries every feed has confirmed. With no feeds the log
        empties outright; with one partitioned cluster it grows only until
        the lease sweep tombstones (and unregisters) it."""
        if not self._feeds:
            if self._order:
                self._log.clear()
                self._order.clear()
            return
        min_acked = min(f.acked_rev for f in self._feeds.values())
        order = self._order
        hi = bisect.bisect_right(order, (min_acked, "\U0010ffff"))
        if not hi:
            return
        log = self._log
        for rev, key in order[:hi]:
            ent = log.get(key)
            if ent is not None and ent[2] == rev:
                del log[key]
        del order[:hi]
