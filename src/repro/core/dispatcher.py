"""Intelligent job dispatcher (paper §2.iv) + failure detector + stragglers.

Routing: a job carries tags (``requires`` capabilities, ``locality`` preference);
the dispatcher filters registered clusters by capability, honors explicit routing
rules (the paper's "pre-defined service routing rule"), then picks the least
loaded by telemetry. It doubles as the pubsub message publisher of §4.1: CRD
configuration objects are broadcast to every registered control agent.

Fault tolerance: cluster registrations are lease-backed; the overwatch deletes
them when heartbeats stop. The dispatcher watches the tombstones and re-dispatches
the dead cluster's jobs to healthy clusters — resuming from the job's last
committed checkpoint manifest (recorded under /checkpoints/<job>). Straggler
mitigation compares per-job step rates against the fleet median and re-dispatches
(or backup-dispatches) jobs that fall below a configurable fraction of it.

Hot path (the scaling overhaul): the dispatcher no longer issues overwatch
range scans per operation. It subscribes to ``/clusters/``, ``/telemetry/``
and ``/jobs/`` watch events and maintains materialized views:

  * ``_clusters`` / ``_telemetry`` — registration + telemetry directories,
    incrementally invalidated (``clusters()``/``telemetry()`` are now O(n)
    dict copies with zero store round-trips);
  * ``_load_order`` — a (load, cluster) sorted candidate structure, so
    ``pick()`` finds the least-loaded eligible clusters without re-reading
    telemetry;
  * ``_caps_index`` — capability -> clusters, so ``candidates()`` intersects
    small sets instead of scanning every registration;
  * ``_jobs_by_cluster`` / ``_placement`` / ``_status`` / ``_running`` —
    placement and status views, so ``recover_cluster_jobs`` touches only the
    dead cluster's jobs and ``check_stragglers`` only running jobs, never the
    whole ``/jobs/`` keyspace.

Every view is derived purely from watch events emitted by the (linearizable)
overwatch, so it is exactly as consistent as the range scans it replaces.

Batch-event form (the sharding/coalescing overhaul): the views subscribe via
``watch_batch`` and ingest revision-ordered event lists — one callback per
flush instead of one per mutation. Every public method that reads a view
opens with ``ow.flush_watches()``, the read barrier that makes the views
exactly as fresh as a linearizable range would be; with coalescing off the
batches are synchronous singletons and behavior is unchanged.

``submit_many`` amortizes admission over a batch: the min-load block of
``_load_order`` is computed once and unconstrained jobs round-robin across it
without re-probing per job.

Depth-aware placement (the data-plane overhaul): the broker publishes
per-queue ``{"ready", "inflight"}`` depth under ``/queues/<name>`` (via the
pipeline composer's sweep-cadence publisher), and the dispatcher keeps a
materialized ``_queue_depth`` view of it. A job that declares the queues its
workers will consume (``tags={"queues": [...]}`` — a worker-pod job) is
placed on the eligible cluster whose capabilities cover the deepest matching
backlog: ready tasks in a compliance queue can only be drained by workers on
clusters holding those capability tags, so placement follows the backlog.
Ties (including "no depth telemetry yet") fall back to least-load, so the
bias degrades to plain least-loaded placement when queues are empty.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.overwatch import OverwatchService
from repro.core.transport import DeliveryError, Envelope, Fabric


@dataclasses.dataclass
class RoutingRule:
    """If ``match(job)`` then restrict candidates to ``clusters``."""
    name: str
    match: Callable[[dict], bool]
    clusters: List[str]


class Dispatcher:
    def __init__(self, fabric: Fabric, master: str, overwatch: OverwatchService,
                 straggler_factor: float = 0.5):
        self.fabric = fabric
        self.master = master
        self.ow = overwatch
        self.rules: List[RoutingRule] = []
        self.straggler_factor = straggler_factor
        self._rr = itertools.count()
        # flight recorder (set by ManagementPlane): sampled job submissions
        # open a "job" root + "dispatch" child whose context rides the
        # dispatch envelope to the remote agent
        self.tracer = None
        self.dispatch_log: List[tuple] = []
        self._relays: Dict[tuple, tuple] = {}
        # ------------------------- materialized views (watch-invalidated)
        self._clusters: Dict[str, dict] = {}
        self._telemetry: Dict[str, dict] = {}
        self._cur_load: Dict[str, float] = {}
        self._load_order: List[Tuple[float, str]] = []   # sorted (load, name)
        self._caps_index: Dict[str, Set[str]] = {}
        self._placement: Dict[str, dict] = {}
        self._jobs_by_cluster: Dict[str, Set[str]] = {}
        self._status: Dict[str, dict] = {}
        self._running: Set[str] = set()
        self._queue_depth: Dict[str, dict] = {}
        self._retired: Set[str] = set()     # retired in absentia, unconfirmed
        self._straggler_rules: Dict[str, RoutingRule] = {}
        self._down_callbacks: List[Callable[[str], None]] = []
        # failure detector + view maintenance: subscribe (batch form) before
        # any registration so the views never miss an event. Registration
        # order is load-bearing under coalesced delivery: batches flush in
        # registration order, and a cluster tombstone's recovery side effect
        # reads the job/telemetry views — so those views must ingest their
        # slice of the flush round FIRST, or a job placed in the same round a
        # cluster dies would be invisible to recover_cluster_jobs and lost.
        overwatch.watch_batch("/jobs/", self._on_job_batch)
        overwatch.watch_batch("/telemetry/", self._on_telemetry_batch)
        overwatch.watch_batch("/queues/", self._on_queue_batch)
        overwatch.watch_batch("/clusters/", self._on_cluster_batch)
        self._seed_views()

    # ----------------------------------------------------------- view maintenance
    def _seed_views(self) -> None:
        """Replay pre-existing state (no-op when, as usual, the dispatcher is
        created before any cluster registers)."""
        for key, val in self.ow.handle(
                {"op": "range", "prefix": "/clusters/"})["items"].items():
            self._cluster_put(key.split("/")[-1], val)
        for key, val in self.ow.handle(
                {"op": "range", "prefix": "/telemetry/"})["items"].items():
            self._telemetry_put(key.split("/")[-1], val)
        for key, val in self.ow.handle(
                {"op": "range", "prefix": "/jobs/"})["items"].items():
            self._job_put(key, val)
        for key, val in self.ow.handle(
                {"op": "range", "prefix": "/queues/"})["items"].items():
            self._queue_depth[key[len("/queues/"):]] = val

    def _cluster_put(self, name: str, info: dict) -> None:
        old = self._clusters.get(name)
        if old is not None:
            for cap in old.get("capabilities", ()):
                self._caps_index.get(cap, set()).discard(name)
        else:
            load = self._telemetry.get(name, {}).get("load", 0.0)
            self._cur_load[name] = load
            bisect.insort(self._load_order, (load, name))
        self._clusters[name] = info
        for cap in info.get("capabilities", ()):
            self._caps_index.setdefault(cap, set()).add(name)

    def _cluster_del(self, name: str) -> None:
        info = self._clusters.pop(name, None)
        if info is None:
            return
        for cap in info.get("capabilities", ()):
            self._caps_index.get(cap, set()).discard(name)
        self._load_order_discard(name)

    def _load_order_discard(self, name: str) -> None:
        load = self._cur_load.pop(name, None)
        if load is None:
            return
        i = bisect.bisect_left(self._load_order, (load, name))
        if i < len(self._load_order) and self._load_order[i] == (load, name):
            del self._load_order[i]

    def _telemetry_put(self, name: str, tele: dict) -> None:
        self._telemetry[name] = tele
        if name in self._clusters:
            self._load_order_discard(name)
            load = tele.get("load", 0.0)
            self._cur_load[name] = load
            bisect.insort(self._load_order, (load, name))

    def _on_cluster_batch(self, events: List[tuple]) -> None:
        for event, key, value, _rev in events:
            cluster = key.split("/")[-1]
            if event == "put":
                self._cluster_put(cluster, value)
                continue
            if event != "delete":
                continue
            self._cluster_del(cluster)
            for cb in self._down_callbacks:
                cb(cluster)
            self.recover_cluster_jobs(cluster)

    def _on_telemetry_batch(self, events: List[tuple]) -> None:
        for event, key, value, _rev in events:
            cluster = key.split("/")[-1]
            if event == "put":
                self._telemetry_put(cluster, value)
            elif event == "delete":
                self._telemetry.pop(cluster, None)
                if cluster in self._clusters:
                    self._load_order_discard(cluster)
                    self._cur_load[cluster] = 0.0
                    bisect.insort(self._load_order, (0.0, cluster))

    def _job_put(self, key: str, value: dict) -> None:
        parts = key.split("/")
        if len(parts) != 4:
            return
        _, _, jid, leaf = parts
        if leaf == "status" and jid in self._retired:
            # a retired-in-absentia pod's agent is talking again (partition
            # healed before its lease expired): finish the retirement —
            # re-send the retire and re-tombstone the key it just re-put —
            # instead of letting the zombie repopulate the views forever
            cluster = value.get("cluster")
            if cluster is not None and cluster in self._clusters:
                try:
                    self._send_agent(cluster,
                                     {"kind": "retire", "job_id": jid})
                    self._retired.discard(jid)
                except DeliveryError:
                    pass
            self.ow.handle({"op": "delete", "key": key})
            return
        if leaf == "placement":
            old = self._placement.get(jid)
            if old is not None:
                self._jobs_by_cluster.get(old["cluster"], set()).discard(jid)
            self._placement[jid] = value
            self._jobs_by_cluster.setdefault(value["cluster"], set()).add(jid)
        elif leaf == "status":
            self._status[jid] = value
            if value.get("status") == "running":
                self._running.add(jid)
            else:
                self._running.discard(jid)
            if value.get("status") == "done":
                self._gc_straggler_rule(jid)

    def _on_job_batch(self, events: List[tuple]) -> None:
        for event, key, value, _rev in events:
            if event == "put":
                self._job_put(key, value)
                continue
            parts = key.split("/")
            if len(parts) != 4:
                continue
            _, _, jid, leaf = parts
            if leaf == "placement":
                old = self._placement.pop(jid, None)
                if old is not None:
                    self._jobs_by_cluster.get(old["cluster"],
                                              set()).discard(jid)
            elif leaf == "status":
                self._status.pop(jid, None)
                self._running.discard(jid)

    def _on_queue_batch(self, events: List[tuple]) -> None:
        for event, key, value, _rev in events:
            queue = key[len("/queues/"):]
            if event == "put":
                self._queue_depth[queue] = value
            elif event == "delete":
                self._queue_depth.pop(queue, None)

    def _gc_straggler_rule(self, jid: str) -> None:
        """Satellite fix: straggler rules used to accumulate forever, slowing
        ``candidates()`` for every future job. Drop the rule once the
        re-dispatched job completes."""
        rule = self._straggler_rules.pop(jid, None)
        if rule is not None:
            try:
                self.rules.remove(rule)
            except ValueError:
                pass

    # ---------------------------------------------------------------- directories
    def clusters(self) -> Dict[str, dict]:
        self.ow.flush_watches()              # read barrier for the views
        return dict(self._clusters)

    def telemetry(self) -> Dict[str, dict]:
        self.ow.flush_watches()
        return dict(self._telemetry)

    def queue_depths(self) -> Dict[str, dict]:
        self.ow.flush_watches()
        return dict(self._queue_depth)

    def job_status(self, job_id: str) -> Optional[dict]:
        """The job's last reported status, straight from the watch view."""
        self.ow.flush_watches()
        return self._status.get(job_id)

    def placement_of(self, job_id: str) -> Optional[dict]:
        """The job's placement record, straight from the watch view."""
        self.ow.flush_watches()
        return self._placement.get(job_id)

    def placements(self) -> Dict[str, dict]:
        """Every live placement record (job_id -> record) — the recovered
        autoscaler's adoption view: placements are the only surviving truth
        about which worker-pod jobs existed before a master crash."""
        self.ow.flush_watches()
        return dict(self._placement)

    def _agent_addr(self, cluster: str):
        return tuple(self._clusters[cluster]["agent_addr"])

    # ----------------------------------------------------------------- CRD pubsub
    def broadcast_spec(self, spec, master_state) -> None:
        """The pubsub publisher: push the CRD to every registered agent."""
        self.ow.flush_watches()
        # one Envelope for the whole fan-out: the message is identical per
        # cluster, so the AppSpec walk for byte accounting happens once, not
        # O(clusters) times
        msg = Envelope({"kind": "configure", "spec": spec,
                        "master_state": master_state})
        for cluster in list(self._clusters):
            try:
                self._send_agent(cluster, msg)
            except DeliveryError:
                # partitioned but not yet tombstoned: skip it — the lease
                # sweep will deregister it, and a broadcast must never be
                # hostage to one unreachable cluster (elastic fleets
                # re-broadcast the spec on every pod change)
                continue

    def send_agent(self, cluster: str, msg: dict) -> dict:
        """Public master->agent RPC over the dispatch relay (the replica
        shipper's path). Raises ``KeyError`` for an unknown/tombstoned
        cluster and ``DeliveryError`` when the relay is unreachable."""
        return self._send_agent(cluster, msg)

    def _send_agent(self, cluster: str, msg: dict) -> dict:
        info = self._clusters[cluster]          # one lookup, zero round-trips
        addr = tuple(info["agent_addr"])
        if cluster == self.master:              # single hop: no Envelope copy
            return self.fabric.send(self.master, "system@dispatcher",
                                    cluster, addr, msg)
        # master -> private agent rides the lazily-created dispatch relay
        # (multiple hops: size the envelope once)
        if not isinstance(msg, Envelope):
            msg = Envelope(msg)
        return self.fabric.send(self.master, "system@dispatcher", self.master,
                                self._master_relay(cluster, info["idx"], addr),
                                msg)

    def _master_relay(self, cluster: str, idx: int, agent_addr) -> tuple:
        """Lazily create the master->agent dispatch channel (initialization).
        A channel already terminating at the relay address is reused — a
        dispatcher rebuilt by crash recovery rides its predecessor's tunnels
        instead of stacking duplicates."""
        key = ("dispatch-relay", cluster)
        if key not in self._relays:
            local = (f"10.200.0.{idx}", 6100)
            if self.fabric.channel_at(self.master, local) is None:
                self.fabric.create_channel(self.master, local, cluster,
                                           agent_addr)
            self._relays[key] = local
        return self._relays[key]

    # ------------------------------------------------------------------- dispatch
    def add_rule(self, rule: RoutingRule) -> None:
        self.rules.append(rule)

    def _eligible(self, needs: Set[str],
                  matched_rules: List[RoutingRule]) -> Set[str]:
        if needs:
            sets = [self._caps_index.get(cap, set()) for cap in needs]
            cands = set.intersection(*sets) if sets else set(self._clusters)
        else:
            cands = set(self._clusters)
        for rule in matched_rules:
            cands &= set(rule.clusters)
        return cands

    def candidates(self, job: dict) -> List[str]:
        self.ow.flush_watches()
        needs = set(job.get("tags", {}).get("requires", ()))
        return sorted(self._eligible(
            needs, [r for r in self.rules if r.match(job)]))

    def pick(self, job: dict) -> Optional[str]:
        self.ow.flush_watches()
        needs = set(job.get("tags", {}).get("requires", ()))
        matched = [r for r in self.rules if r.match(job)]
        return self._pick(needs, matched,
                          job.get("tags", {}).get("queues", ()),
                          job.get("tags", {}).get("cost_class"))

    def _min_load_hi(self) -> int:
        """End index of the least-loaded tie block: the contiguous,
        name-sorted front of ``_load_order`` — O(log n). 0 when no cluster
        is registered."""
        if not self._load_order:
            return 0
        min_load = self._load_order[0][0]
        return bisect.bisect_right(self._load_order, (min_load, "\U0010ffff"))

    def _pick(self, needs: Set[str], matched: List[RoutingRule],
              queue_pref=(), cost_class: Optional[str] = None
              ) -> Optional[str]:
        # roofline steering: a job tagged with a cost class prefers clusters
        # whose capability profile matches its tier (accel for compute/
        # memory-bound, cheap-io for IO-bound). Soft preference only — with
        # no matching tier registered, placement degrades to the depth/load
        # logic below, and an untagged job is byte-identical to today.
        pref_cap = None
        if cost_class is not None:
            from repro.roofline.cost import steering_cap
            pref_cap = steering_cap(cost_class)
        if queue_pref:
            # worker-pod job: deepest matching backlog wins, least-load breaks
            # ties (and carries the decision when no depth is published yet).
            # Queue names ARE capability sets (see ``scheduler.queue_for``):
            # decode each preferred queue's tags + ready depth once, then the
            # per-cluster loop is just a subset test and a sum.
            cands = self._eligible(needs, matched)
            if not cands:
                return None
            pref = []
            for q in queue_pref:
                ready = self._queue_depth.get(q, {}).get("ready", 0)
                if ready:
                    pref.append((set(q.split(",")) if q != "default"
                                 else set(), ready))
            best: List[str] = []
            best_key = None
            for name in sorted(cands):
                caps = set(self._clusters[name].get("capabilities", ()))
                score = sum(r for tags, r in pref if tags <= caps)
                # depth first; tier match breaks depth ties (cold start: no
                # depth published yet steers by profile alone); then load
                key = (-score,
                       0 if pref_cap is None or pref_cap in caps else 1,
                       self._cur_load.get(name, 0.0))
                if best_key is None or key < best_key:
                    best_key, best = key, [name]
                elif key == best_key:
                    best.append(name)
            return best[next(self._rr) % len(best)]
        if pref_cap is not None:
            cands = self._eligible(needs, matched)
            tier = cands & self._caps_index.get(pref_cap, set())
            if tier and tier != cands:
                # least-load within the matching tier
                best, best_load = [], None
                for load, name in self._load_order:
                    if name not in tier:
                        continue
                    if best_load is None:
                        best_load = load
                    elif load != best_load:
                        break
                    best.append(name)
                return best[next(self._rr) % len(best)]
        if not needs and not matched:
            # unconstrained job: every cluster is eligible — index the tie
            # block directly, no list materialization on the per-job path
            hi = self._min_load_hi()
            if not hi:
                return None
            return self._load_order[next(self._rr) % hi][1]
        cands = self._eligible(needs, matched)
        if not cands:
            return None
        # walk the load-ordered structure: the first eligible entry carries the
        # minimum load; ties are adjacent and already name-sorted
        best: List[str] = []
        best_load: Optional[float] = None
        for load, name in self._load_order:
            if name not in cands:
                continue
            if best_load is None:
                best_load = load
            elif load != best_load:
                break
            best.append(name)
        # cands is a subset of _clusters and _load_order mirrors _clusters,
        # so the walk always finds at least one entry
        return best[next(self._rr) % len(best)]

    def _trace_root(self, job: dict):
        """Root span for a sampled job submission (None when untraced)."""
        tr = self.tracer
        if tr is None:
            return None
        tid = f"job/{job['job_id']}"
        if not tr.sampled(tid):
            return None
        return tr.open_span("job", "dispatcher", trace_id=tid)

    def submit(self, job: dict) -> str:
        root = self._trace_root(job)
        try:
            cluster = self.pick(job)
            if cluster is None:
                raise RuntimeError(
                    f"no eligible cluster for job {job['job_id']} "
                    f"(requires {job.get('tags', {})})")
            self._dispatch_to(cluster, job, _root=root)
            return cluster
        finally:
            if root is not None:
                self.tracer.end_span(root)

    def dispatch_to(self, cluster: str, job: dict) -> None:
        """Public placement-decided dispatch: the caller picked the cluster
        (e.g. the autoscaler, which needs to know WHICH cluster an
        unreachable dispatch was aimed at so it can exclude it and retry)."""
        self._dispatch_to(cluster, job)

    def _dispatch_to(self, cluster: str, job: dict, _root=None) -> None:
        """Placement already decided: ship the job and record the placement.
        Traced submissions attach the dispatch span's context to the
        envelope; without a caller-held root (``dispatch_to``/
        ``submit_many``) a sampled job gets its own root here."""
        tr = self.tracer
        msg = {"kind": "dispatch", "job": job}
        sp = owned = None
        if tr is not None:
            if _root is None:
                _root = owned = self._trace_root(job)
            if _root is not None:
                sp = tr.open_span("dispatch", "dispatcher", parent=_root,
                                  attrs={"cluster": cluster})
                msg["trace"] = sp
        try:
            resp = self._send_agent(cluster, msg)
            if not resp.get("ok"):
                raise RuntimeError(f"dispatch failed: {resp.get('error')}")
            self.ow.handle(
                {"op": "put", "key": f"/jobs/{job['job_id']}/placement",
                 "value": {"cluster": cluster, "job": job,
                           "clock": self.fabric.clock}})
            self.dispatch_log.append(
                (self.fabric.clock, job["job_id"], cluster))
        finally:
            if sp is not None:
                tr.end_span(sp)
            if owned is not None:
                tr.end_span(owned)

    def submit_many(self, jobs: List[dict]) -> List[str]:
        """Batched admission: amortize ``pick()`` over the batch.

        The min-load block at the front of ``_load_order`` is computed once;
        unconstrained jobs round-robin across it with no per-job re-probe
        (telemetry cannot move mid-batch — loads only change via heartbeats,
        which land between fabric ticks). Constrained jobs (capability tags,
        matching routing rules, or a queue-depth placement preference) fall
        back to a per-job ``pick()``. Returns the chosen cluster per job, in
        submission order.
        """
        self.ow.flush_watches()
        placed: List[str] = []
        block: Optional[List[str]] = None
        for job in jobs:
            needs = set(job.get("tags", {}).get("requires", ()))
            matched = [r for r in self.rules if r.match(job)]
            queue_pref = job.get("tags", {}).get("queues", ())
            cost_class = job.get("tags", {}).get("cost_class")
            if not needs and not matched and not queue_pref \
                    and cost_class is None:
                while True:
                    if block is None:
                        hi = self._min_load_hi()
                        if not hi:
                            raise RuntimeError(
                                f"no eligible cluster for job {job['job_id']}")
                        block = [name for _, name in self._load_order[:hi]]
                    cluster = block[next(self._rr) % len(block)]
                    if cluster in self._clusters:
                        break
                    # a cluster died mid-batch (lease swept by one of our own
                    # placement puts, sync-notify mode): drop the stale block
                    # and re-probe
                    block = None
            else:
                cluster = self._pick(needs, matched, queue_pref, cost_class)
                if cluster is None:
                    raise RuntimeError(
                        f"no eligible cluster for job {job['job_id']} "
                        f"(requires {job.get('tags', {})})")
            try:
                self._dispatch_to(cluster, job)
            except DeliveryError:
                # under coalesced delivery the death of a cluster mid-batch is
                # only a pending tombstone, invisible to the membership check
                # above — the dispatch itself fails instead. Take the barrier,
                # re-place this one job on the refreshed views, and keep the
                # rest of the batch going. Only unreachability retries: an
                # agent-side rejection (RuntimeError) is job-intrinsic and
                # propagates exactly as submit() would — already-placed jobs
                # of the batch stay placed.
                self.ow.flush_watches()
                block = None
                cluster = self._pick(needs, matched, queue_pref, cost_class)
                if cluster is None:
                    raise RuntimeError(
                        f"no eligible cluster for job {job['job_id']} "
                        f"(requires {job.get('tags', {})})")
                self._dispatch_to(cluster, job)
            placed.append(cluster)
        return placed

    def retire(self, job_id: str) -> bool:
        """Gracefully retire a placed job (the autoscaler's scale-down path):
        the hosting agent stops it, then the job's ``/jobs/<id>`` placement
        and status records are DELETED — unlike ``cancel``, retirement never
        reads as a failure, and unlike completion it leaves no store records
        behind, so recovery/stragglers can never resurrect a retired pod and
        elastic churn (fleets scaling 0 -> N -> 0 forever) cannot leak keys
        or view entries. If the hosting cluster is unreachable the records
        are still tombstoned ("retired in absentia"): with no placement on
        file, the eventual cluster-death recovery skips the job. Returns
        False only when the job has no placement at all (already gone —
        retirement is idempotent)."""
        self.ow.flush_watches()
        placement = self._placement.get(job_id)
        if placement is None:
            return False
        cluster = placement["cluster"]
        confirmed = False
        if cluster in self._clusters:
            try:
                self._send_agent(cluster, {"kind": "retire",
                                           "job_id": job_id})
                confirmed = True
            except DeliveryError:
                pass                     # in absentia: tombstones still land
        if not confirmed:
            # the agent never heard the retire: if its partition heals before
            # the lease sweep, its next heartbeat re-puts the status key —
            # _job_put watches for that and finishes the retirement then
            self._retired.add(job_id)
        self.ow.handle({"op": "delete", "key": f"/jobs/{job_id}/placement"})
        self.ow.handle({"op": "delete", "key": f"/jobs/{job_id}/status"})
        self.ow.handle({"op": "delete", "key": f"/checkpoints/{job_id}"})
        return True

    # ----------------------------------------------------------- failure handling
    def on_cluster_down(self, cb: Callable[[str], None]) -> None:
        self._down_callbacks.append(cb)

    def recover_cluster_jobs(self, dead: str) -> List[str]:
        """Re-dispatch every job placed on a dead cluster from its last committed
        checkpoint manifest. Uses the per-cluster placement index: cost scales
        with the dead cluster's jobs, not the whole /jobs/ keyspace."""
        self.ow.flush_watches()
        moved = []
        for jid in sorted(self._jobs_by_cluster.get(dead, set())):
            placement = self._placement.get(jid)
            if placement is None:
                continue
            status = self._status.get(jid)
            if status and status.get("status") == "done":
                continue
            job = dict(placement["job"])
            ck = self.ow.handle({"op": "get",
                                 "key": f"/checkpoints/{jid}"})["value"]
            if ck:
                job["restore_from"] = ck
            try:
                new_cluster = self.submit(job)
                moved.append(f"{jid}->{new_cluster}")
            except (RuntimeError, DeliveryError):
                self.ow.handle({"op": "put", "key": f"/jobs/{jid}/status",
                                "value": {"cluster": None, "status": "pending",
                                          "progress": 0.0, "rate": 0.0,
                                          "clock": self.fabric.clock}})
        return moved

    # -------------------------------------------------------- straggler mitigation
    def check_stragglers(self) -> List[str]:
        """Compare per-job step rates; re-dispatch jobs below factor x median.
        Scans the running-jobs view only — no /jobs/ range round-trip."""
        self.ow.flush_watches()
        rates = {}
        for jid in sorted(self._running):
            val = self._status.get(jid)
            if val is not None:
                rates[jid] = (val.get("rate", 0.0), val["cluster"])
        if len(rates) < 2:
            return []
        rs = sorted(r for r, _ in rates.values())
        median = rs[len(rs) // 2]
        moved = []
        for jid, (rate, cluster) in rates.items():
            if median > 0 and rate < self.straggler_factor * median:
                placement = self._placement.get(jid)
                if placement is None:
                    continue
                job = dict(placement["job"])
                ck = self.ow.handle({"op": "get",
                                     "key": f"/checkpoints/{jid}"})["value"]
                if ck:
                    job["restore_from"] = ck
                # exclude the slow cluster, cancel there, re-dispatch; one rule
                # per job, GC'd on completion (see _gc_straggler_rule). A job
                # straggling again folds the new exclusion into the replacement
                # rule instead of orphaning the old one in self.rules
                prev = self._straggler_rules.get(jid)
                eligible = (prev.clusters if prev is not None
                            else list(self._clusters))
                self._gc_straggler_rule(jid)
                rule = RoutingRule(
                    name=f"straggler-{jid}",
                    match=lambda j, _jid=jid: j["job_id"] == _jid,
                    clusters=[c for c in eligible if c != cluster])
                self.add_rule(rule)
                self._straggler_rules[jid] = rule
                try:
                    self._send_agent(cluster, {"kind": "cancel", "job_id": jid})
                    new_cluster = self.submit(job)
                    moved.append(f"{jid}:{cluster}->{new_cluster}")
                except (RuntimeError, DeliveryError):
                    pass
        return moved
