"""Intelligent job dispatcher (paper §2.iv) + failure detector + stragglers.

Routing: a job carries tags (``requires`` capabilities, ``locality`` preference);
the dispatcher filters registered clusters by capability, honors explicit routing
rules (the paper's "pre-defined service routing rule"), then picks the least
loaded by telemetry. It doubles as the pubsub message publisher of §4.1: CRD
configuration objects are broadcast to every registered control agent.

Fault tolerance: cluster registrations are lease-backed; the overwatch deletes
them when heartbeats stop. The dispatcher watches the tombstones and re-dispatches
the dead cluster's jobs to healthy clusters — resuming from the job's last
committed checkpoint manifest (recorded under /checkpoints/<job>). Straggler
mitigation compares per-job step rates against the fleet median and re-dispatches
(or backup-dispatches) jobs that fall below a configurable fraction of it.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional

from repro.core.overwatch import OverwatchService
from repro.core.transport import DeliveryError, Fabric


@dataclasses.dataclass
class RoutingRule:
    """If ``match(job)`` then restrict candidates to ``clusters``."""
    name: str
    match: Callable[[dict], bool]
    clusters: List[str]


class Dispatcher:
    def __init__(self, fabric: Fabric, master: str, overwatch: OverwatchService,
                 straggler_factor: float = 0.5):
        self.fabric = fabric
        self.master = master
        self.ow = overwatch
        self.rules: List[RoutingRule] = []
        self.straggler_factor = straggler_factor
        self._rr = itertools.count()
        self.dispatch_log: List[tuple] = []
        # failure detector: watch registration tombstones
        overwatch.watch("/clusters/", self._on_cluster_event)
        self._down_callbacks: List[Callable[[str], None]] = []

    # ---------------------------------------------------------------- directories
    def clusters(self) -> Dict[str, dict]:
        return {k.split("/")[-1]: v
                for k, v in self.ow.handle({"op": "range",
                                            "prefix": "/clusters/"})["items"].items()}

    def telemetry(self) -> Dict[str, dict]:
        return {k.split("/")[-1]: v
                for k, v in self.ow.handle({"op": "range",
                                            "prefix": "/telemetry/"})["items"].items()}

    def _agent_addr(self, cluster: str):
        info = self.clusters()[cluster]
        return tuple(info["agent_addr"])

    # ----------------------------------------------------------------- CRD pubsub
    def broadcast_spec(self, spec, master_state) -> None:
        """The pubsub publisher: push the CRD to every registered agent."""
        for cluster, info in self.clusters().items():
            self._send_agent(cluster, {"kind": "configure", "spec": spec,
                                       "master_state": master_state})

    def _send_agent(self, cluster: str, msg: dict) -> dict:
        addr = self._agent_addr(cluster)
        if cluster == self.master:
            return self.fabric.send(self.master, "system@dispatcher",
                                    cluster, addr, msg)
        # master -> private agent rides the agent bootstrap channel
        from repro.core.agent import AGENT_PORT
        from repro.core import gateways as GW
        idx = self.clusters()[cluster]["idx"]
        # dispatcher reaches remote agents through a dedicated relay channel
        relay = (f"10.{idx}.0.30", AGENT_PORT)
        return self.fabric.send(self.master, "system@dispatcher", self.master,
                                self._master_relay(cluster, idx, addr), msg)

    def _master_relay(self, cluster: str, idx: int, agent_addr) -> tuple:
        """Lazily create the master->agent dispatch channel (initialization)."""
        key = ("dispatch-relay", cluster)
        if not hasattr(self, "_relays"):
            self._relays = {}
        if key not in self._relays:
            local = (f"10.200.0.{idx}", 6100)
            ch = self.fabric.create_channel(self.master, local, cluster,
                                            agent_addr)
            self._relays[key] = local
        return self._relays[key]

    # ------------------------------------------------------------------- dispatch
    def add_rule(self, rule: RoutingRule) -> None:
        self.rules.append(rule)

    def candidates(self, job: dict) -> List[str]:
        regs = self.clusters()
        needs = set(job.get("tags", {}).get("requires", ()))
        cands = [c for c, info in regs.items()
                 if needs.issubset(set(info.get("capabilities", ())))]
        for rule in self.rules:
            if rule.match(job):
                cands = [c for c in cands if c in rule.clusters]
        return sorted(cands)

    def pick(self, job: dict) -> Optional[str]:
        cands = self.candidates(job)
        if not cands:
            return None
        tele = self.telemetry()
        loads = {c: tele.get(c, {}).get("load", 0.0) for c in cands}
        m = min(loads.values())
        best = [c for c in cands if loads[c] == m]
        return best[next(self._rr) % len(best)]

    def submit(self, job: dict) -> str:
        cluster = self.pick(job)
        if cluster is None:
            raise RuntimeError(f"no eligible cluster for job {job['job_id']} "
                               f"(requires {job.get('tags', {})})")
        resp = self._send_agent(cluster, {"kind": "dispatch", "job": job})
        if not resp.get("ok"):
            raise RuntimeError(f"dispatch failed: {resp.get('error')}")
        self.ow.handle({"op": "put", "key": f"/jobs/{job['job_id']}/placement",
                        "value": {"cluster": cluster, "job": job,
                                  "clock": self.fabric.clock}})
        self.dispatch_log.append((self.fabric.clock, job["job_id"], cluster))
        return cluster

    # ----------------------------------------------------------- failure handling
    def on_cluster_down(self, cb: Callable[[str], None]) -> None:
        self._down_callbacks.append(cb)

    def _on_cluster_event(self, event: str, key: str, value, rev: int) -> None:
        if event != "delete":
            return
        cluster = key.split("/")[-1]
        for cb in self._down_callbacks:
            cb(cluster)
        self.recover_cluster_jobs(cluster)

    def recover_cluster_jobs(self, dead: str) -> List[str]:
        """Re-dispatch every job placed on a dead cluster from its last committed
        checkpoint manifest."""
        moved = []
        placements = self.ow.handle(
            {"op": "range", "prefix": "/jobs/"})["items"]
        for key, val in placements.items():
            if not key.endswith("/placement") or val["cluster"] != dead:
                continue
            jid = key.split("/")[2]
            status = placements.get(f"/jobs/{jid}/status")
            if status and status.get("status") == "done":
                continue
            job = dict(val["job"])
            ck = self.ow.handle({"op": "get",
                                 "key": f"/checkpoints/{jid}"})["value"]
            if ck:
                job["restore_from"] = ck
            try:
                new_cluster = self.submit(job)
                moved.append(f"{jid}->{new_cluster}")
            except (RuntimeError, DeliveryError):
                self.ow.handle({"op": "put", "key": f"/jobs/{jid}/status",
                                "value": {"cluster": None, "status": "pending",
                                          "progress": 0.0, "rate": 0.0,
                                          "clock": self.fabric.clock}})
        return moved

    # -------------------------------------------------------- straggler mitigation
    def check_stragglers(self) -> List[str]:
        """Compare per-job step rates; re-dispatch jobs below factor x median."""
        statuses = self.ow.handle({"op": "range", "prefix": "/jobs/"})["items"]
        rates = {}
        for key, val in statuses.items():
            if key.endswith("/status") and val.get("status") == "running":
                jid = key.split("/")[2]
                rates[jid] = (val.get("rate", 0.0), val["cluster"])
        if len(rates) < 2:
            return []
        rs = sorted(r for r, _ in rates.values())
        median = rs[len(rs) // 2]
        moved = []
        for jid, (rate, cluster) in rates.items():
            if median > 0 and rate < self.straggler_factor * median:
                job_key = f"/jobs/{jid}/placement"
                placement = self.ow.handle({"op": "get", "key": job_key})["value"]
                job = dict(placement["job"])
                ck = self.ow.handle({"op": "get",
                                     "key": f"/checkpoints/{jid}"})["value"]
                if ck:
                    job["restore_from"] = ck
                # exclude the slow cluster, cancel there, re-dispatch
                self.add_rule(RoutingRule(
                    name=f"straggler-{jid}",
                    match=lambda j, _jid=jid: j["job_id"] == _jid,
                    clusters=[c for c in self.clusters() if c != cluster]))
                try:
                    self._send_agent(cluster, {"kind": "cancel", "job_id": jid})
                    new_cluster = self.submit(job)
                    moved.append(f"{jid}:{cluster}->{new_cluster}")
                except (RuntimeError, DeliveryError):
                    pass
        return moved
