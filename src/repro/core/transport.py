"""Simulated inter-cluster fabric (the paper's network substrate, §4.1).

Models exactly the connectivity regime the paper assumes:

  * Within a cluster: any endpoint can reach any (ip, port) — fast local network
    (the ICI/intra-cluster path).
  * Across clusters: NO direct reachability. The only cross-cluster transport is a
    ``Channel`` (the SSH/port-forwarding tunnel of Algorithm 4), pinned to gateway
    endpoints. Traffic that is not routed through a configured gateway chain simply
    does not arrive — mirroring real firewalled private clouds.
  * Access control: default-deny pod->service tables (Algorithm 3) enforced at
    send time.

Delivery is synchronous and deterministic; a simulated clock (``tick``) drives
lease expiry and heartbeat scheduling in the layers above. Per-edge byte counters
make the paper's "thin cross-boundary traffic" claim measurable
(``cross_cluster_bytes`` vs ``local_bytes``), and fault injection (partition a
cluster, kill a channel) drives the fault-tolerance tests.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import Counter, defaultdict
from typing import Any, Callable, Dict, Optional, Tuple

Address = Tuple[str, int]            # (ip, port)


class DeliveryError(Exception):
    """Raised when the fabric cannot deliver a message (no route / denied / down)."""


@dataclasses.dataclass
class Channel:
    """A cross-cluster tunnel between two gateway endpoints (Algorithm 4)."""
    channel_id: int
    cluster_a: str
    addr_a: Address
    cluster_b: str
    addr_b: Address
    alive: bool = True
    bytes_ab: int = 0
    bytes_ba: int = 0

    def other_end(self, cluster: str, addr: Address):
        if (cluster, addr) == (self.cluster_a, self.addr_a):
            return self.cluster_b, self.addr_b
        if (cluster, addr) == (self.cluster_b, self.addr_b):
            return self.cluster_a, self.addr_a
        return None


def _payload_bytes(payload: Any) -> int:
    if isinstance(payload, (bytes, bytearray)):
        return len(payload)
    if isinstance(payload, str):
        return len(payload.encode())
    if isinstance(payload, dict):
        return sum(_payload_bytes(k) + _payload_bytes(v)
                   for k, v in payload.items())
    if isinstance(payload, (list, tuple)):
        return sum(_payload_bytes(v) for v in payload)
    if isinstance(payload, (int, float, bool)) or payload is None:
        return 8
    return 64  # opaque object envelope


class Fabric:
    """The hybrid-cloud network: clusters, gateways, channels, ACLs, a clock."""

    def __init__(self):
        self.clock: float = 0.0
        self._handlers: Dict[Tuple[str, Address], Callable] = {}
        self._forwards: Dict[Tuple[str, Address], Address] = {}
        self._channels: Dict[Tuple[str, Address], Channel] = {}
        self._channel_ids = itertools.count(1)
        self.channels: Dict[int, Channel] = {}
        self._partitioned: set = set()           # clusters cut off from everything
        self._acl: Dict[str, "AclTable"] = {}
        self.local_bytes: Counter = Counter()    # per-cluster intra bytes
        self.cross_bytes: Counter = Counter()    # per (src, dst) cluster pair
        self.message_log: list = []
        self._timers: list = []                  # (deadline, callback) heap-ish

    # ------------------------------------------------------------------- topology
    def register_handler(self, cluster: str, addr: Address,
                         handler: Callable[[Any], Any]) -> None:
        self._handlers[(cluster, addr)] = handler

    def add_forward(self, cluster: str, src: Address, dst: Address) -> None:
        """Istio-style in-cluster forwarding rule src -> dst (Algorithm 2)."""
        self._forwards[(cluster, src)] = dst

    def remove_forward(self, cluster: str, src: Address) -> None:
        self._forwards.pop((cluster, src), None)

    def create_channel(self, cluster_a: str, addr_a: Address, cluster_b: str,
                       addr_b: Address) -> Channel:
        ch = Channel(next(self._channel_ids), cluster_a, addr_a, cluster_b,
                     addr_b)
        self._channels[(cluster_a, addr_a)] = ch
        self._channels[(cluster_b, addr_b)] = ch
        self.channels[ch.channel_id] = ch
        return ch

    def set_acl(self, cluster: str, table: "AclTable") -> None:
        self._acl[cluster] = table

    # ------------------------------------------------------------- fault injection
    def partition_cluster(self, cluster: str) -> None:
        self._partitioned.add(cluster)

    def heal_cluster(self, cluster: str) -> None:
        self._partitioned.discard(cluster)

    def kill_channel(self, channel_id: int) -> None:
        self.channels[channel_id].alive = False

    def revive_channel(self, channel_id: int) -> None:
        self.channels[channel_id].alive = True

    # ------------------------------------------------------------------------ time
    def tick(self, dt: float = 1.0) -> None:
        self.clock += dt
        due = [t for t in self._timers if t[0] <= self.clock]
        self._timers = [t for t in self._timers if t[0] > self.clock]
        for _, cb in sorted(due, key=lambda t: t[0]):
            cb()

    def call_later(self, delay: float, cb: Callable[[], None]) -> None:
        self._timers.append((self.clock + delay, cb))

    # -------------------------------------------------------------------- delivery
    def send(self, src_cluster: str, src_id: str, cluster: str, addr: Address,
             payload: Any, _hops: int = 0) -> Any:
        """Send from a component (pod/agent) to an in-cluster (ip, port).

        Cross-cluster reachability exists ONLY through channels installed on the
        path via forwarding rules. Returns the handler's response.
        """
        if _hops > 16:
            raise DeliveryError(f"routing loop at {cluster}:{addr}")
        if src_cluster in self._partitioned or cluster in self._partitioned:
            raise DeliveryError(f"cluster partitioned: {src_cluster}->{cluster}")
        if src_cluster != cluster:
            raise DeliveryError(
                f"no direct cross-cluster route {src_cluster}->{cluster}; "
                "flows must traverse gateway channels (Algorithm 4)")

        acl = self._acl.get(cluster)
        if acl is not None and _hops == 0 and not acl.allowed(src_id, addr):
            raise DeliveryError(
                f"ACL deny: {src_id} -> {cluster}:{addr} (Algorithm 3)")

        nbytes = _payload_bytes(payload)
        self.local_bytes[cluster] += nbytes
        self.message_log.append((self.clock, src_cluster, src_id, cluster, addr))

        # channel endpoint? hop across the boundary
        ch = self._channels.get((cluster, addr))
        if ch is not None:
            if not ch.alive:
                raise DeliveryError(f"channel {ch.channel_id} down")
            other = ch.other_end(cluster, addr)
            assert other is not None
            o_cluster, o_addr = other
            if o_cluster in self._partitioned:
                raise DeliveryError(f"cluster partitioned: {o_cluster}")
            if (cluster, addr) == (ch.cluster_a, ch.addr_a):
                ch.bytes_ab += nbytes
            else:
                ch.bytes_ba += nbytes
            self.cross_bytes[(cluster, o_cluster)] += nbytes
            return self._deliver_local(o_cluster, o_addr, src_id, payload,
                                       _hops + 1)

        return self._deliver_local(cluster, addr, src_id, payload, _hops)

    def _deliver_local(self, cluster: str, addr: Address, src_id: str,
                       payload: Any, hops: int) -> Any:
        # follow in-cluster forwarding rules (gateway port maps)
        seen = set()
        while (cluster, addr) in self._forwards:
            if (cluster, addr) in seen:
                raise DeliveryError(f"forward loop in {cluster} at {addr}")
            seen.add((cluster, addr))
            addr = self._forwards[(cluster, addr)]
            ch = self._channels.get((cluster, addr))
            if ch is not None:
                return self.send(cluster, f"gw@{cluster}", cluster, addr,
                                 payload, _hops=hops + 1)
        handler = self._handlers.get((cluster, addr))
        if handler is None:
            raise DeliveryError(f"no endpoint at {cluster}:{addr}")
        return handler(payload)

    # ------------------------------------------------------------------ accounting
    def cross_cluster_bytes(self) -> int:
        return sum(self.cross_bytes.values())

    def locality_ratio(self) -> float:
        """Fraction of all bytes that stayed inside a cluster (paper's claim: ~1)."""
        local = sum(self.local_bytes.values())
        cross = self.cross_cluster_bytes()
        return local / max(local + cross, 1)


class AclTable:
    """Default-deny pod->(ip, port) table (Algorithm 3)."""

    def __init__(self):
        self._allowed: set = set()
        self._exempt_prefixes = ("gw@", "agent@", "system@")

    def allow(self, src_id: str, addr: Address) -> None:
        self._allowed.add((src_id, addr))

    def block_all(self, addr: Address) -> None:
        self._allowed = {(s, a) for (s, a) in self._allowed if a != addr}

    def allowed(self, src_id: str, addr: Address) -> bool:
        if any(src_id.startswith(p) for p in self._exempt_prefixes):
            return True                     # infra components, not app pods
        return (src_id, addr) in self._allowed

    def entries(self) -> set:
        return set(self._allowed)
