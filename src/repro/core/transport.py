"""Simulated inter-cluster fabric (the paper's network substrate, §4.1).

Models exactly the connectivity regime the paper assumes:

  * Within a cluster: any endpoint can reach any (ip, port) — fast local network
    (the ICI/intra-cluster path).
  * Across clusters: NO direct reachability. The only cross-cluster transport is a
    ``Channel`` (the SSH/port-forwarding tunnel of Algorithm 4), pinned to gateway
    endpoints. Traffic that is not routed through a configured gateway chain simply
    does not arrive — mirroring real firewalled private clouds.
  * Access control: default-deny pod->service tables (Algorithm 3) enforced at
    send time.

Delivery is synchronous and deterministic; a simulated clock (``tick``) drives
lease expiry and heartbeat scheduling in the layers above. Per-edge byte counters
make the paper's "thin cross-boundary traffic" claim measurable
(``cross_cluster_bytes`` vs ``local_bytes``), and fault injection (partition a
cluster, kill a channel) drives the fault-tolerance tests.

Byte accounting covers the full round trip wherever it matters: the request
payload is charged on every hop it traverses, and on any path that crosses a
gateway channel the handler's RESPONSE is charged back along the same path
(sized exactly once at the terminal handler and propagated up the hop stack —
never re-walked per hop). A fat range response crossing a channel is
cross-boundary traffic exactly like a fat request, which is what makes
"serve remote reads from a local replica" a measurable byte win rather than a
free-response illusion. Purely intra-cluster round trips skip the response
walk entirely — the cross-boundary ledger is the paper's claim, and sizing
every local data-plane response would tax the hottest path for a number
nothing gates.

The send fast path is deliberately lean (this is the hottest function in the
repo): ACL exemption checks are memoized per source id instead of re-scanning
the exempt prefixes per message, the per-string/per-envelope byte caches evict
one entry at a time instead of wholesale (no re-encode storms at the limit),
``message_log_limit=0`` skips message-tuple construction entirely, and the
dominant no-forwarding-rule delivery case skips the loop-detection machinery.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import Counter, deque
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

Address = Tuple[str, int]            # (ip, port)


class DeliveryError(Exception):
    """Raised when the fabric cannot deliver a message (no route / denied / down)."""


class StaleEpochError(RuntimeError):
    """A request was fenced by the shard map: it carried an epoch older than
    the current map (or hit a frozen, mid-migration shard) and the bounded
    refresh+retry in the client could not land it. Subclassing RuntimeError
    keeps every existing best-effort caller (agent heartbeats, depth
    publication) on its normal retry-next-tick path."""


class RingLog:
    """Bounded append-only log (list-compatible for the common read patterns).

    ``limit=None`` keeps everything (test/debug); a finite limit turns it into
    a ring buffer so long-running planes do not grow without bound.
    ``total_appended`` keeps counting even after old entries are evicted.
    """

    def __init__(self, limit: Optional[int] = None):
        self.limit = limit
        self._buf: deque = deque(maxlen=limit)
        self.total_appended = 0

    def append(self, item: Any) -> None:
        self._buf.append(item)
        self.total_appended += 1

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator:
        return iter(self._buf)

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            return list(self._buf)[idx]
        return self._buf[idx]

    def __bool__(self) -> bool:
        return bool(self._buf)

    def clear(self) -> None:
        self._buf.clear()


@dataclasses.dataclass
class Channel:
    """A cross-cluster tunnel between two gateway endpoints (Algorithm 4)."""
    channel_id: int
    cluster_a: str
    addr_a: Address
    cluster_b: str
    addr_b: Address
    alive: bool = True
    bytes_ab: int = 0
    bytes_ba: int = 0

    def other_end(self, cluster: str, addr: Address):
        if (cluster, addr) == (self.cluster_a, self.addr_a):
            return self.cluster_b, self.addr_b
        if (cluster, addr) == (self.cluster_b, self.addr_b):
            return self.cluster_a, self.addr_a
        return None


class Envelope(dict):
    """A dict payload with a cached byte size (zero-copy accounting).

    Hot envelope types — overwatch ops, telemetry heartbeats, job dispatches —
    are built once and then traverse several fabric hops (gateway forwards,
    channel crossings), each of which used to re-walk every nested value dict
    in ``_payload_bytes``. An ``Envelope`` is sized exactly once: at
    construction when the sender already knows the size (``nbytes=``), or
    lazily on the first ``send`` — subsequent hops read the cached number.
    The computed size is identical to the plain-dict walk, so byte ledgers are
    unchanged; only the walking stops.
    """

    __slots__ = ("_nbytes",)

    def __init__(self, *args, nbytes: Optional[int] = None, **kwargs):
        super().__init__(*args, **kwargs)
        self._nbytes = nbytes

    @property
    def nbytes(self) -> int:
        if self._nbytes is None:
            self._nbytes = _dict_bytes(self)
        return self._nbytes


# Control-plane traffic is dominated by a small vocabulary of repeated strings
# (op names, key prefixes, field names) and fixed dict envelopes, so byte
# accounting memoizes per-string encoded sizes and per-envelope key overhead.
_STR_BYTES_CACHE: Dict[str, int] = {}
_DICT_KEYS_CACHE: Dict[Tuple[str, ...], int] = {}
_CACHE_LIMIT = 65536


def _evict_one(cache: dict) -> None:
    """Drop the oldest entry (dict insertion order — FIFO, not LRU: tracking
    recency would cost a dict move on every HIT of the hottest path to avoid
    an occasional ~100ns re-encode; an evicted hot entry simply re-inserts on
    its next use). Wholesale ``clear()`` at the limit used to force the
    entire hot vocabulary to re-encode in one thrash storm; one-at-a-time
    eviction keeps the steady-state hit rate."""
    cache.pop(next(iter(cache)))


def _str_bytes(s: str) -> int:
    n = _STR_BYTES_CACHE.get(s)
    if n is None:
        n = len(s.encode())
        if len(_STR_BYTES_CACHE) >= _CACHE_LIMIT:
            _evict_one(_STR_BYTES_CACHE)
        _STR_BYTES_CACHE[s] = n
    return n


def _dict_bytes(payload: dict) -> int:
    try:
        sig = tuple(payload.keys())
        key_bytes = _DICT_KEYS_CACHE.get(sig)
        if key_bytes is None:
            key_bytes = sum(_payload_bytes(k) for k in sig)
            if len(_DICT_KEYS_CACHE) >= _CACHE_LIMIT:
                _evict_one(_DICT_KEYS_CACHE)
            _DICT_KEYS_CACHE[sig] = key_bytes
    except TypeError:                 # unhashable keys: no memoization
        key_bytes = sum(_payload_bytes(k) for k in payload)
    return key_bytes + sum(_payload_bytes(v) for v in payload.values())


def _payload_bytes(payload: Any) -> int:
    if isinstance(payload, Envelope):
        return payload.nbytes          # precomputed / cached — no value walk
    if isinstance(payload, (bytes, bytearray)):
        return len(payload)
    if isinstance(payload, str):
        return _str_bytes(payload)
    if isinstance(payload, dict):
        return _dict_bytes(payload)
    if isinstance(payload, (list, tuple)):
        return sum(_payload_bytes(v) for v in payload)
    if isinstance(payload, (int, float, bool)) or payload is None:
        return 8
    return 64  # opaque object envelope


class Fabric:
    """The hybrid-cloud network: clusters, gateways, channels, ACLs, a clock."""

    def __init__(self, message_log_limit: Optional[int] = 100_000):
        self.clock: float = 0.0
        self._handlers: Dict[Tuple[str, Address], Callable] = {}
        self._forwards: Dict[Tuple[str, Address], Address] = {}
        self._channels: Dict[Tuple[str, Address], Channel] = {}
        self._channel_ids = itertools.count(1)
        self.channels: Dict[int, Channel] = {}
        self._partitioned: set = set()           # clusters cut off from everything
        self._acl: Dict[str, "AclTable"] = {}
        self.local_bytes: Counter = Counter()    # per-cluster intra bytes
        self.cross_bytes: Counter = Counter()    # per (src, dst) cluster pair
        # named operational counters the byte ledgers can't express — e.g.
        # ``fallback_reads``: bounded-staleness reads that had to abandon an
        # out-of-bound local replica for a primary round trip
        self.stats: Counter = Counter()
        self.message_log: RingLog = RingLog(message_log_limit)
        self._timers: List[Tuple[float, int, Callable]] = []   # real min-heap
        self._timer_seq = itertools.count()      # FIFO tie-break at one deadline
        # fault-injection seam: invoked as (cluster, addr, payload) right
        # before every handler call. First-class on the fabric (not a handler
        # wrapper) so it keeps observing through service rebuilds and counts
        # recovery traffic too (repro.core.faults arms it).
        self.on_deliver: Optional[Callable[[str, Address, Any], None]] = None
        # trace-context stack: while a handler runs, the "trace" field of the
        # payload being delivered (repro.observability.trace.TRACE_KEY) is on
        # top, so a handler many hops from the sender — gateway relays
        # included — can parent its spans via current_trace() without every
        # intermediate service threading the context through its own API.
        self._trace_ctx: List[Any] = []

    # ------------------------------------------------------------------- topology
    def register_handler(self, cluster: str, addr: Address,
                         handler: Callable[[Any], Any]) -> None:
        self._handlers[(cluster, addr)] = handler

    def add_forward(self, cluster: str, src: Address, dst: Address) -> None:
        """Istio-style in-cluster forwarding rule src -> dst (Algorithm 2)."""
        self._forwards[(cluster, src)] = dst

    def remove_forward(self, cluster: str, src: Address) -> None:
        self._forwards.pop((cluster, src), None)

    def create_channel(self, cluster_a: str, addr_a: Address, cluster_b: str,
                       addr_b: Address) -> Channel:
        ch = Channel(next(self._channel_ids), cluster_a, addr_a, cluster_b,
                     addr_b)
        self._channels[(cluster_a, addr_a)] = ch
        self._channels[(cluster_b, addr_b)] = ch
        self.channels[ch.channel_id] = ch
        return ch

    def channel_at(self, cluster: str, addr: Address) -> Optional[Channel]:
        """The channel terminating at (cluster, addr), if any — lets a
        re-run of Algorithm 4 (AppSpec re-broadcast for an elastic fleet)
        skip tunnels that already exist instead of stacking duplicates."""
        return self._channels.get((cluster, addr))

    def set_acl(self, cluster: str, table: "AclTable") -> None:
        self._acl[cluster] = table

    # ------------------------------------------------------------- fault injection
    def partition_cluster(self, cluster: str) -> None:
        self._partitioned.add(cluster)

    def heal_cluster(self, cluster: str) -> None:
        self._partitioned.discard(cluster)

    def kill_channel(self, channel_id: int) -> None:
        self.channels[channel_id].alive = False

    def revive_channel(self, channel_id: int) -> None:
        self.channels[channel_id].alive = True

    # ------------------------------------------------------------------------ time
    def tick(self, dt: float = 1.0) -> None:
        self.clock += dt
        # snapshot the due set BEFORE running callbacks: timers scheduled while
        # firing (heartbeat re-arm) wait for the next tick, as they always did
        due = []
        while self._timers and self._timers[0][0] <= self.clock:
            due.append(heapq.heappop(self._timers))
        for _, _, cb in due:
            cb()

    def call_later(self, delay: float, cb: Callable[[], None]) -> None:
        heapq.heappush(self._timers,
                       (self.clock + delay, next(self._timer_seq), cb))

    # -------------------------------------------------------------------- delivery
    def current_trace(self) -> Optional[str]:
        """The trace context of the message currently being delivered (the
        ``"trace_id|span_id"`` string riding its payload), or ``None``.
        Valid only inside a handler call; nested deliveries stack."""
        return self._trace_ctx[-1] if self._trace_ctx else None

    def send(self, src_cluster: str, src_id: str, cluster: str, addr: Address,
             payload: Any, _hops: int = 0) -> Any:
        """Send from a component (pod/agent) to an in-cluster (ip, port).

        Cross-cluster reachability exists ONLY through channels installed on the
        path via forwarding rules. Returns the handler's response. The request
        is byte-accounted on every hop; the response is accounted too on any
        path that crossed a channel.
        """
        return self._send(src_cluster, src_id, cluster, addr, payload,
                          _hops, False)[0]

    def _send(self, src_cluster: str, src_id: str, cluster: str,
              addr: Address, payload: Any, _hops: int,
              need_rbytes: bool) -> Tuple[Any, int]:
        """Internal send returning ``(response, response_bytes)`` so that the
        response is sized exactly once (at the terminal handler) and every
        hop on the way back charges the propagated number. ``need_rbytes``
        tells the terminal whether anything upstream will charge the
        response — entering a channel forces it, a purely-local path skips
        the walk and returns 0."""
        if _hops > 16:
            raise DeliveryError(f"routing loop at {cluster}:{addr}")
        if src_cluster in self._partitioned or cluster in self._partitioned:
            raise DeliveryError(f"cluster partitioned: {src_cluster}->{cluster}")
        if src_cluster != cluster:
            raise DeliveryError(
                f"no direct cross-cluster route {src_cluster}->{cluster}; "
                "flows must traverse gateway channels (Algorithm 4)")

        acl = self._acl.get(cluster)
        if acl is not None and _hops == 0 and not acl.allowed(src_id, addr):
            raise DeliveryError(
                f"ACL deny: {src_id} -> {cluster}:{addr} (Algorithm 3)")

        nbytes = _payload_bytes(payload)
        self.local_bytes[cluster] += nbytes
        if self.message_log.limit != 0:   # limit 0: skip tuple construction
            self.message_log.append(
                (self.clock, src_cluster, src_id, cluster, addr))

        # channel endpoint? hop across the boundary
        ch = self._channels.get((cluster, addr))
        if ch is not None:
            if not ch.alive:
                raise DeliveryError(f"channel {ch.channel_id} down")
            other = ch.other_end(cluster, addr)
            assert other is not None
            o_cluster, o_addr = other
            if o_cluster in self._partitioned:
                raise DeliveryError(f"cluster partitioned: {o_cluster}")
            a_to_b = (cluster, addr) == (ch.cluster_a, ch.addr_a)
            if a_to_b:
                ch.bytes_ab += nbytes
            else:
                ch.bytes_ba += nbytes
            self.cross_bytes[(cluster, o_cluster)] += nbytes
            resp, rbytes = self._deliver_local(o_cluster, o_addr, src_id,
                                               payload, _hops + 1, True)
            # the response re-crosses the channel in the other direction
            if a_to_b:
                ch.bytes_ba += rbytes
            else:
                ch.bytes_ab += rbytes
            self.cross_bytes[(o_cluster, cluster)] += rbytes
            self.local_bytes[cluster] += rbytes
            return resp, rbytes

        return self._deliver_local(cluster, addr, src_id, payload, _hops,
                                   need_rbytes)

    def _deliver_local(self, cluster: str, addr: Address, src_id: str,
                       payload: Any, hops: int,
                       need_rbytes: bool) -> Tuple[Any, int]:
        # hot path: no forwarding rule at the dialed address — straight to the
        # handler, no loop-detection set, no rule walk
        fwd = self._forwards.get((cluster, addr))
        if fwd is None:
            handler = self._handlers.get((cluster, addr))
            if handler is None:
                raise DeliveryError(f"no endpoint at {cluster}:{addr}")
            if self.on_deliver is not None:
                self.on_deliver(cluster, addr, payload)
            ctx = payload.get("trace") if isinstance(payload, dict) else None
            if ctx is None:              # untraced message: zero extra work
                resp = handler(payload)
            else:
                self._trace_ctx.append(ctx)
                try:                     # finally: CrashError must still pop
                    resp = handler(payload)
                finally:
                    self._trace_ctx.pop()
            if not need_rbytes:          # purely-local round trip: no walk
                return resp, 0
            rbytes = _payload_bytes(resp)
            self.local_bytes[cluster] += rbytes
            return resp, rbytes
        # follow in-cluster forwarding rules (gateway port maps)
        seen = {(cluster, addr)}
        addr = fwd
        while True:
            ch = self._channels.get((cluster, addr))
            if ch is not None:
                return self._send(cluster, f"gw@{cluster}", cluster, addr,
                                  payload, hops + 1, need_rbytes)
            fwd = self._forwards.get((cluster, addr))
            if fwd is None:
                break
            if (cluster, addr) in seen:
                raise DeliveryError(f"forward loop in {cluster} at {addr}")
            seen.add((cluster, addr))
            addr = fwd
        handler = self._handlers.get((cluster, addr))
        if handler is None:
            raise DeliveryError(f"no endpoint at {cluster}:{addr}")
        if self.on_deliver is not None:
            self.on_deliver(cluster, addr, payload)
        ctx = payload.get("trace") if isinstance(payload, dict) else None
        if ctx is None:
            resp = handler(payload)
        else:
            self._trace_ctx.append(ctx)
            try:
                resp = handler(payload)
            finally:
                self._trace_ctx.pop()
        if not need_rbytes:
            return resp, 0
        rbytes = _payload_bytes(resp)
        self.local_bytes[cluster] += rbytes
        return resp, rbytes

    # ------------------------------------------------------------------ accounting
    def cross_cluster_bytes(self) -> int:
        return sum(self.cross_bytes.values())

    def locality_ratio(self) -> float:
        """Fraction of all bytes that stayed inside a cluster (paper's claim: ~1)."""
        local = sum(self.local_bytes.values())
        cross = self.cross_cluster_bytes()
        return local / max(local + cross, 1)


class AclTable:
    """Default-deny pod->(ip, port) table (Algorithm 3).

    The exempt-prefix test (infra components: gateways, agents, system pods)
    used to run ``any(startswith)`` on every ``Fabric.send`` — the single
    hottest string scan in the plane. It is now resolved once per source id:
    precomputed at ``allow`` time for ids the table learns about, memoized on
    first sight for everything else. ``stats['prefix_scans']`` counts actual
    prefix walks so tests can pin the scan-once property; exemption is a pure
    function of the id (the prefix tuple is fixed at construction), so the
    cache never needs invalidation — ``block_all`` only touches the allow set.
    """

    def __init__(self):
        self._allowed: set = set()
        self._exempt_prefixes = ("gw@", "agent@", "system@")
        self._exempt_cache: Dict[str, bool] = {}
        self.stats: Counter = Counter()

    def _is_exempt(self, src_id: str) -> bool:
        e = self._exempt_cache.get(src_id)
        if e is None:
            self.stats["prefix_scans"] += 1
            e = any(src_id.startswith(p) for p in self._exempt_prefixes)
            if len(self._exempt_cache) >= _CACHE_LIMIT:
                _evict_one(self._exempt_cache)
            self._exempt_cache[src_id] = e
        return e

    def allow(self, src_id: str, addr: Address) -> None:
        self._allowed.add((src_id, addr))
        self._is_exempt(src_id)             # precompute at allow time

    def block_all(self, addr: Address) -> None:
        self._allowed = {(s, a) for (s, a) in self._allowed if a != addr}

    def allowed(self, src_id: str, addr: Address) -> bool:
        if self._is_exempt(src_id):
            return True                     # infra components, not app pods
        return (src_id, addr) in self._allowed

    def entries(self) -> set:
        return set(self._allowed)
