"""Deterministic, shard-aware, checkpointable synthetic token pipeline.

Every batch is a pure function of (seed, step, shard) — restart/elastic-rescale
resume is exact: the only pipeline state is the integer step, which rides in the
training checkpoint. Shards map to (pod, data) coordinates so each host draws only
its slice (data never crosses the pod boundary — the paper's locality discipline
applied to the input path).

Tasks:
  * "ramp"   — tok[i+1] = tok[i] + 1 (mod V'): learnable next-token structure, so
               the end-to-end 100M example shows a real loss curve.
  * "random" — iid uniform tokens (throughput benchmarking).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    task: str = "ramp"
    num_shards: int = 1
    shard_id: int = 0
    step: int = 0                      # the ONLY mutable state (checkpointable)

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0
        self.shard_batch = self.global_batch // self.num_shards

    # ------------------------------------------------------------------ stateless core
    def batch_at(self, step: int, shard_id: Optional[int] = None,
                 batch: Optional[int] = None) -> Dict[str, jax.Array]:
        shard = self.shard_id if shard_id is None else shard_id
        B = self.shard_batch if batch is None else batch
        S = self.seq_len
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), shard)
        if self.task == "ramp":
            v_eff = min(self.vocab_size, 1024)
            offset = jax.random.randint(key, (B, 1), 0, v_eff)
            toks = (offset + jnp.arange(S + 1)[None, :]) % v_eff
        else:
            toks = jax.random.randint(key, (B, S + 1), 0, self.vocab_size)
        toks = toks.astype(jnp.int32)
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "loss_mask": jnp.ones((B, S), jnp.bfloat16),
        }

    # --------------------------------------------------------------------- iteration
    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def global_batch_at(self, step: int) -> Dict[str, jax.Array]:
        """The full global batch (all shards concatenated) — single-process runs."""
        return self.batch_at(step, shard_id=0, batch=self.global_batch)

    # ------------------------------------------------------------------- checkpointing
    def state_dict(self) -> dict:
        return {"step": int(self.step), "seed": int(self.seed),
                "task": self.task}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.seed and state["task"] == self.task, \
            "data pipeline config mismatch on restore"
        self.step = int(state["step"])
