"""Server: slot-based continuous batching over the decode cache.

Requests (prompt token arrays) queue up; each free slot prefills one request
(B=1) and splices its cache into the batched decode cache at the slot's batch
index; every tick runs ONE batched decode step for all active slots (inactive
slots compute masked garbage — the standard continuous-batching trade). Slots
free as requests hit EOS/max_new, so long and short generations coexist without
head-of-line blocking.

The batch axis of every cache leaf is located *generically* by diffing
``cache_defs(batch=1)`` against ``cache_defs(batch=2)`` — the same Server drives
dense KV caches, MoE, ring-buffer windows, SSM states and hybrid caches without
family-specific code.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs import base as configs
from repro.launch.mesh import make_test_mesh
from repro.models.model import Model
from repro.parallel.sharding import MeshPlan

tmap = jax.tree_util.tree_map


@dataclasses.dataclass
class Request:
    req_id: str
    prompt: List[int]
    max_new: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeJobConfig:
    arch: str = "qwen3-0.6b"
    reduced: bool = True
    slots: int = 4
    max_len: int = 256
    eos_id: Optional[int] = None
    greedy: bool = True
    seed: int = 0

    @classmethod
    def from_job(cls, job: dict) -> "ServeJobConfig":
        payload = dict(job.get("payload", {}))
        payload.setdefault("arch", job.get("arch") or "qwen3-0.6b")
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in payload.items() if k in known})


class Server:
    def __init__(self, cfg: ServeJobConfig, params: Optional[dict] = None,
                 mesh=None):
        self.cfg = cfg
        arch_cfg = configs.get(cfg.arch)
        if cfg.reduced:
            arch_cfg = arch_cfg.reduced()
        arch_cfg = dataclasses.replace(arch_cfg, remat="none")
        self.arch_cfg = arch_cfg
        mesh = mesh or make_test_mesh()
        self.model = Model(arch_cfg, MeshPlan(mesh=mesh, fsdp=False))
        self.params = params if params is not None else \
            self.model.init_params(jax.random.PRNGKey(cfg.seed))

        B, L = cfg.slots, cfg.max_len
        self.cache = self.model.init_cache(B, L)
        self._batch_axis = self._locate_batch_axes(L)
        self.slots: List[Optional[Request]] = [None] * B
        self.queue: Deque[Request] = deque()
        self._ids = itertools.count(1)
        self._decode = jax.jit(self.model.decode_step)
        self._prefill_cache: Dict[int, object] = {}
        self._rng = jax.random.PRNGKey(cfg.seed + 17)
        self.steps = 0
        self._init_params = self.params
        self._init_seed = cfg.seed

    def rebind(self, cfg: ServeJobConfig) -> None:
        """Re-arm a warm server for a new task of the SAME compiled family
        (the step-cache hit path): fresh request/slot/cache state, same model
        and jitted decode/prefill functions. The caller guarantees the cache
        key (arch, reduced, slots, max_len) matches; eos/greedy/seed are
        host-side and may differ."""
        if cfg.seed == self._init_seed:
            self.params = self._init_params
        else:
            self.params = self.model.init_params(jax.random.PRNGKey(cfg.seed))
            self._init_params = self.params
            self._init_seed = cfg.seed
        self.cfg = cfg
        self.cache = self.model.init_cache(cfg.slots, cfg.max_len)
        self.slots = [None] * cfg.slots
        self.queue = deque()
        self.requests: Dict[str, Request] = {}
        self._ids = itertools.count(1)
        self._rng = jax.random.PRNGKey(cfg.seed + 17)
        self.steps = 0

    # ------------------------------------------------------------- batch-axis magic
    def _locate_batch_axes(self, L: int):
        d1 = self.model.cache_defs(1, L)
        d2 = self.model.cache_defs(2, L)

        def axis(a, b):
            diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                     if x != y]
            assert len(diffs) == 1, (a.shape, b.shape)
            return diffs[0]

        is_def = lambda x: hasattr(x, "logical")
        return tmap(axis, d1, d2, is_leaf=is_def)

    def _splice(self, slot: int, one_cache: dict) -> None:
        def put(full, one, ax):
            return jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=ax)
        self.cache = tmap(put, self.cache, one_cache, self._batch_axis)

    # ----------------------------------------------------------------- request path
    def submit(self, prompt: List[int], max_new: int = 16) -> str:
        rid = f"req-{next(self._ids):04d}"
        req = Request(rid, list(prompt), max_new)
        self.queue.append(req)
        if not hasattr(self, "requests"):
            self.requests: Dict[str, Request] = {}
        self.requests[rid] = req
        return rid

    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            fn = lambda params, batch: self.model.prefill(
                params, batch, max_len=self.cfg.max_len)
            self._prefill_cache[length] = jax.jit(fn)
        return self._prefill_cache[length]

    def _aux_inputs(self, B: int) -> dict:
        c, out = self.arch_cfg, {}
        if c.family == "encdec":
            out["frames"] = jnp.zeros((B, c.encoder_frames, c.d_model),
                                      jnp.bfloat16)
        if c.family == "vlm":
            out["patches"] = jnp.zeros((B, c.num_patches, c.d_model),
                                       jnp.bfloat16)
        return out

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.cfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, key = jax.random.split(self._rng)
        return jax.random.categorical(key, logits).astype(jnp.int32)

    def _admit(self) -> None:
        for slot in range(self.cfg.slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            toks = jnp.asarray([req.prompt], jnp.int32)
            batch = {"tokens": toks, **self._aux_inputs(1)}
            logits, one_cache = self._prefill_fn(len(req.prompt))(
                self.params, batch)
            self._splice(slot, one_cache)
            first = int(self._sample(logits)[0])
            req.generated.append(first)
            self.slots[slot] = req
            self._maybe_finish(slot)

    def _maybe_finish(self, slot: int) -> None:
        req = self.slots[slot]
        if req is None:
            return
        hit_eos = (self.cfg.eos_id is not None and req.generated
                   and req.generated[-1] == self.cfg.eos_id)
        total = len(req.prompt) + len(req.generated)
        if hit_eos or len(req.generated) >= req.max_new \
                or total >= self.cfg.max_len - 1:
            req.done = True
            self.slots[slot] = None

    # -------------------------------------------------------------------- main loop
    def step(self) -> int:
        """Admit + one batched decode step. Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        last = [r.generated[-1] if r else 0 for r in self.slots]
        tokens = jnp.asarray(last, jnp.int32)[:, None]
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        nxt = self._sample(logits)
        for i in active:
            self.slots[i].generated.append(int(nxt[i]))
            self._maybe_finish(i)
        self.steps += 1
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return [r for r in getattr(self, "requests", {}).values() if r.done]

    def pending(self) -> int:
        return len(self.queue) + sum(r is not None for r in self.slots)
