"""Step-level telemetry: rates, EMAs, and the straggler-detector feed.

The control agent heartbeats these numbers to the overwatch (`/telemetry/...`,
`/jobs/.../status.rate`); the dispatcher's straggler check compares job rates
against the fleet median — so everything here must be cheap and monotone.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class StepTimer:
    """EMA of step wall time + derived tokens/s. Pure-python, checkpoint-free."""
    tokens_per_step: int = 0
    alpha: float = 0.1
    ema_s: Optional[float] = None
    last_t: Optional[float] = None
    steps: int = 0

    def tick(self, now: Optional[float] = None) -> Optional[float]:
        now = time.monotonic() if now is None else now
        dt = None
        if self.last_t is not None:
            dt = now - self.last_t
            self.ema_s = dt if self.ema_s is None else (
                (1 - self.alpha) * self.ema_s + self.alpha * dt)
        self.last_t = now
        self.steps += 1
        return dt

    @property
    def steps_per_s(self) -> float:
        return 1.0 / self.ema_s if self.ema_s else 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_per_step * self.steps_per_s

    def snapshot(self) -> dict:
        return {"steps": self.steps, "ema_step_s": self.ema_s,
                "steps_per_s": self.steps_per_s,
                "tokens_per_s": self.tokens_per_s}


@dataclasses.dataclass
class MetricsLog:
    """Bounded in-memory metrics ring (examples/tests read loss curves off it).

    The ring is a ``deque(maxlen=capacity)``: append past capacity evicts the
    oldest row in O(1) instead of the old list's O(n) front-slice on every
    overflowing append."""
    capacity: int = 4096
    rows: Deque = None

    def __post_init__(self):
        # maxlen depends on the capacity field, so it can't be a field default
        self.rows = deque(self.rows or (), maxlen=self.capacity)

    def log(self, step: int, metrics: Dict[str, float]) -> None:
        row = {"step": step}
        for k, v in metrics.items():
            try:
                row[k] = float(v)
            except (TypeError, ValueError):
                pass
        self.rows.append(row)

    def latest(self) -> Optional[dict]:
        return self.rows[-1] if self.rows else None

    def series(self, key: str) -> List[float]:
        return [r[key] for r in self.rows if key in r]
