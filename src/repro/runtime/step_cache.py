"""Keyed LRU caches of compiled workloads — the "warm worker" optimization.

Rebuilding a ``Trainer`` per pipeline task pays model construction plus jit
compilation of the train step (seconds) before the first real step runs
(milliseconds); a 12-stage same-family DAG re-pays it 12 times. A
:class:`TrainerCache` keys warm trainers by their *compiled family* — (arch,
reduced, mode, seq_len, global_batch, n_pods, microbatches, data_task, opt,
local_sgd) — everything the jitted step function's shapes and constants
depend on. A hit calls ``Trainer.rebind`` (reset step/state/data, keep the
model + compiled step); per-run knobs (steps, seed, checkpoint_dir/every)
are deliberately OUT of the key. :class:`ServerCache` is the serve-side
twin, keyed by (arch, reduced, slots, max_len).

``capacity=0`` disables caching (a fresh build per task — the cold baseline
``benchmarks/workloads.py`` measures against); eviction is LRU.

The ``run_*_task`` functions hold the actual task semantics shared by the
worker's cached handlers and the module-level cold fallbacks:

  * train — resume from the task's own ``checkpoint_dir`` (latest committed
    step; integrity-validated) and run only the REMAINING steps to the
    payload's target, so a task redelivered after a worker retire/crash
    continues instead of restarting: exactly-once step accounting rides the
    checkpoint, whatever the delivery count. Final checkpoint save blocks
    (the manifest it returns must be durable); the periodic in-loop saves
    overlap the next steps asynchronously.
  * eval — STRICT restore through ``CheckpointManager.restore``'s staleness/
    leaf checks: a missing or half-written checkpoint fails the task (and
    rides the retry machinery) instead of silently scoring fresh params.
  * serve — synthetic prompts through the continuous-batching server.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple


def _freeze(v):
    if dataclasses.is_dataclass(v):
        return tuple(sorted(dataclasses.asdict(v).items()))
    return v


class _LRU:
    """Shared LRU mechanics; subclasses define key_of/build/rebind."""

    def __init__(self, capacity: int = 4):
        self.capacity = max(int(capacity), 0)
        self._lru: "OrderedDict[tuple, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._lru)

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._lru)}

    def get(self, cfg):
        key = self.key_of(cfg)
        hit = self._lru.get(key)
        if hit is not None:
            self.hits += 1
            self._lru.move_to_end(key)
            self.rebind(hit, cfg)
            return hit
        self.misses += 1
        obj = self.build(cfg)
        if self.capacity:
            self._lru[key] = obj
            while len(self._lru) > self.capacity:
                self._lru.popitem(last=False)
                self.evictions += 1
        return obj


class TrainerCache(_LRU):
    @staticmethod
    def key_of(cfg) -> Tuple:
        return ("train", cfg.arch, cfg.reduced, cfg.mode, cfg.seq_len,
                cfg.global_batch, cfg.n_pods, cfg.microbatches,
                cfg.data_task, _freeze(cfg.opt), _freeze(cfg.local_sgd))

    @staticmethod
    def build(cfg):
        from repro.runtime.train_loop import Trainer
        return Trainer(cfg)

    @staticmethod
    def rebind(trainer, cfg) -> None:
        trainer.rebind(cfg)


class ServerCache(_LRU):
    @staticmethod
    def key_of(cfg) -> Tuple:
        return ("serve", cfg.arch, cfg.reduced, cfg.slots, cfg.max_len)

    @staticmethod
    def build(cfg):
        from repro.runtime.serve_loop import Server
        return Server(cfg)

    @staticmethod
    def rebind(server, cfg) -> None:
        server.rebind(cfg)


# ------------------------------------------------------------- task semantics
def run_train_task(cache: Optional[TrainerCache], payload: dict) -> dict:
    from repro.runtime.train_loop import TrainJobConfig
    cfg = TrainJobConfig.from_job({"payload": dict(payload)})
    # `is None`, not truthiness: an EMPTY cache is falsy (len 0) but must
    # still be used, or the first task of every family would build cold
    # without populating it
    tr = (TrainerCache(0) if cache is None else cache).get(cfg)
    resumed = 0
    if cfg.checkpoint_dir and payload.get("resume", True):
        # latest committed step in our own directory (0 = fresh start);
        # integrity failures (torn write, stale manifest) raise -> retry
        resumed = tr.restore()
    ran = max(cfg.steps - tr.step, 0)
    m = tr.run(ran) if ran else {}
    out = {"steps": tr.step, "loss": m.get("loss", tr.loss()),
           "ran_steps": ran, "resumed_from": resumed,
           # StepTimer's EMA step wall time: the flight recorder folds it
           # into the task's execute span so a trace shows not just how long
           # a train task took but how fast its steps were going
           "step_ema_s": tr.timer.ema_s}
    if cfg.checkpoint_dir:
        out["checkpoint"] = tr.save_checkpoint()
    return out


def run_eval_task(cache: Optional[TrainerCache], payload: dict) -> dict:
    from repro.runtime.train_loop import TrainJobConfig
    cfg = TrainJobConfig.from_job({"payload": dict(payload)})
    tr = (TrainerCache(0) if cache is None else cache).get(cfg)
    out = {}
    if payload.get("restore_from"):
        # strict: a missing/uncommitted/half-written checkpoint FAILS the
        # task — never a silently-fresh-params eval_loss
        out["restored_step"] = tr.restore(payload["restore_from"],
                                          strict=True)
    batch = tr._sync_batch(10_000)
    loss, _ = tr.model.loss_fn(tr.params_for_eval()
                               if cfg.mode == "local_sgd"
                               else tr.state["params"], batch)
    out["eval_loss"] = float(loss)
    return out


def run_serve_task(cache: Optional[ServerCache], payload: dict) -> dict:
    from repro.runtime.serve_loop import ServeJobConfig
    cfg = ServeJobConfig.from_job({"payload": dict(payload)})
    srv = (ServerCache(0) if cache is None else cache).get(cfg)
    n = int(payload.get("n_requests", cfg.slots))
    max_new = int(payload.get("max_new", 8))
    prompt_len = max(int(payload.get("prompt_len", 4)), 1)
    vocab = srv.arch_cfg.vocab_size
    for i in range(n):
        srv.submit([(i + j) % vocab for j in range(prompt_len)],
                   max_new=max_new)
    done = srv.run()
    return {"requests": len(done),
            "generated_tokens": sum(len(r.generated) for r in done),
            "decode_steps": srv.steps}
