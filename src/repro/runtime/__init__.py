"""Runtime: JAX-executing local control planes, train/serve loops, elasticity."""
