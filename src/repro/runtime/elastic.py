"""Elastic scaling: re-mesh live training state when pods join or leave.

The paper's management plane treats cluster membership as dynamic (lease-backed
registration, failure detection). For the SPMD data plane that means the mesh
itself must be rebuildable mid-run: on membership change we

  1. rebuild the mesh over the surviving/new devices,
  2. re-derive every PartitionSpec from the SAME logical axes (MeshPlan is pure),
  3. ``jax.device_put`` the state onto the new shardings (XLA moves only the
     shards that must move),
  4. rescale the data pipeline's shard map — the pipeline is a pure function of
     (seed, step, shard), so no data is lost or duplicated.

Semantics preserved across a re-mesh: parameter values, optimizer moments, data
step. Changed: per-pod batch slicing (global batch is invariant).
``ElasticController`` watches the overwatch's ``/clusters/`` prefix and drives
the swap; tests/test_elastic.py asserts loss-curve continuity across a shrink.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax

from repro.parallel.sharding import MeshPlan

tmap = jax.tree_util.tree_map


def remesh_state(state, old_plan: MeshPlan, new_plan: MeshPlan, specs_fn):
    """Move a sharded pytree to a new mesh. ``specs_fn(plan) -> spec tree``."""
    new_specs = specs_fn(new_plan)
    return tmap(
        lambda x, s: jax.device_put(
            x, jax.sharding.NamedSharding(new_plan.mesh, s)),
        state, new_specs)


def divisors_mesh(n_devices: int) -> tuple:
    """Largest (data, model) grid for n devices (prefer square-ish, model<=data)."""
    best = (n_devices, 1)
    for m in range(1, int(n_devices ** 0.5) + 1):
        if n_devices % m == 0:
            best = (n_devices // m, m)
    return best


class ElasticController:
    """Watches cluster membership; triggers re-mesh callbacks on change.

    In the simulated fabric, "devices" are the registered clusters' capacities;
    on real hardware this maps to jax.devices() after a slice reconfiguration.
    """

    def __init__(self, overwatch, on_change: Callable[[List[str]], None]):
        self.ow = overwatch
        self.on_change = on_change
        self.members: Optional[List[str]] = None
        overwatch.watch("/clusters/", self._event)

    def _event(self, event: str, key: str, value, rev: int) -> None:
        members = sorted(self.ow.handle(
            {"op": "range", "prefix": "/clusters/"})["items"])
        members = [m.split("/")[-1] for m in members]
        if members != self.members:
            self.members = members
            self.on_change(members)
