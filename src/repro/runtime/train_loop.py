"""Trainer: the real (JAX-executing) training loop behind a local control plane.

Two synchronization modes, selected per job:
  * "sync"      — per-step synchronous data parallelism (the baseline the paper's
                  thin-boundary argument is measured against);
  * "local_sgd" — the Titchener mode: H pod-local AdamW steps per round, one
                  int8+error-feedback compressed delta exchange across the pod
                  boundary (repro.optim.local_sgd) — the paper's "occasional
                  cross-boundary traffic" regime.

Deterministic restart: checkpoint = (train state, data step, RNG seed); the data
pipeline is a pure function of step, so kill/restore resumes bit-exact (validated
in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import base as configs
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import init_train_state, make_train_step
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.optim.local_sgd import (LocalSGDConfig, init_local_sgd_state,
                                   make_round_fn, pod_free_plan)
from repro.parallel.sharding import MeshPlan
from repro.runtime.telemetry import MetricsLog, StepTimer

tmap = jax.tree_util.tree_map


@dataclasses.dataclass
class TrainJobConfig:
    arch: str = "qwen3-0.6b"
    steps: int = 50
    seq_len: int = 64
    global_batch: int = 8
    reduced: bool = True             # reduced() config for CPU execution
    mode: str = "sync"               # sync | local_sgd
    n_pods: int = 2                  # local_sgd: pods emulated via the vmap dim
    microbatches: int = 1
    seed: int = 0
    data_task: str = "ramp"
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 25
    opt: AdamWConfig = dataclasses.field(default_factory=lambda: AdamWConfig(
        peak_lr=1e-2, warmup_steps=20, total_steps=2000, weight_decay=0.0))
    local_sgd: LocalSGDConfig = dataclasses.field(default_factory=LocalSGDConfig)

    @classmethod
    def from_job(cls, job: dict) -> "TrainJobConfig":
        payload = dict(job.get("payload", {}))
        payload.setdefault("arch", job.get("arch") or "qwen3-0.6b")
        payload.setdefault("steps", job.get("steps", 50))
        known = {f.name for f in dataclasses.fields(cls)}
        for key in ("opt", "local_sgd"):
            if key in payload and isinstance(payload[key], dict):
                klass = AdamWConfig if key == "opt" else LocalSGDConfig
                payload[key] = klass(**payload[key])
        return cls(**{k: v for k, v in payload.items() if k in known})


class Trainer:
    def __init__(self, cfg: TrainJobConfig, mesh=None,
                 on_checkpoint: Optional[Callable[[int, str], None]] = None):
        self.cfg = cfg
        arch_cfg = configs.get(cfg.arch)
        if cfg.reduced:
            arch_cfg = arch_cfg.reduced()
        arch_cfg = dataclasses.replace(arch_cfg, remat="none")
        self.arch_cfg = arch_cfg
        mesh = mesh or make_test_mesh()
        self.plan = MeshPlan(mesh=mesh, fsdp=False)
        self.step = 0

        if cfg.mode == "local_sgd":
            # pods are a leading vmapped dim; the model must not shard on "pod"
            self.model = Model(arch_cfg, pod_free_plan(self.plan))
            params = self.model.init_params(jax.random.PRNGKey(cfg.seed))
            self.state = init_local_sgd_state(params, cfg.n_pods)
            spmd = "pod" if "pod" in mesh.shape else None
            self.round_fn = jax.jit(make_round_fn(
                self.model.loss_fn, cfg.opt, cfg.local_sgd, spmd_axis=spmd))
        else:
            self.model = Model(arch_cfg, self.plan)
            self.state = init_train_state(self.model,
                                          jax.random.PRNGKey(cfg.seed))
            self.step_fn = jax.jit(make_train_step(self.model, cfg.opt,
                                                   cfg.microbatches))

        # pristine copies for ``rebind``: JAX updates are functional, so the
        # initial tree can be handed back verbatim when a cached trainer is
        # re-armed for a new task of the same compiled family
        self._init_state = self.state
        self._init_seed = cfg.seed
        self.data = SyntheticTokens(
            vocab_size=arch_cfg.vocab_size, seq_len=cfg.seq_len,
            global_batch=cfg.global_batch, seed=cfg.seed, task=cfg.data_task)
        self.metrics = MetricsLog()
        self.timer = StepTimer(tokens_per_step=cfg.global_batch * cfg.seq_len)
        self.ckpt = (CheckpointManager(cfg.checkpoint_dir)
                     if cfg.checkpoint_dir else None)
        if self.ckpt and on_checkpoint:
            self.ckpt.on_commit(on_checkpoint)

    def rebind(self, cfg: TrainJobConfig,
               on_checkpoint: Optional[Callable[[int, str], None]] = None
               ) -> None:
        """Re-arm a warm trainer for a new task of the SAME compiled family
        (the step-cache hit path): reset step/state/data/metrics, point the
        checkpoint manager at the task's directory, and keep the model and
        jitted step function — the expensive part — untouched. The caller
        (``repro.runtime.step_cache``) guarantees the cache key (arch, shape,
        mode, ...) matches; only per-run knobs may differ here."""
        if self.ckpt:
            self.ckpt.wait()             # bound the previous task's async save
        if cfg.seed == self._init_seed:
            self.state = self._init_state
        else:
            if cfg.mode == "local_sgd":
                params = self.model.init_params(jax.random.PRNGKey(cfg.seed))
                self.state = init_local_sgd_state(params, cfg.n_pods)
            else:
                self.state = init_train_state(self.model,
                                              jax.random.PRNGKey(cfg.seed))
            self._init_state = self.state
            self._init_seed = cfg.seed
        self.cfg = cfg
        self.step = 0
        self.data = SyntheticTokens(
            vocab_size=self.arch_cfg.vocab_size, seq_len=cfg.seq_len,
            global_batch=cfg.global_batch, seed=cfg.seed, task=cfg.data_task)
        self.metrics = MetricsLog()
        self.timer = StepTimer(tokens_per_step=cfg.global_batch * cfg.seq_len)
        self.ckpt = (CheckpointManager(cfg.checkpoint_dir)
                     if cfg.checkpoint_dir else None)
        if self.ckpt and on_checkpoint:
            self.ckpt.on_commit(on_checkpoint)

    # ------------------------------------------------------------------ step logic
    def _sync_batch(self, step: int) -> Dict[str, jax.Array]:
        batch = self.data.global_batch_at(step)
        return self._with_aux_inputs(batch, self.cfg.global_batch)

    def _with_aux_inputs(self, batch: dict, B: int) -> dict:
        c = self.arch_cfg
        if c.family == "encdec":
            key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed + 1), 0)
            batch["frames"] = jax.random.normal(
                key, (B, c.encoder_frames, c.d_model), jnp.bfloat16)
        if c.family == "vlm":
            key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed + 2), 0)
            batch["patches"] = jax.random.normal(
                key, (B, c.num_patches, c.d_model), jnp.bfloat16)
        return batch

    def _round_batches(self, step: int) -> Dict[str, jax.Array]:
        """local_sgd: [H, n_pods, B/pods, ...] batch stack for one round."""
        H, P = self.cfg.local_sgd.inner_steps, self.cfg.n_pods
        Bp = self.cfg.global_batch // P
        rows = []
        for h in range(H):
            pods = [self._with_aux_inputs(
                self.data.batch_at(step + h, shard_id=p, batch=Bp), Bp)
                for p in range(P)]
            rows.append(tmap(lambda *x: jnp.stack(x), *pods))
        return tmap(lambda *x: jnp.stack(x), *rows)

    def step_once(self) -> Dict[str, float]:
        if self.cfg.mode == "local_sgd":
            batches = self._round_batches(self.step)
            self.state, m = self.round_fn(self.state, batches)
            self.step += self.cfg.local_sgd.inner_steps
        else:
            batch = self._sync_batch(self.step)
            self.state, m = self.step_fn(self.state, batch)
            self.step += 1
        m = {k: float(v) for k, v in m.items()}
        self.timer.tick()
        self.metrics.log(self.step, m)
        if (self.ckpt and self.step % self.cfg.checkpoint_every == 0):
            # non-blocking: the manager snapshots host leaves synchronously,
            # then writes on its thread while the next steps run — periodic
            # checkpointing leaves the hot loop (save() itself serializes
            # against a still-running previous write)
            self.save_checkpoint(blocking=False)
        return m

    def run(self, steps: Optional[int] = None) -> Dict[str, float]:
        target = self.step + (steps if steps is not None else self.cfg.steps)
        last = {}
        while self.step < target:
            last = self.step_once()
        return last

    # ---------------------------------------------------------------- checkpointing
    def save_checkpoint(self, blocking: bool = True) -> Optional[dict]:
        """Snapshot the train state. ``blocking=False`` returns as soon as
        the host-side leaf snapshot is taken; the disk write overlaps the
        following steps and the next save (or ``restore``/``rebind``/an
        explicit blocking save) joins it."""
        if not self.ckpt:
            return None
        self.ckpt.save(self.step, self.state,
                       extra={"data": self.data.state_dict(),
                              "arch": self.cfg.arch, "mode": self.cfg.mode})
        if blocking:
            self.ckpt.wait()
        return {"step": self.step, "path": str(self.ckpt.directory)}

    def restore(self, manifest: Optional[dict] = None,
                strict: bool = False) -> int:
        """Restore from a manifest {step, path} (or latest in our own dir).

        Returns the restored step; 0 means "no checkpoint, fresh start" —
        the resume semantics a train task wants. ``strict=True`` raises
        instead (``FileNotFoundError``): an eval task told to restore MUST
        see a committed checkpoint, never silently score fresh params. All
        integrity checks (manifest-vs-directory staleness, missing leaves,
        torn writes) are ``CheckpointManager.restore``'s and always raise."""
        if self.ckpt:
            self.ckpt.wait()             # our own async save is a valid source
        directory = (manifest or {}).get("path") or (
            self.cfg.checkpoint_dir if self.ckpt else None)
        if directory is None:
            if strict:
                raise FileNotFoundError(
                    f"restore requested but no checkpoint directory in "
                    f"manifest or config: {manifest!r}")
            return 0
        mgr = CheckpointManager(directory)
        step = (manifest or {}).get("step") or mgr.latest_step()
        if step is None:
            if strict:
                raise FileNotFoundError(
                    f"no committed checkpoint in {directory}")
            return 0
        self.state, step, extra = mgr.restore(self.state, step=step)
        self.data.load_state_dict(extra["data"])
        self.step = int(step)
        return self.step

    # -------------------------------------------------------------------- inspection
    def loss(self) -> Optional[float]:
        row = self.metrics.latest()
        return row.get("loss") if row else None

    def params_for_eval(self) -> dict:
        if self.cfg.mode == "local_sgd":
            return tmap(lambda m: m.astype(jnp.dtype(self.arch_cfg.dtype)),
                        self.state["master"])
        return self.state["params"]
