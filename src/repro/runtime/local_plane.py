"""JaxLocalPlane — a local control plane that really executes JAX jobs.

Same interface as ``repro.core.plane.SimLocalPlane`` (capabilities / submit /
cancel / poll / load) so the management plane drives it identically; ``poll``
advances a bounded slice of real work per heartbeat (cooperative scheduling with
the fabric clock), which is what makes the fault-tolerance tests honest: a
cluster killed mid-job leaves a half-trained model whose *restored* continuation
must match the uninterrupted run bit-for-bit.

Checkpoint manifests are published through the ``publish`` callback (the harness
wires it to the overwatch at ``/checkpoints/{job_id}``); re-dispatched jobs carry
``restore_from`` manifests back (see Dispatcher.recover_cluster_jobs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.runtime.serve_loop import Server, ServeJobConfig
from repro.runtime.train_loop import Trainer, TrainJobConfig


@dataclasses.dataclass
class _TrainJob:
    trainer: Trainer
    total_steps: int
    status: str = "running"

    def advance(self, budget: int) -> None:
        n = min(budget, self.total_steps - self.trainer.step)
        if n > 0:
            self.trainer.run(n)
        if self.trainer.step >= self.total_steps:
            self.trainer.save_checkpoint()
            self.status = "done"

    def progress(self) -> float:
        return float(self.trainer.step)

    def rate(self) -> float:
        return self.trainer.timer.steps_per_s

    def extra(self) -> dict:
        return {"loss": self.trainer.loss()}


@dataclasses.dataclass
class _ServeJob:
    server: Server
    status: str = "running"
    served: int = 0

    def advance(self, budget: int) -> None:
        for _ in range(budget):
            if self.server.step() == 0 and not self.server.queue:
                break
        self.served = sum(r.done for r in
                          getattr(self.server, "requests", {}).values())
        if self.server.pending() == 0:
            self.status = "done"

    def progress(self) -> float:
        return float(self.served)

    def rate(self) -> float:
        return 1.0

    def extra(self) -> dict:
        return {"served": self.served}


class JaxLocalPlane:
    """Executes 'train' and 'serve' jobs; anything else is rejected upstream by
    capability matching."""

    def __init__(self, caps=("cpu", "train", "serve"),
                 steps_per_poll: int = 2,
                 publish: Optional[Callable[[str, dict], None]] = None,
                 mesh=None, checkpoint_root: Optional[str] = None):
        self._caps = tuple(caps)
        self.steps_per_poll = steps_per_poll
        self.publish = publish
        self.mesh = mesh
        self.checkpoint_root = checkpoint_root
        self.jobs: Dict[str, object] = {}

    def capabilities(self):
        return self._caps

    # --------------------------------------------------------------------- lifecycle
    def submit(self, job: dict) -> None:
        jid = job["job_id"]
        kind = job.get("kind", "train")
        if kind == "serve":
            cfg = ServeJobConfig.from_job(job)
            server = Server(cfg, mesh=self.mesh)
            for p in job.get("payload", {}).get("requests", ()):
                server.submit(p.get("prompt", [1, 2, 3]),
                              p.get("max_new", 8))
            self.jobs[jid] = _ServeJob(server)
            return
        cfg = TrainJobConfig.from_job(job)
        if cfg.checkpoint_dir is None and self.checkpoint_root:
            cfg = dataclasses.replace(
                cfg, checkpoint_dir=f"{self.checkpoint_root}/{jid}")
        on_ckpt = None
        if self.publish:
            def on_ckpt(step: int, path: str, _jid=jid) -> None:
                # path is .../step_XXXXXXXX/manifest.json; the manifest records
                # the checkpoint DIRECTORY (what a restoring Trainer needs).
                import os
                ck_dir = os.path.dirname(os.path.dirname(path))
                self.publish(_jid, {"step": step, "path": ck_dir})
        trainer = Trainer(cfg, mesh=self.mesh, on_checkpoint=on_ckpt)
        restore = job.get("restore_from")
        if restore:
            trainer.restore(restore)
        self.jobs[jid] = _TrainJob(trainer, total_steps=cfg.steps)

    def cancel(self, job_id: str) -> None:
        rec = self.jobs.get(job_id)
        if rec is not None:
            rec.status = "failed"

    def poll(self, job_id: str) -> dict:
        rec = self.jobs[job_id]
        if rec.status == "running":
            rec.advance(self.steps_per_poll)
        out = {"progress": rec.progress(), "status": rec.status,
               "rate": rec.rate() if rec.status == "running" else 0.0}
        out.update(rec.extra())
        return out

    def load(self) -> float:
        return sum(1.0 for r in self.jobs.values() if r.status == "running")
