"""Sharded, async, atomically-committed checkpointing.

Layout: <dir>/step_<N>/<leaf-files>.bin + manifest.json. The manifest is written
LAST (fsync'd, then atomically renamed); a checkpoint without a manifest is
invisible to ``latest_step`` — a crash mid-save can never corrupt restartability.
Commit callbacks let the Titchener overwatch record the manifest (the management
plane's "last committed checkpoint" used by the dispatcher for re-dispatch after
pod failure).

On a real multi-host fleet each process writes only its addressable shards; here
(single process) leaves are fetched whole. The on-disk format is dtype-agnostic
raw bytes + a JSON description, so bf16/int8 round-trip without pickle.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append(_SEP.join(parts))
        leaves.append(leaf)
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, use_async: bool = True):
        self.directory = directory
        self.keep = keep
        self.use_async = use_async
        self._thread: Optional[threading.Thread] = None
        self._commit_hooks: List[Callable[[int, str], None]] = []
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------------- hooks
    def on_commit(self, fn: Callable[[int, str], None]) -> None:
        """fn(step, manifest_path) runs after a checkpoint becomes durable."""
        self._commit_hooks.append(fn)

    # -------------------------------------------------------------------------- save
    def save(self, step: int, tree, extra: Optional[dict] = None,
             blocking: bool = False) -> str:
        """Snapshot ``tree`` (+ JSON-serializable ``extra``) at ``step``."""
        self.wait()
        names, leaves, _ = _flatten_with_names(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        target = os.path.join(self.directory, f"step_{step:08d}")

        def write():
            tmp = target + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            entries = {}
            for i, (name, arr) in enumerate(zip(names, host_leaves)):
                fname = f"leaf_{i:05d}.bin"
                with open(os.path.join(tmp, fname), "wb") as f:
                    f.write(arr.tobytes())
                entries[name] = {"file": fname, "shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
            manifest = {"step": step, "leaves": entries, "extra": extra or {}}
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath + ".tmp", "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.rename(mpath + ".tmp", mpath)           # manifest last = commit point
            # swap the finished tree in WITHOUT a window where no committed
            # checkpoint exists at this step: rename the old tree aside, then
            # the atomic tmp->target rename, then drop the old one. A crash
            # anywhere in the sequence leaves at least one complete,
            # manifest-bearing tree on disk (the .old survivor is ignored by
            # all_steps and reaped by the next save of this step).
            if os.path.exists(target):
                old = target + ".old"
                if os.path.exists(old):
                    shutil.rmtree(old)
                os.rename(target, old)
                os.rename(tmp, target)
                shutil.rmtree(old, ignore_errors=True)
            else:
                os.rename(tmp, target)
            self._gc()
            for hook in self._commit_hooks:
                hook(step, os.path.join(target, "manifest.json"))

        if self.use_async and not blocking:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return target

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------------ restore
    def all_steps(self) -> List[int]:
        out = []
        for d in sorted(os.listdir(self.directory)):
            if not d.startswith("step_"):
                continue
            try:
                step = int(d[5:])       # skips .tmp / .old crash leftovers
            except ValueError:
                continue
            if os.path.exists(os.path.join(self.directory, d,
                                           "manifest.json")):
                out.append(step)
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None) -> tuple:
        """Restore into the structure of ``like`` (tree of arrays or
        ShapeDtypeStructs). Returns (tree, step, extra)."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        target = os.path.join(self.directory, f"step_{step:08d}")
        mpath = os.path.join(target, "manifest.json")
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"checkpoint step {step} has no committed manifest "
                f"(crash left an uncommitted tree?): {mpath}")
        with open(mpath) as f:
            manifest = json.load(f)
        # staleness/integrity validation BEFORE any bytes are materialized: a
        # manifest that disagrees with its directory name, a missing leaf
        # file, or a truncated one (torn write around the commit point) must
        # fail loudly here — not as a reshape error (or worse, silently wrong
        # params) deep inside restore
        if manifest.get("step") != step:
            raise ValueError(
                f"stale checkpoint: directory says step {step} but manifest "
                f"says step {manifest.get('step')}")
        names, leaves, treedef = _flatten_with_names(like)
        for name in names:
            ent = manifest["leaves"].get(name)
            if ent is None:
                raise KeyError(f"checkpoint step {step} has no leaf {name!r}")
            path = os.path.join(target, ent["file"])
            if not os.path.exists(path):
                raise FileNotFoundError(
                    f"checkpoint step {step}: leaf file missing: {path}")
            want = (int(np.prod(ent["shape"])) if ent["shape"] else 1) \
                * jnp.dtype(ent["dtype"]).itemsize
            got = os.path.getsize(path)
            if got != want:
                raise ValueError(
                    f"checkpoint step {step}: leaf {name!r} is {got} bytes, "
                    f"expected {want} ({ent['shape']} {ent['dtype']})")
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        out = []
        for name, leaf, shd in zip(names, leaves, shard_leaves):
            ent = manifest["leaves"][name]
            dtype = jnp.dtype(ent["dtype"])
            with open(os.path.join(target, ent["file"]), "rb") as f:
                arr = np.frombuffer(f.read(), dtype=dtype).reshape(ent["shape"])
            val = jax.device_put(arr, shd) if shd is not None else jnp.asarray(arr)
            out.append(val)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, manifest["step"], manifest["extra"]
