# Perf-critical compute layers of the managed substrate (DESIGN.md §6):
# flash_attention, ssd_scan, rmsnorm — each: pallas kernel + ops.py wrapper + ref.py oracle.
