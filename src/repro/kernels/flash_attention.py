"""Flash attention (causal/windowed, GQA) as a Pallas TPU kernel.

TPU-native design (see DESIGN.md §6):
  * grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the innermost grid dim is
    sequential on TPU, so VMEM scratch (acc/m/l) carries the online-softmax state
    across kv blocks — HBM→VMEM streams one (blk_q × d) q tile and one (blk_kv × d)
    k/v tile at a time.
  * blocks are MXU-aligned (128); head_dim is padded to a multiple of 128 by ops.py.
  * GQA is expressed in the k/v BlockSpec index_map (q head h reads kv head h//group),
    so no repeat_kv materialization ever happens.
  * causal + sliding-window masks are computed from global block offsets; fully-masked
    blocks still occupy grid slots but short-circuit through pl.when.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int, blk_q: int, blk_kv: int,
                 num_kv_blocks: int, seq_q: int, seq_kv: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    q_start = qi * blk_q
    k_start = kj * blk_kv

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level reachability: skip compute for blocks entirely outside the mask
    q_last = q_start + blk_q - 1
    reachable = jnp.asarray(True)
    if causal:
        reachable = jnp.logical_and(reachable, k_start <= q_last)
    if window > 0:
        reachable = jnp.logical_and(reachable, k_start + blk_kv - 1 >= q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale          # [blk_q, d]
        k = k_ref[0, :, 0, :].astype(jnp.float32)                  # [blk_kv, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))    # [blk_q, blk_kv]

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_kv
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_cur

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           blk_q: int = 128, blk_kv: int = 128,
                           interpret: bool = False):
    """q: [B, Sq, H, D]; k, v: [B, Skv, K, D] with H % K == 0. D must be 128-aligned
    (ops.py pads). Returns [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    assert H % K == 0, (H, K)
    group = H // K
    blk_q = min(blk_q, Sq)
    blk_kv = min(blk_kv, Skv)
    nq = pl.cdiv(Sq, blk_q)
    nkv = pl.cdiv(Skv, blk_kv)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_kv=blk_kv, num_kv_blocks=nkv, seq_q=Sq, seq_kv=Skv)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, blk_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, blk_kv, 1, D), lambda b, h, i, j, g=group: (b, j, h // g, 0)),
            pl.BlockSpec((1, blk_kv, 1, D), lambda b, h, i, j, g=group: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, D), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
