"""Fused RMSNorm as a Pallas TPU kernel: one HBM read, f32 accumulation in VMEM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, *, eps: float = 1e-6, blk_rows: int = 256,
                   interpret: bool = False):
    """x: [..., D] flattened to rows; scale: [D]."""
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    blk = min(blk_rows, rows)
    grid = (pl.cdiv(rows, blk),)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
