"""Pure-jnp oracles for every kernel. Naive, O(S^2)-memory where applicable —
small shapes only; tests assert_allclose kernels (interpret=True) against these."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q [B,Sq,H,D], k/v [B,Skv,K,D] -> [B,Sq,H,D]. Naive masked softmax attention.

    For decode (Sq=1 against a prefix cache) set causal=False and pass the valid
    prefix only."""
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    group = H // K
    kk = jnp.repeat(k, group, axis=2)
    vv = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / math.sqrt(D)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        # align ends: q token i sits at absolute position i + (Skv - Sq)
        mask = mask & (k_pos <= q_pos + (Skv - Sq))
    if window > 0:
        mask = mask & (q_pos + (Skv - Sq) - k_pos < window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_ref(x, dt, a, bm, cm):
    """Naive per-timestep SSD recurrence (the oracle for ssd_scan).

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t . h_t
    x [B,S,H,P], dt [B,S,H], a [H], bm/cm [B,S,N] -> y [B,S,H,P], final h [B,H,N,P]
    """
    B, S, H, P = x.shape
    N = bm.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    bf, cf = bm.astype(jnp.float32), cm.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp          # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dtt * af[None, :])                        # [B,H]
        inject = jnp.einsum("bn,bhp->bhnp", bt, xt * dtt[..., None])
        h = h * decay[..., None, None] + inject                   # [B,H,N,P]
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                    # [B,S,H,P]
    return y, h


def rmsnorm_ref(x, scale, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
