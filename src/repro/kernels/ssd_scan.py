"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

Per (batch, head) the sequence is split into chunks of Q tokens. Within a chunk the
"dual" quadratic form runs on the MXU (a [Q, Q] decay-masked score matmul); across
chunks a [N, P] state recurrence is carried in VMEM scratch — the innermost grid dim
(chunk index) is sequential on TPU, so the scratch state plays the role of the
recurrent carry with zero HBM round-trips.

Inputs (single B/C group, as mamba2 uses G=1):
  x  [B, S, H, P]   token inputs per head
  dt [B, S, H]      softplus-activated timestep (>0)
  A  [H]            negative decay rate per head (A < 0)
  Bm [B, S, N]      input projection onto state
  Cm [B, S, N]      state readout
Output: y [B, S, H, P], plus (optionally, via ops.py) the final state [B, H, N, P].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [Q]
    a = a_ref[0].astype(jnp.float32)                 # scalar (this head)
    bm = b_ref[0, :, :].astype(jnp.float32)          # [Q, N]
    cm = c_ref[0, :, :].astype(jnp.float32)          # [Q, N]

    dta = dt * a                                     # [Q] (negative)
    cum = jnp.cumsum(dta)                            # inclusive cumsum
    seg_total = cum[-1]

    # intra-chunk dual form: L[i, j] = exp(cum[i] - cum[j]) for i >= j
    li = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = iota_i >= iota_j
    decay = jnp.where(causal, jnp.exp(li), 0.0)      # [Q, Q]
    scores = (cm @ bm.T) * decay                     # [Q, Q]
    xdt = x * dt[:, None]                            # [Q, P]
    y_intra = scores @ xdt                           # [Q, P]

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                           # [N, P] f32
    y_inter = jnp.exp(cum)[:, None] * (cm @ state)   # [Q, P]

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(sum dta) h + sum_j exp(cum[-1]-cum[j]) dt_j B_j x_j^T
    w = jnp.exp(seg_total - cum) * dt                # [Q]
    new_state = jnp.exp(seg_total) * state + (bm * w[:, None]).T @ x  # [N, P]
    state_ref[...] = new_state


def ssd_scan_pallas(x, dt, a, bm, cm, *, chunk: int = 256, interpret: bool = False):
    """See module docstring. S must be divisible by ``chunk`` (ops.py pads)."""
    B, S, H, P = x.shape
    N = bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bm, cm)
