"""Public kernel ops with platform dispatch.

impl resolution order:
  * "pallas"  — pl.pallas_call TPU kernel (interpret=True on CPU for tests)
  * "blocked" — pure-jnp block-streaming implementation with identical math and
                O(S)-memory (the lowering target on CPU, incl. the multi-pod dry-run)
  * "naive"   — ref.py oracle (small shapes / tests only)

``flash_attention`` carries a custom VJP implementing the block-wise flash backward
(residuals are q, k, v, o, lse — O(S), never O(S^2)), so training at 4k–32k sequence
lengths keeps linear attention memory on both forward and backward passes.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

NEG_INF = -1e30


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "blocked"


# --------------------------------------------------------------------------- attention
def _block_mask(q_start, blk_q, k_start, blk_kv, offset, causal, window, seq_kv):
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_kv), 0) + offset
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_kv), 1)
    mask = k_pos < seq_kv
    if causal:
        mask = jnp.logical_and(mask, k_pos <= q_pos)
    if window > 0:
        mask = jnp.logical_and(mask, q_pos - k_pos < window)
    return mask


def _flash_fwd_blocked(q, k, v, causal, window, blk_kv=512):
    """Online-softmax forward, scanning kv blocks. Returns (o, lse)."""
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    group = H // K
    scale = 1.0 / math.sqrt(D)
    offset = Skv - Sq  # q token i lives at absolute position i + offset
    blk = min(blk_kv, Skv)
    nkv = -(-Skv // blk)
    pad = nkv * blk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nkv, blk, K, D)
    vb = v.reshape(B, nkv, blk, K, D)
    qf = q.astype(jnp.float32) * scale

    def step(carry, inp):
        acc, m, l = carry
        j, kj, vj = inp
        kj = jnp.repeat(kj.astype(jnp.float32), group, axis=2)   # [B,blk,H,D]
        vj = jnp.repeat(vj.astype(jnp.float32), group, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj)                # [B,H,Sq,blk]
        mask = _block_mask(0, Sq, j * blk, blk, offset, causal, window, Skv)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vj)
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    xs = (jnp.arange(nkv), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0))
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), xs)
    l = jnp.maximum(l, 1e-30)
    o = (acc / l[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)  # [B,Sq,H,D]
    lse = m + jnp.log(l)                                            # [B,H,Sq]
    return o, lse


def _flash_bwd_blocked(causal, window, blk_kv, res, do):
    q, k, v, o, lse = res
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    group = H // K
    scale = 1.0 / math.sqrt(D)
    offset = Skv - Sq
    blk = min(blk_kv, Skv)
    nkv = -(-Skv // blk)
    pad = nkv * blk - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    kb = jnp.moveaxis(kp.reshape(B, nkv, blk, K, D), 1, 0)
    vb = jnp.moveaxis(vp.reshape(B, nkv, blk, K, D), 1, 0)

    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    delta = jnp.einsum("bqhd,bqhd->bhq", o.astype(jnp.float32), dof)  # [B,H,Sq]

    def step(dq, inp):
        j, kj, vj = inp
        kjr = jnp.repeat(kj.astype(jnp.float32), group, axis=2)
        vjr = jnp.repeat(vj.astype(jnp.float32), group, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kjr) * scale
        mask = _block_mask(0, Sq, j * blk, blk, offset, causal, window, Skv)
        p = jnp.where(mask[None, None], jnp.exp(s - lse[..., None]), 0.0)
        dv_j = jnp.einsum("bhqk,bqhd->bkhd", p, dof)
        dp = jnp.einsum("bqhd,bkhd->bhqk", dof, vjr)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhqk,bkhd->bqhd", ds, kjr)
        dk_j = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
        # fold GQA groups back onto kv heads
        dk_j = dk_j.reshape(B, blk, K, group, D).sum(axis=3)
        dv_j = dv_j.reshape(B, blk, K, group, D).sum(axis=3)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Sq, H, D), jnp.float32)
    xs = (jnp.arange(nkv), kb, vb)
    dq, (dkb, dvb) = jax.lax.scan(step, dq0, xs)
    dk = jnp.moveaxis(dkb, 0, 1).reshape(B, nkv * blk, K, D)[:, :Skv]
    dv = jnp.moveaxis(dvb, 0, 1).reshape(B, nkv * blk, K, D)[:, :Skv]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_blocked(q, k, v, causal, window, blk_kv):
    o, _ = _flash_fwd_blocked(q, k, v, causal, window, blk_kv)
    return o


def _flash_blocked_fwd(q, k, v, causal, window, blk_kv):
    o, lse = _flash_fwd_blocked(q, k, v, causal, window, blk_kv)
    return o, (q, k, v, o, lse)


_flash_blocked.defvjp(_flash_blocked_fwd, _flash_bwd_blocked)


def _pad_head_dim(x, mult=128):
    D = x.shape[-1]
    pad = (-D) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, D


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: Optional[str] = None, blk_kv: int = 512,
                    interpret: bool = False):
    """q [B,Sq,H,D], k/v [B,Skv,K,D] -> [B,Sq,H,D]. GQA via H % K == 0."""
    impl = impl or _default_impl()
    if impl == "naive":
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    if impl == "pallas":
        qp, D0 = _pad_head_dim(q)
        kp, _ = _pad_head_dim(k)
        vp, _ = _pad_head_dim(v)
        if qp.shape[-1] != D0:
            # keep the softmax scale of the true head dim
            qp = qp * math.sqrt(qp.shape[-1] / D0)
        out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                     interpret=interpret)
        return out[..., :D0]
    return _flash_blocked(q, k, v, causal, window, blk_kv)


def attend_cache(q, k_cache, v_cache, pos, *, window: int = 0,
                 packed: bool = False):
    """Decode-step attention: q [B,1,H,D] against a [B,Smax,K,D] cache where
    positions >= ``pos``+1 are not yet written. Plain einsum (q_len == 1).

    ``packed=True`` (§Perf decode lever): GQA grouped einsum directly against
    the bf16 cache — no ``jnp.repeat`` (group x) and no f32 cache copy (2x),
    i.e. up to 2·group x less cache read traffic; f32 happens only in the MXU
    accumulator (preferred_element_type)."""
    B, _, H, D = q.shape
    _, Smax, K, _ = k_cache.shape
    group = H // K
    if packed:
        qg = q.reshape(B, K, group, D)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                       preferred_element_type=jnp.float32) / math.sqrt(D)
        k_pos = jnp.arange(Smax)[None, None, None, :]
        mask = k_pos <= pos.reshape(B, 1, 1, 1)
        if window > 0:
            mask = jnp.logical_and(mask,
                                   pos.reshape(B, 1, 1, 1) - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v_cache,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, 1, H, D).astype(q.dtype)
    kk = jnp.repeat(k_cache.astype(jnp.float32), group, axis=2)
    vv = jnp.repeat(v_cache.astype(jnp.float32), group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk) / math.sqrt(D)
    k_pos = jnp.arange(Smax)[None, None, None, :]
    mask = k_pos <= pos
    if window > 0:
        mask = jnp.logical_and(mask, pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    return out.astype(q.dtype)


def attend_cache_ring(q, k_cache, v_cache, pos):
    """Decode attention against a ring-buffer window cache of size W.

    Slot s holds absolute position p_s = pos - ((pos - s) mod W); every live slot is
    inside the window by construction, so the only mask is p_s >= 0 (cold start).
    q [B,1,H,D]; k/v [B,W,K,D]; pos [B] (the position just written)."""
    B, _, H, D = q.shape
    _, W, K, _ = k_cache.shape
    group = H // K
    kk = jnp.repeat(k_cache.astype(jnp.float32), group, axis=2)
    vv = jnp.repeat(v_cache.astype(jnp.float32), group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk) / math.sqrt(D)
    slots = jnp.arange(W)[None, :]
    p_slot = pos[:, None] - jnp.mod(pos[:, None] - slots, W)      # [B, W]
    mask = (p_slot >= 0)[:, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- SSD scan
def _ssd_blocked(x, dt, a, bm, cm, chunk, init_state=None):
    """Chunked SSD in pure jnp (same math as the pallas kernel), vectorized over
    chunks with a lax.scan inter-chunk recurrence. Returns (y, final_state)."""
    B, S, H, P = x.shape
    N = bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    xf = x.reshape(B, nc, Q, H, P).astype(jnp.float32)
    dtf = dt.reshape(B, nc, Q, H).astype(jnp.float32)
    bf = bm.reshape(B, nc, Q, N).astype(jnp.float32)
    cf = cm.reshape(B, nc, Q, N).astype(jnp.float32)
    af = a.astype(jnp.float32)

    dta = dtf * af                                   # [B,nc,Q,H]
    cum = jnp.cumsum(dta, axis=2)
    seg = cum[:, :, -1, :]                           # [B,nc,H]

    # intra-chunk (dual quadratic form)
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # [B,nc,Q,Q,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cf, bf)                # [B,nc,Q,Q]
    xdt = xf * dtf[..., None]                                 # [B,nc,Q,H,P]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, xdt)

    # chunk states: S_c = sum_j exp(seg - cum_j) dt_j B_j (x_j)^T
    w = jnp.exp(seg[:, :, None, :] - cum) * dtf               # [B,nc,Q,H]
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bf, w, xf)  # [B,nc,H,N,P]

    # inter-chunk recurrence over c
    def step(h, inp):
        seg_c, st_c = inp                                     # [B,H], [B,H,N,P]
        h_out = h                                             # state entering chunk c
        h = h * jnp.exp(seg_c)[..., None, None] + st_c
        return h, h_out

    h0 = (jnp.zeros((B, H, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    hT, h_in = jax.lax.scan(step, h0, (jnp.moveaxis(seg, 1, 0),
                                       jnp.moveaxis(states, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                           # [B,nc,H,N,P]

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", cf, jnp.exp(cum), h_in)
    y = (y_intra + y_inter).reshape(B, Sp, H, P)[:, :S].astype(x.dtype)
    return y, hT


def ssd_scan(x, dt, a, bm, cm, *, chunk: int = 256, impl: Optional[str] = None,
             init_state=None, return_state: bool = False, interpret: bool = False):
    impl = impl or _default_impl()
    if impl == "naive":
        y, h = ref.ssd_ref(x, dt, a, bm, cm)
    elif impl == "pallas":
        S = x.shape[1]
        Q = min(chunk, S)
        pad = (-S) % Q
        if pad or init_state is not None or return_state:
            # pallas path currently covers the steady-state (no initial state) case;
            # fall back for the others
            y, h = _ssd_blocked(x, dt, a, bm, cm, chunk, init_state)
        else:
            y = ssd_scan_pallas(x, dt, a, bm, cm, chunk=Q, interpret=interpret)
            h = None
    else:
        y, h = _ssd_blocked(x, dt, a, bm, cm, chunk, init_state)
    return (y, h) if return_state else y


def ssd_decode_step(x, dt, a, bm, cm, state):
    """One-token SSD recurrence. x [B,1,H,P], dt [B,1,H], bm/cm [B,1,N],
    state [B,H,N,P] -> (y [B,1,H,P], new_state)."""
    xf = x[:, 0].astype(jnp.float32)
    dtf = dt[:, 0].astype(jnp.float32)
    bf = bm[:, 0].astype(jnp.float32)
    cf = cm[:, 0].astype(jnp.float32)
    decay = jnp.exp(dtf * a.astype(jnp.float32)[None, :])     # [B,H]
    inject = jnp.einsum("bn,bhp->bhnp", bf, xf * dtf[..., None])
    new_state = state.astype(jnp.float32) * decay[..., None, None] + inject
    y = jnp.einsum("bn,bhnp->bhp", cf, new_state)
    return y[:, None].astype(x.dtype), new_state.astype(state.dtype)


# --------------------------------------------------------------------------- rmsnorm
def rmsnorm(x, scale, *, eps: float = 1e-6, impl: Optional[str] = None,
            interpret: bool = False):
    impl = impl or ("pallas" if jax.default_backend() == "tpu" else "naive")
    if impl == "pallas":
        return rmsnorm_pallas(x, scale, eps=eps, interpret=interpret)
    return ref.rmsnorm_ref(x, scale, eps=eps)
