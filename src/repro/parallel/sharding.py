"""Logical-axis sharding rules over the (pod, data, model) production mesh.

Every tensor dimension in the framework carries a *logical* axis name; ``MeshPlan``
maps logical names to mesh axes and degrades gracefully (drops mesh axes) whenever a
dimension is not divisible — so the same model code lowers on the 512-chip production
mesh, the 256-chip single-pod mesh, and a 2-device CPU test mesh.

Cross-pod traffic discipline (the paper's thin-boundary insight): only the "pod" axis
crosses DCN. Rules keep every *per-layer* collective (TP/SP/EP/FSDP) on in-pod axes;
the pod axis carries batch parallelism only, so the per-step DCN traffic is exactly one
gradient reduction — which the Titchener local-sync trainer further amortizes/compresses
(see repro/optim/local_sgd.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (in order; trailing axes dropped if not divisible)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": (),                 # activations: sequence stays unsharded unless SP
    "seq_sp": ("model",),      # residual-stream sequence parallelism
    "cache_seq": ("model",),   # decode KV/state cache: shard time dim on model axis
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "ffn_nofsdp": (),
    "ssm_heads": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "embed": ("data",),        # FSDP: weight-embed dim over the in-pod data axis
    "embed_nofsdp": (),
    "layers": (),              # scan dimension
    "state": (),
    "conv": (),
    "qk_depth": (),
    "capacity": (),
    None: (),
}


# optimizer-state override: ZeRO — spread the FSDP dim over the pod axis as well,
# so AdamW moments + f32 master shard 512-way (grads are pod-reduced anyway).
OPT_RULES = dict(DEFAULT_RULES, embed=("pod", "data"))

# pure data-parallel + ZeRO rules for small models where TP matmuls fall below
# MXU efficiency (hillclimb lever; see EXPERIMENTS.md §Perf cell 3): batch over
# EVERY axis, weights ZeRO-sharded over (data, model), no tensor parallelism.
DP_ONLY_RULES = dict(
    DEFAULT_RULES,
    batch=("pod", "data", "model"),
    heads=(), kv_heads=(), ffn=(), ssm_heads=(),
    vocab=(),
    embed=("data", "model"),
    cache_seq=(),
)


def opt_rules_for(base: dict) -> dict:
    """ZeRO optimizer rules derived from any base rule set: spread the weight
    embed dim over the pod axis in addition to the base axes."""
    embed = tuple(dict.fromkeys(("pod",) + tuple(base.get("embed", ()))))
    return dict(base, embed=embed)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A mesh plus the policy switches that pick sharding rules."""
    mesh: Mesh
    fsdp: bool = True          # shard weight embed dims over "data" (ZeRO-3 style)
    sp: bool = False           # sequence-parallel residual stream (hillclimb switch)
    bf16_reduce: bool = False  # bf16 partial-sum dots -> bf16 TP all-reduces
    moe_combine_reshard: bool = False  # a2a slot buffers before combine gather
    rules: Optional[dict] = None

    @property
    def reduce_dtype(self):
        """preferred_element_type for dots whose partial sums cross TP shards.
        bf16 halves every TP all-reduce + the activation traffic around it; the
        MXU still accumulates f32 within a tile (TPU), so only the cross-shard
        reduction is low-precision (MaxText default practice)."""
        import jax.numpy as jnp
        return jnp.bfloat16 if self.bf16_reduce else None

    def axis_size(self, name: str) -> int:
        return self.mesh.shape.get(name, 1)

    def _mesh_axes_for(self, logical: Optional[str],
                       rules: Optional[dict] = None,
                       is_opt: bool = False) -> Tuple[str, ...]:
        rules = rules if rules is not None else (self.rules or DEFAULT_RULES)
        if logical == "embed" and not self.fsdp and not is_opt:
            logical = "embed_nofsdp"
        if logical == "seq" and self.sp:
            logical = "seq_sp"
        axes = rules.get(logical, ())
        return tuple(a for a in axes if a in self.mesh.shape)

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None,
             rules: Optional[dict] = None, is_opt: bool = False) -> P:
        """PartitionSpec for a tensor; drops mesh axes a dim can't divide and never
        reuses a mesh axis across dims (PartitionSpec invariant)."""
        entries = []
        used = set()
        for d, logical in enumerate(logical_axes):
            axes = tuple(a for a in self._mesh_axes_for(logical, rules, is_opt)
                         if a not in used)
            if shape is not None and axes:
                kept = []
                prod = 1
                for a in axes:
                    n = self.axis_size(a)
                    if shape[d] % (prod * n) == 0:
                        kept.append(a)
                        prod *= n
                    else:
                        break
                axes = tuple(kept)
            used.update(axes)
            entries.append(axes if len(axes) != 1 else axes[0])
        cleaned = [e if e != () else None for e in entries]
        while cleaned and cleaned[-1] is None:
            cleaned.pop()
        return P(*cleaned)

    def opt_spec(self, logical_axes, shape=None) -> P:
        """PartitionSpec for optimizer state (ZeRO over the pod axis)."""
        base = self.rules or DEFAULT_RULES
        return self.spec(logical_axes, shape, rules=opt_rules_for(base),
                         is_opt=True)

    def sharding(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def logical_spec(plan: MeshPlan, logical_axes, shape=None) -> P:
    return plan.spec(logical_axes, shape)


def constrain(x: jax.Array, plan: MeshPlan, logical_axes) -> jax.Array:
    """with_sharding_constraint by logical axes (shape-aware divisibility fallback)."""
    spec = plan.spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(plan.mesh, spec))


def pad_to_multiple(n: int, m: int) -> int:
    return int(math.ceil(n / m) * m)
