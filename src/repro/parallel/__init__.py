from repro.parallel.sharding import MeshPlan, logical_spec, constrain  # noqa: F401
