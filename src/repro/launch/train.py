"""Training launcher.

Two modes:
  * ``--driver``   (default) — run a real (reduced-config on CPU, full on TPU)
    training job through the management plane: registers pods, dispatches a
    train job, ticks heartbeats, prints progress + the boundary byte ledger.
  * ``--direct``   — run the Trainer directly (no management plane), useful for
    quick loss-curve checks and the 100M end-to-end example.

On a real fleet this same file is the per-host entrypoint: jax.distributed
initializes from the scheduler-provided coordinator, make_production_mesh()
builds the (pod, data, model) mesh, and the control agent points at the real
overwatch endpoint instead of the in-process one.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --steps 30
  PYTHONPATH=src python -m repro.launch.train --direct --mode local_sgd
"""
from __future__ import annotations

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mode", choices=("sync", "local_sgd"), default="sync")
    ap.add_argument("--direct", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--clusters", type=int, default=2,
                    help="driver mode: number of private clusters")
    args = ap.parse_args()

    payload = {"arch": args.arch, "steps": args.steps, "seq_len": args.seq_len,
               "global_batch": args.global_batch, "mode": args.mode,
               "checkpoint_dir": args.checkpoint_dir}

    if args.direct:
        from repro.runtime.train_loop import Trainer, TrainJobConfig
        tr = Trainer(TrainJobConfig.from_job({"payload": payload}))
        for _ in range(args.steps):
            m = tr.step_once()
            if tr.step % 5 == 0 or tr.step == args.steps:
                print(f"step {tr.step:5d} loss {m.get('loss', m.get('delta_norm', 0)):.4f} "
                      f"({tr.timer.tokens_per_s:.0f} tok/s)")
        return

    from repro.core.plane import ManagementPlane
    from repro.runtime.local_plane import JaxLocalPlane

    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True,
                      local_plane=JaxLocalPlane())
    for i in range(args.clusters):
        name = f"private-{i}"
        agent_holder = {}
        lp = JaxLocalPlane(
            publish=lambda jid, man, _n=name: plane.agents[_n].ow.put(
                f"/checkpoints/{jid}", man),
            checkpoint_root=args.checkpoint_dir or "/tmp/titchener_ckpt")
        plane.add_cluster(name, local_plane=lp)

    jid = plane.submit_job("train", arch=args.arch, steps=args.steps,
                           payload=payload)
    print(f"dispatched {jid}")
    done = plane.run_until_done([jid], max_ticks=10 * args.steps + 100)
    st = plane.job_status(jid)
    print(f"status: {json.dumps(st, indent=1)}")
    print("boundary:", json.dumps(plane.boundary_report()["cross_cluster_bytes"]))
    if not done:
        raise SystemExit("job did not finish in the tick budget")


if __name__ == "__main__":
    main()
