import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: prove the production mesh shards every (arch x shape) cell.

For each cell this lowers + compiles the assigned step on
  * the single-pod mesh  (data=16, model=16)  = 256 chips, and
  * the multi-pod mesh   (pod=2, data=16, model=16) = 512 chips,
prints ``compiled.memory_analysis()`` (proves the per-device footprint) and
``compiled.cost_analysis()`` (XLA's view), runs the while-aware HLO accounting
(repro.roofline.hlo_stats — XLA's cost analysis does not multiply scanned layer
stacks), and writes one JSON artifact per cell under artifacts/dryrun/<mesh>/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --skip-existing
  ... --set sp=true --set num_microbatches=4 --tag sp_on       # hillclimb variants
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import base as configs
from repro.configs.shapes import SHAPES, cell_is_runnable
from repro.launch.mesh import CHIPS_PER_POD, make_production_mesh
from repro.launch.steps import CellOptions, build_cell
from repro.roofline.hlo_stats import module_stats, stats_to_json

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _mem_json(ma) -> dict:
    if ma is None:
        return {}
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes")
    return {f: getattr(ma, f, 0) for f in fields}


def run_cell(arch: str, shape: str, mesh_kind: str, opts: CellOptions,
             tag: str = "baseline", verbose: bool = True) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, opts)
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    st = module_stats(text, pod_size=CHIPS_PER_POD,
                      n_devices=mesh.devices.size)

    rec = {
        "cell": f"{arch}/{shape}",
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "tag": tag,
        "step": cell.spec.step,
        "chips": int(mesh.devices.size),
        "options": {**dataclasses.asdict(opts),
                    "extra": dict(opts.extra)},
        "timings_s": {"lower": round(t_lower, 2),
                      "compile": round(t_compile, 2)},
        "memory_analysis": _mem_json(ma),
        "xla_cost_analysis": {k: ca.get(k) for k in ("flops", "bytes accessed")
                              if k in ca},
        "hlo_stats": stats_to_json(st),
        "hlo_text_bytes": len(text),
        "params": cell.cfg.param_count(),
        "active_params": cell.cfg.active_param_count(),
        "tokens_per_step": (cell.spec.global_batch *
                            (cell.spec.seq_len
                             if cell.spec.step != "decode" else 1)),
    }
    if verbose:
        mm = rec["memory_analysis"]
        per_dev = (mm.get("argument_size_in_bytes", 0)
                   + mm.get("temp_size_in_bytes", 0)
                   + mm.get("output_size_in_bytes", 0)
                   - mm.get("alias_size_in_bytes", 0))
        print(f"  memory_analysis: {mm}")
        print(f"  -> bytes/device ~ {per_dev/1e9:.2f} GB")
        print(f"  cost_analysis(XLA): {rec['xla_cost_analysis']}")
        hs = rec["hlo_stats"]
        print(f"  hlo_stats (while-aware, per device): "
              f"flops={hs['flops']:.3e} hbm={hs['hbm_bytes']:.3e} "
              f"coll={hs['collective_bytes']:.3e} "
              f"(dcn={hs['cross_pod_bytes']:.3e})")
    return rec


def artifact_path(arch: str, shape: str, mesh_kind: str,
                  tag: str = "baseline") -> Path:
    d = ARTIFACTS / mesh_kind
    d.mkdir(parents=True, exist_ok=True)
    suffix = "" if tag == "baseline" else f"__{tag}"
    return d / f"{arch}__{shape}{suffix}.json"


def parse_set(kvs) -> CellOptions:
    opts = {}
    for kv in kvs or ():
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            v = v.lower() == "true"
        elif v.lstrip("-").isdigit():
            v = int(v)
        else:
            try:
                v = float(v)
            except ValueError:
                pass
        opts[k] = v
    known = {f.name for f in dataclasses.fields(CellOptions)}
    extra = tuple((k, v) for k, v in opts.items() if k not in known)
    kwargs = {k: v for k, v in opts.items() if k in known}
    if extra:
        kwargs["extra"] = extra
    return CellOptions(**kwargs)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", help="arch id (repeatable)")
    ap.add_argument("--shape", action="append", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--set", action="append", dest="sets", metavar="K=V",
                    help="CellOptions override, e.g. --set sp=true")
    ap.add_argument("--tag", default="baseline",
                    help="artifact tag (hillclimb variants)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    opts = parse_set(args.sets)
    archs = args.arch or configs.names()
    shapes = args.shape or list(SHAPES)
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    failures = []
    for arch in archs:
        cfg = configs.get(arch)
        for shape in shapes:
            reason = cell_is_runnable(cfg, shape)
            if reason:
                print(f"SKIP {arch}/{shape}: {reason}")
                n_skip += 1
                continue
            for mesh_kind in meshes:
                path = artifact_path(arch, shape, mesh_kind, args.tag)
                if args.skip_existing and path.exists():
                    n_ok += 1
                    continue
                print(f"=== {arch}/{shape} [{mesh_kind}] tag={args.tag}",
                      flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_kind, opts, args.tag)
                    path.write_text(json.dumps(rec, indent=1))
                    print(f"  wrote {path} "
                          f"(lower {rec['timings_s']['lower']}s, "
                          f"compile {rec['timings_s']['compile']}s)",
                          flush=True)
                    n_ok += 1
                except Exception as e:        # noqa: BLE001
                    n_fail += 1
                    failures.append((arch, shape, mesh_kind, repr(e)))
                    traceback.print_exc()
                    if args.fail_fast:
                        raise
    print(f"\ndry-run summary: ok={n_ok} skip={n_skip} fail={n_fail}")
    for f in failures:
        print("  FAIL", *f)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
