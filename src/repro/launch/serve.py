"""Serving launcher: batched requests against a small model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --requests 6

Drives the continuous-batching Server either directly or as a managed job
through the ManagementPlane (``--driver``), mirroring the train launcher.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--driver", action="store_true")
    args = ap.parse_args()

    prompts = [[1 + (i % 7), 2, 3 + i % 5] + [4] * (i % 4)
               for i in range(args.requests)]

    if args.driver:
        from repro.core.plane import ManagementPlane
        from repro.runtime.local_plane import JaxLocalPlane
        plane = ManagementPlane()
        plane.add_cluster("master", is_master=True,
                          local_plane=JaxLocalPlane())
        plane.add_cluster("edge-0", local_plane=JaxLocalPlane())
        jid = plane.submit_job(
            "serve", arch=args.arch,
            payload={"arch": args.arch, "slots": args.slots,
                     "max_len": args.max_len,
                     "requests": [{"prompt": p, "max_new": args.max_new}
                                  for p in prompts]})
        ok = plane.run_until_done([jid], max_ticks=500)
        print("job:", plane.job_status(jid), "ok:", ok)
        return

    from repro.runtime.serve_loop import Server, ServeJobConfig
    server = Server(ServeJobConfig(arch=args.arch, slots=args.slots,
                                   max_len=args.max_len))
    for p in prompts:
        server.submit(p, max_new=args.max_new)
    done = server.run()
    for r in done:
        print(f"{r.req_id}: {r.prompt} -> {r.generated}")
    print(f"{len(done)} requests in {server.steps} decode steps "
          f"(batched slots={args.slots})")


if __name__ == "__main__":
    main()
