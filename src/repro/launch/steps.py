"""Jit-able train / prefill / decode steps + their shardings, per (arch x shape).

``build_cell`` is the single entry used by the dry-run, the roofline benches and the
hillclimb: it binds (ArchConfig, ShapeSpec, Mesh, CellOptions) to a jitted step with
explicit in/out shardings and returns everything needed to ``.lower()`` it with
ShapeDtypeStruct stand-ins (no device memory).

Step semantics per the assignment:
  * train_4k     -> train_step(state, batch)          fwd+bwd+AdamW, microbatched
  * prefill_32k  -> prefill_step(params, batch)       KV/state cache build
  * decode_32k   -> decode_step(params, tokens, cache) one token, cache donated
  * long_500k    -> decode_step (sub-quadratic archs only)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import base as configs
from repro.configs.base import ArchConfig
from repro.configs.shapes import SHAPES, ShapeSpec, cell_is_runnable, token_inputs
from repro.models.model import Model
from repro.optim.adamw import (AdamWConfig, abstract_opt_state, adamw_update,
                               init_opt_state, opt_state_specs)
from repro.parallel.sharding import MeshPlan, constrain

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class CellOptions:
    """Hillclimb knobs. Defaults = the paper-faithful baseline configuration."""
    fsdp: bool = True
    sp: bool = False                   # sequence-parallel residual stream
    bf16_reduce: bool = False          # bf16 partial-sum dots / TP all-reduces
    dp_only: bool = False              # batch over ALL axes, no TP (small models)
    moe_combine_reshard: bool = False  # a2a slot buffers before MoE combine
    titchener: bool = False            # lower the local-SGD round (train cells)
    num_microbatches: int = 0          # 0 = auto (see _auto_microbatches)
    remat: Optional[str] = None        # override ArchConfig.remat
    accum_dtype: str = "float32"
    capacity_factor: float = 0.0       # >0 overrides the MoE capacity factor
    loss_chunk: int = 0                # >0: chunked CE (see Model._chunked_ce)
    packed_decode: bool = False        # GQA decode attn w/o repeat/f32 copy
    zero2_accum: bool = False          # opt-sharded (pod-spread) grad accum
    donate: bool = True
    extra: Tuple[Tuple[str, Any], ...] = ()   # free-form knob ledger for §Perf


def _auto_microbatches(cfg: ArchConfig, spec: ShapeSpec) -> int:
    if spec.step != "train":
        return 1
    # keep per-device live activations ~O(layers x mb x seq x d_model / mesh)
    return 8 if spec.global_batch >= 64 else 1


# ------------------------------------------------------------------------- shardings
def batch_pspecs(plan: MeshPlan, cfg: ArchConfig,
                 inputs: Dict[str, jax.ShapeDtypeStruct]) -> Dict[str, P]:
    logical = {
        "tokens": ("batch", "seq"),
        "targets": ("batch", "seq"),
        "loss_mask": ("batch", "seq"),
        "frames": ("batch", None, None),
        "patches": ("batch", None, None),
    }
    return {k: plan.spec(logical[k], v.shape) for k, v in inputs.items()}


def named(mesh: Mesh, tree):
    return tmap(lambda s: NamedSharding(mesh, s), tree,
                is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------- train step
def make_train_step(model: Model, opt_cfg: AdamWConfig, num_microbatches: int,
                    zero2_accum: bool = False, accum_dtype: str = "float32"):
    """(state, batch) -> (state, metrics); grads accumulated over microbatches.

    ``zero2_accum`` shards the f32 grad accumulator like the OPTIMIZER state
    (ZeRO-2): with a pod axis, a param-spec accumulator is pod-REPLICATED, so
    every microbatch pays a pod (DCN) all-reduce; the opt-spec accumulator is
    pod-sharded, turning that into per-microbatch reduce-scatters and moving
    the grads exactly where adamw_update consumes them (§Perf cell 2 it.2).
    """
    plan, cfg = model.plan, model.cfg
    M = num_microbatches
    grad_fn = jax.value_and_grad(model.loss_fn, has_aux=True)

    def train_step(state: dict, batch: Dict[str, jax.Array]):
        params, opt = state["params"], state["opt"]
        if M <= 1:
            (_, metrics), grads = grad_fn(params, batch)
            grads = tmap(lambda g: g.astype(jnp.float32), grads)
        else:
            mb = tmap(lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                      batch)
            if zero2_accum:
                from repro.models.params import is_def, param_defs
                specs = tmap(lambda d: plan.opt_spec(d.logical, d.shape),
                             param_defs(cfg), is_leaf=is_def)
            else:
                specs = model.param_specs()
            acc_dt = jnp.dtype(accum_dtype)
            zeros = tmap(lambda p, s: jax.lax.with_sharding_constraint(
                jnp.zeros(p.shape, acc_dt), NamedSharding(plan.mesh, s)),
                params, specs)

            def acc(carry, b):
                g_acc, loss_acc, tok_acc = carry
                (_, m), g = grad_fn(params, b)
                g_acc = tmap(
                    lambda a, gi, s: jax.lax.with_sharding_constraint(
                        a + gi.astype(acc_dt),
                        NamedSharding(plan.mesh, s)),
                    g_acc, g, specs)
                return (g_acc, loss_acc + m["loss"], tok_acc + m["tokens"]), None

            (grads, loss_sum, tok_sum), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32),
                      jnp.zeros((), jnp.float32)), mb)
            grads = tmap(lambda g: g.astype(jnp.float32) / M, grads)
            metrics = {"loss": loss_sum / M, "tokens": tok_sum,
                       "aux_loss": jnp.zeros((), jnp.float32)}
        new_params, new_opt, opt_metrics = adamw_update(params, grads, opt,
                                                        opt_cfg)
        metrics = dict(metrics, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def train_state_specs(cfg: ArchConfig, plan: MeshPlan) -> dict:
    from repro.models.params import partition_specs
    return {"params": partition_specs(cfg, plan),
            "opt": opt_state_specs(cfg, plan)}


def abstract_train_state(cfg: ArchConfig) -> dict:
    from repro.models.params import abstract_params
    return {"params": abstract_params(cfg), "opt": abstract_opt_state(cfg)}


def init_train_state(model: Model, key) -> dict:
    params = model.init_params(key)
    return {"params": params, "opt": init_opt_state(params)}


# --------------------------------------------------------------------------- serving
def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params: dict, batch: Dict[str, jax.Array]):
        return model.prefill(params, batch, max_len=max_len)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params: dict, tokens: jax.Array, cache: dict):
        return model.decode_step(params, tokens, cache)
    return decode_step


# ------------------------------------------------------- Titchener local-SGD cell
def _build_titchener_cell(cfg, spec, mesh, plan, opts, opt_cfg) -> "Cell":
    """Lower one local-SGD ROUND (H pod-local AdamW steps + compressed pod-axis
    delta exchange) instead of one sync-DP step. Normalization for §Perf: the
    round consumes the same tokens as one baseline step (H x Bp x P x seq =
    global_batch x seq), so DCN bytes/round compare 1:1 with DCN bytes/step."""
    import jax.numpy as jnp
    from repro.models.params import (abstract_params, is_def, param_defs,
                                     partition_specs)
    from repro.optim.local_sgd import (LocalSGDConfig, make_round_fn,
                                       pod_free_plan)
    extra = dict(opts.extra)
    P_pods = mesh.shape.get("pod", 1)
    H = int(extra.get("inner_steps", 8))
    lcfg = LocalSGDConfig(inner_steps=H,
                          compress=bool(extra.get("compress", True)))
    pf = pod_free_plan(plan)
    model = Model(cfg, pf)
    round_fn = make_round_fn(model.loss_fn, opt_cfg, lcfg,
                             spmd_axis="pod" if P_pods > 1 else None,
                             mesh=mesh)

    params_abs = abstract_params(cfg)
    pspecs = partition_specs(cfg, pf)
    f32 = jnp.float32

    def stack_abs(t, dtype=None):
        return tmap(lambda a: jax.ShapeDtypeStruct(
            (P_pods,) + a.shape, dtype or a.dtype), t)

    def stack_spec(t):
        return tmap(lambda s: jax.sharding.PartitionSpec("pod", *s), t,
                    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    state_abs = {
        "pod_params": stack_abs(params_abs),
        "pod_opt": {"m": stack_abs(params_abs, f32),
                    "v": stack_abs(params_abs, f32),
                    "master": stack_abs(params_abs, f32),
                    "step": jax.ShapeDtypeStruct((P_pods,), jnp.int32)},
        "master": tmap(lambda a: jax.ShapeDtypeStruct(a.shape, f32),
                       params_abs),
        "momentum": tmap(lambda a: jax.ShapeDtypeStruct(a.shape, f32),
                         params_abs),
        "ef": stack_abs(params_abs, f32),
        "round": jax.ShapeDtypeStruct((), jnp.int32),
    }
    global_spec = tmap(lambda d: pf.spec(d.logical, d.shape),
                       param_defs(cfg), is_leaf=is_def)
    state_specs = {
        "pod_params": stack_spec(pspecs),
        "pod_opt": {"m": stack_spec(pspecs), "v": stack_spec(pspecs),
                    "master": stack_spec(pspecs),
                    "step": jax.sharding.PartitionSpec("pod")},
        "master": global_spec,
        "momentum": global_spec,
        "ef": stack_spec(pspecs),
        "round": jax.sharding.PartitionSpec(),
    }

    Bp = spec.global_batch // (P_pods * H)
    assert Bp >= 1, "global batch too small for H x pods"
    S = spec.seq_len
    batches_abs = {
        "tokens": jax.ShapeDtypeStruct((H, P_pods, Bp, S), jnp.int32),
        "targets": jax.ShapeDtypeStruct((H, P_pods, Bp, S), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((H, P_pods, Bp, S), jnp.bfloat16),
    }
    bspec = jax.sharding.PartitionSpec(None, "pod", "data")
    batch_specs = {k: bspec for k in batches_abs}

    in_sh = (named(mesh, state_specs), named(mesh, batch_specs))
    out_sh = (named(mesh, state_specs),
              {"delta_norm": jax.sharding.NamedSharding(
                  mesh, jax.sharding.PartitionSpec())})
    return Cell(cfg=cfg, spec=spec, mesh=mesh, plan=plan, model=model,
                opts=opts, fn=round_fn, abstract_args=(state_abs, batches_abs),
                in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=(0,) if opts.donate else ())


# ------------------------------------------------------------------------- the cell
@dataclasses.dataclass
class Cell:
    """Everything needed to lower / run one (arch x shape x mesh) combination."""
    cfg: ArchConfig
    spec: ShapeSpec
    mesh: Mesh
    plan: MeshPlan
    model: Model
    opts: CellOptions
    fn: Any                       # the step callable
    abstract_args: tuple          # ShapeDtypeStructs for .lower()
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple

    @property
    def name(self) -> str:
        return f"{self.cfg.name}/{self.spec.name}"

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.abstract_args)


def build_cell(arch: str, shape: str, mesh: Mesh,
               opts: CellOptions = CellOptions(),
               opt_cfg: AdamWConfig = AdamWConfig()) -> Cell:
    cfg = configs.get(arch) if isinstance(arch, str) else arch
    if opts.remat is not None:
        cfg = dataclasses.replace(cfg, remat=opts.remat)
    if opts.capacity_factor > 0:
        cfg = dataclasses.replace(cfg, capacity_factor=opts.capacity_factor)
    if opts.loss_chunk > 0:
        cfg = dataclasses.replace(cfg, loss_chunk=opts.loss_chunk)
    if opts.packed_decode:
        cfg = dataclasses.replace(cfg, packed_decode=True)
    spec = SHAPES[shape]
    skip = cell_is_runnable(cfg, shape)
    if skip:
        raise ValueError(f"cell {cfg.name}/{shape} not runnable: {skip}")
    from repro.parallel.sharding import DP_ONLY_RULES
    rules = DP_ONLY_RULES if opts.dp_only else None
    plan = MeshPlan(mesh=mesh, fsdp=opts.fsdp, sp=opts.sp,
                    bf16_reduce=opts.bf16_reduce,
                    moe_combine_reshard=opts.moe_combine_reshard, rules=rules)
    model = Model(cfg, plan)
    inputs = token_inputs(cfg, spec)
    in_pspecs = batch_pspecs(plan, cfg, inputs)
    B, S = spec.global_batch, spec.seq_len

    if opts.titchener and spec.step == "train":
        return _build_titchener_cell(cfg, spec, mesh, plan, opts, opt_cfg)

    if spec.step == "train":
        # dp_only shards batch over every mesh axis -> microbatching would
        # leave devices idle; run the full batch in one shot.
        M = 1 if opts.dp_only else (opts.num_microbatches
                                    or _auto_microbatches(cfg, spec))
        fn = make_train_step(model, opt_cfg, M, zero2_accum=opts.zero2_accum,
                             accum_dtype=opts.accum_dtype)
        st_specs = train_state_specs(cfg, plan)
        abstract = (abstract_train_state(cfg), inputs)
        in_sh = (named(mesh, st_specs), named(mesh, in_pspecs))
        out_sh = (named(mesh, st_specs),
                  tmap(lambda _: NamedSharding(mesh, P()),
                       {"loss": 0, "tokens": 0, "aux_loss": 0, "grad_norm": 0,
                        "lr": 0}))
        donate = (0,) if opts.donate else ()
    elif spec.step == "prefill":
        fn = make_prefill_step(model, max_len=S)
        from repro.models.params import abstract_params, partition_specs
        abstract = (abstract_params(cfg), inputs)
        in_sh = (named(mesh, partition_specs(cfg, plan)),
                 named(mesh, in_pspecs))
        cache_sh = named(mesh, model.cache_specs(B, S))
        logits_sh = NamedSharding(
            mesh, plan.spec(("batch", "vocab"), (B, cfg.vocab_size)))
        out_sh = (logits_sh, cache_sh)
        donate = ()
    else:  # decode
        fn = make_decode_step(model)
        from repro.models.params import abstract_params, partition_specs
        cache = model.abstract_cache(B, S)
        abstract = (abstract_params(cfg), inputs["tokens"], cache)
        cache_sh = named(mesh, model.cache_specs(B, S))
        in_sh = (named(mesh, partition_specs(cfg, plan)),
                 NamedSharding(mesh, in_pspecs["tokens"]), cache_sh)
        logits_sh = NamedSharding(
            mesh, plan.spec(("batch", "vocab"), (B, cfg.vocab_size)))
        out_sh = (logits_sh, cache_sh)
        donate = (2,) if opts.donate else ()

    return Cell(cfg=cfg, spec=spec, mesh=mesh, plan=plan, model=model,
                opts=opts, fn=fn, abstract_args=abstract, in_shardings=in_sh,
                out_shardings=out_sh, donate_argnums=donate)
