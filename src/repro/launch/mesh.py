"""Mesh construction for the production fleet and for CPU tests.

Everything is a FUNCTION (never module-level mesh state) so importing this module
never touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS while tests and benches must see the real single device.

Topology (TPU v5e target):
  * single pod  : (data=16, model=16) = 256 chips, all axes on ICI.
  * multi pod   : (pod=2, data=16, model=16) = 512 chips; the "pod" axis is DCN —
    the thin boundary of the paper. Sharding rules (repro.parallel.sharding) keep
    every per-layer collective off the pod axis; only batch parallelism (gradient
    reduction / Titchener local-sync deltas) crosses it.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

# hardware constants (TPU v5e) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (in-pod)
DCN_BW = 6.25e9                # bytes/s per host pair (cross-pod, ~50 Gbit)
CHIPS_PER_POD = 256


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Optional[Tuple[int, ...]] = None,
                   axes: Optional[Tuple[str, ...]] = None) -> Mesh:
    """Mesh over whatever devices exist (CPU tests: usually one device)."""
    n = jax.device_count()
    if shape is None:
        shape, axes = (1, n), ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def n_pods(mesh: Mesh) -> int:
    return mesh.shape.get("pod", 1)


def chips(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
