"""qwen3-0.6b [dense] — qk_norm, GQA (attn dim decoupled from d_model). [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    compliance_tags=("region:any", "onprem:ok"),
))
