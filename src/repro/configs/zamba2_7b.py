"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block every 6 layers.
[arXiv:2411.15242; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,             # 3584 / 32
    d_ff=14336,               # shared block MLP width
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    shared_block_every=6,
    max_context=1_048_576,
    compliance_tags=("region:any", "longctx:ok"),
))
