"""whisper-medium [audio] — enc-dec transformer backbone; conv frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (1500 frames). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,            # decoder layers
    encoder_layers=24,
    encoder_frames=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,              # 1024 / 16 (whisper uses d_model/heads)
    d_ff=4096,
    vocab_size=51_865,
    rope_theta=10_000.0,      # (whisper uses learned abs pos; we use RoPE — noted in DESIGN.md)
    compliance_tags=("region:any", "modality:audio"),
))
