from repro.configs.base import ArchConfig, get, names, register  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeSpec, cell_is_runnable, token_inputs  # noqa: F401
