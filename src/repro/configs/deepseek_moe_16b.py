"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,               # per-expert width (fine-grained)
    vocab_size=102_400,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    router_normalize=True,
    rope_theta=10_000.0,
    compliance_tags=("region:any",),
))
