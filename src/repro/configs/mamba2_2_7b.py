"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free. [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    max_context=1_048_576,
    compliance_tags=("region:any", "longctx:ok"),
))
