"""Assigned input-shape sets and ``input_specs()`` (ShapeDtypeStruct stand-ins).

Each LM shape pairs (seq_len, global_batch) with the step it lowers:
  * ``train_4k``     -> train_step   (forward+backward+optimizer update)
  * ``prefill_32k``  -> prefill_step (forward, KV-cache build, last-token logits)
  * ``decode_32k``   -> serve_step   (one new token against a seq_len KV cache)
  * ``long_500k``    -> serve_step   (sub-quadratic archs only; see ArchConfig.sub_quadratic)

No device memory is allocated here — everything is ``jax.ShapeDtypeStruct`` (the same
pattern the dry-run uses to prove the production mesh shards without hardware).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ArchConfig, shape: str) -> Optional[str]:
    """None if (arch, shape) is a valid dry-run cell, else a skip-reason string."""
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: 524k decode KV out of scope (DESIGN.md §5)"
    return None


def token_inputs(cfg: ArchConfig, spec: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs (token ids + stub-frontend embeddings where applicable)."""
    B, S = spec.global_batch, spec.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    if spec.step == "decode":
        out = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    else:
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if spec.step == "train":
        out["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        out["loss_mask"] = jax.ShapeDtypeStruct((B, S), bf16)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_frames, cfg.d_model), bf16)
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), bf16)
    return out
