"""Architecture configuration registry.

Every assigned architecture is a frozen ``ArchConfig``. The same config object drives:
  * model construction (``repro.models.model``),
  * sharding rules (``repro.parallel.sharding``),
  * the multi-pod dry-run (``repro.launch.dryrun``),
  * the management plane's routing metadata (``compliance_tags`` consumed by
    ``repro.core.dispatcher`` — the paper's "pre-defined service routing rule").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

Family = str  # dense | moe | ssm | hybrid | encdec | vlm


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention features
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None      # window size for local layers
    local_global_pattern: int = 0             # N => every (N+1)-th layer is global, rest local
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    router_normalize: bool = True
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2-style): shared attn+mlp block applied every k mamba layers
    shared_block_every: int = 0
    # enc-dec (whisper-style)
    encoder_layers: int = 0
    encoder_frames: int = 1500                # stub frontend: precomputed frame embeddings
    # vlm (llama-3.2-vision style): every k-th layer is cross-attn to patch embeddings
    cross_attn_every: int = 0
    num_patches: int = 1601                   # stub frontend: precomputed patch embeddings
    # training / numerics
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "full"                       # none | dots | full
    loss_chunk: int = 0                       # >0: chunked CE (never materialize
                                              # full [B,S,V] logits; §Perf lever)
    packed_decode: bool = False               # GQA decode attention without
                                              # repeat/f32 cache copy (§Perf)
    tie_embeddings: bool = False
    max_context: int = 131_072
    # management-plane metadata (Titchener routing rules)
    compliance_tags: Tuple[str, ...] = ()

    # ---- derived ----
    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (see DESIGN.md §5)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # local:global mixes bound most KV to the window; we run them (gemma3).
        return self.sliding_window is not None and self.local_global_pattern > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs autoregress (whisper is enc-dec)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D in the roofline)."""
        c, L, D = self, self.num_layers, self.d_model
        emb = c.vocab_size * D * (1 if c.tie_embeddings else 2)
        total = emb
        for i in range(L):
            total += self._layer_params(i)
        if c.family == "encdec":
            total += D  # encoder final norm
            for _ in range(c.encoder_layers):
                total += self._attn_params() + self._mlp_params(c.d_ff) + 2 * D
        if c.shared_block_every:
            total += self._attn_params() + self._mlp_params(c.d_ff) + 2 * D
        total += D  # final norm
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: shared + top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        c, D = self, self.d_model
        total = c.vocab_size * D * (1 if c.tie_embeddings else 2) + D
        per_layer = self._attn_params() + 2 * D
        per_layer += (c.num_shared_experts + c.top_k) * 3 * D * c.d_ff_expert
        per_layer += D * c.num_experts  # router (all experts scored)
        return total + c.num_layers * per_layer

    def _attn_params(self) -> int:
        c, D = self, self.d_model
        qkv = D * c.num_heads * c.head_dim + 2 * D * c.num_kv_heads * c.head_dim
        out = c.num_heads * c.head_dim * D
        qknorm = 2 * c.head_dim if c.qk_norm else 0
        return qkv + out + qknorm

    def _mlp_params(self, d_ff: int) -> int:
        return 3 * self.d_model * d_ff  # SwiGLU: gate, up, down

    def _ssm_params(self) -> int:
        c, D = self, self.d_model
        G = 1  # single B/C group
        in_proj = D * (2 * c.d_inner + 2 * G * c.ssm_state + c.ssm_heads)
        conv = c.ssm_conv_width * (c.d_inner + 2 * G * c.ssm_state)
        out_proj = c.d_inner * D
        extra = 3 * c.ssm_heads  # A_log, dt_bias, D skip
        return in_proj + conv + out_proj + extra + c.d_inner  # + gate-norm scale

    def _layer_params(self, i: int) -> int:
        c, D = self, self.d_model
        norms = 2 * D
        if c.family == "ssm":
            return c._ssm_params() + D
        if c.family == "hybrid":
            return c._ssm_params() + D  # shared block counted once in param_count
        if c.family == "moe":
            moe = D * c.num_experts  # router
            moe += (c.num_experts + c.num_shared_experts) * 3 * D * c.d_ff_expert
            return self._attn_params() + moe + norms
        if c.family == "vlm" and c.cross_attn_every and (i + 1) % c.cross_attn_every == 0:
            # cross layers REPLACE self-attn: xattn + mlp + 2 norms + gate scalar
            return self._attn_params() + self._mlp_params(c.d_ff) + norms + 1
        if c.family == "encdec":
            # decoder layer: self-attn + cross-attn + mlp + ln1/ln2/ln3
            return (2 * self._attn_params() + self._mlp_params(c.d_ff)
                    + norms + D)
        return self._attn_params() + self._mlp_params(c.d_ff) + norms

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests (full configs only ever dry-run)."""
        if self.local_global_pattern:
            n_layers = self.local_global_pattern + 1      # one full local:global group
        elif self.shared_block_every:
            n_layers = 6
        else:
            n_layers = min(self.num_layers, 4)
        return dataclasses.replace(
            self,
            num_layers=n_layers,
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            num_experts=min(self.num_experts, 8),
            top_k=min(self.top_k, 2),
            d_ff_expert=64 if self.num_experts else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=24 if self.encoder_layers else 1500,
            cross_attn_every=min(self.cross_attn_every, 2),
            num_patches=16 if self.cross_attn_every else 1601,
            sliding_window=64 if self.sliding_window else None,
            shared_block_every=3 if self.shared_block_every else 0,
            max_context=4096,
        )


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # import the per-arch modules exactly once
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        qwen3_32b, phi4_mini_3_8b, gemma3_12b, qwen3_0_6b, deepseek_moe_16b,
        qwen3_moe_235b_a22b, mamba2_2_7b, whisper_medium, zamba2_7b,
        llama32_vision_90b,
    )
