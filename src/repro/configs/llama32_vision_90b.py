"""llama-3.2-vision-90b [vlm] — every 5th layer cross-attends to image patch embeddings;
the vision tower is a STUB (``input_specs()`` provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    cross_attn_every=5,
    num_patches=1601,
    rope_theta=500_000.0,
    compliance_tags=("region:any", "modality:vision", "tier:flagship"),
))
