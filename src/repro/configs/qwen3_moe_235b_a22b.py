"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA kv=4. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,               # per-expert width
    vocab_size=151_936,
    qk_norm=True,
    num_experts=128,
    num_shared_experts=0,
    top_k=8,
    d_ff_expert=1536,
    router_normalize=True,
    rope_theta=1_000_000.0,
    compliance_tags=("region:any", "tier:flagship"),
))
