"""gemma3-12b [dense] — 5:1 local:global sliding-window mix, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

``local_global_pattern=5`` => every 6th layer is global attention, the other five use a
1024-token sliding window (gemma3 convention). head_dim is decoupled from d_model.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262_144,
    qk_norm=True,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    local_global_pattern=5,
    max_context=131_072,
    compliance_tags=("region:any",),
))
