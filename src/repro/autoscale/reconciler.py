"""The autoscaling control loop: diff desired vs. actual worker-pod fleets.

One :class:`Reconciler` runs beside the dispatcher on the master (global)
plane. Each ``reconcile()`` pass, per policy family:

  1. **Observe** — read the watch-materialized ``/queues/<name>`` depth view
     (``dispatcher.queue_depths()``, which takes the read barrier) and sum
     the ready backlog of the family's queues.
  2. **Sync inventory** — reconcile the in-memory pod table against the
     overwatch truth: a pod whose job lost its placement, moved clusters
     (recovery), terminated, or sits on a deregistered cluster is forgotten
     (its leased tasks are redelivered by broker lease expiry — at-least-once
     under failures, exactly-once under graceful operations).
  3. **Decide** — ``policy.desired_replicas(backlog, live)`` with the
     reconciler enforcing the per-direction cooldowns (cold starts from zero
     bypass the up-cooldown).
  4. **Act** — scale up by submitting worker-pod jobs through the
     dispatcher's depth-aware placement, restricted by a per-family routing
     rule to the clusters currently under quota (preferred clusters first,
     spilling over into the rest when the preferred tier is full); scale
     down by draining victims (spillover clusters first, newest pods first)
     through the worker drain protocol, then retiring their jobs.
  5. **Publish** — write the fleet state under ``/autoscale/<family>``
     whenever it changed, so operators (and tests) watch the trajectory the
     same way they watch any other overwatch directory.

The reconciler touches clusters only through the dispatcher (submit/retire)
and the composer (materializing/removing the local ``PipelineWorker``) —
never a cluster-direct RPC, keeping the paper's plane split intact.

Locality: every read in the loop — the depth view, cluster membership,
placements, statuses — is a watch-materialized dispatcher view, i.e.
master-LOCAL state maintained from the overwatch event stream; an inventory
sync never issues a cross-boundary round-trip. The published
``/autoscale/<family>`` state rides the replica fan-out (it is in
``REPLICA_PREFIXES``): remote observers READ fleet trajectories off their
cluster-local replica (``agent.fleet_states()``) and — the notify half —
SUBSCRIBE to them with :meth:`Reconciler.fleet_watch` / a ``ReplicaView``
over ``/autoscale/``, fed by the one shipped envelope per sweep; N observers
on a cluster cost the cross-boundary bytes of zero.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple

from repro.autoscale.policy import ScalingPolicy
from repro.core.dispatcher import RoutingRule
from repro.core.transport import DeliveryError, RingLog

WORKER_POD_STEPS = 10 ** 9      # a worker pod runs until retired, never "done"


@dataclasses.dataclass
class PodRecord:
    name: str
    family: str
    cluster: str
    job_id: str
    worker: object                      # the materialized PipelineWorker
    seq: int
    state: str = "running"              # running | draining | drained


class Reconciler:
    def __init__(self, composer, policies: Sequence[ScalingPolicy],
                 quotas: Optional[Dict[str, int]] = None,
                 preferred: Tuple[str, ...] = (),
                 default_quota: Optional[int] = None,
                 every: float = 1.0,
                 events_limit: Optional[int] = 10_000):
        self.composer = composer
        self.plane = composer.plane
        self.dispatcher = self.plane.dispatcher
        self.policies: Dict[str, ScalingPolicy] = {}
        for p in policies:
            if p.family in self.policies:
                raise ValueError(f"duplicate policy family {p.family!r}")
            self.policies[p.family] = p
        self.quotas = dict(quotas or {})
        self.default_quota = default_quota      # None = unlimited
        self.preferred = tuple(preferred)
        self.every = every
        self.pods: Dict[str, Dict[str, PodRecord]] = {
            f: {} for f in self.policies}
        # (clock, family, action, pod, cluster) — bounded like every other
        # long-lived log in the plane (fabric message_log, overwatch op_log)
        self.events: RingLog = RingLog(events_limit)
        self._seq = itertools.count(1)
        self._last_up: Dict[str, float] = {}
        self._last_down: Dict[str, float] = {}
        self._last_published: Dict[str, dict] = {}
        self._reconciled_at: Optional[float] = None
        # one routing rule per family: restricts worker-pod jobs to the
        # clusters currently under quota; the cluster list is rewritten in
        # place before every submit, so the dispatcher's depth-aware pick
        # chooses WITHIN the quota envelope
        self._rules: Dict[str, RoutingRule] = {}
        for family in self.policies:
            rule = RoutingRule(
                name=f"autoscale-{family}",
                match=lambda job, _f=family: (
                    job.get("tags", {}).get("family") == _f),
                clusters=[])
            self.dispatcher.add_rule(rule)
            self._rules[family] = rule

    # ------------------------------------------------------------ the main loop
    def reconcile(self, force: bool = False) -> None:
        """One pass over every family; rate-limited to ``every`` clock units
        (``force=True`` skips the cadence check — tests and drains)."""
        now = self.plane.fabric.clock
        if (not force and self._reconciled_at is not None
                and now - self._reconciled_at < self.every):
            return
        self._reconciled_at = now
        depths = self.dispatcher.queue_depths()
        for family in sorted(self.policies):
            self._reconcile_family(family, depths, now)
        # ONE AppSpec re-broadcast per pass, however many pods changed —
        # spawned workers only start ticking after this reconcile returns,
        # so deferring the DNS/ACL rebuild to here is safe and turns a
        # scale step of N from N broadcasts into one
        self.composer.flush_spec()

    def _reconcile_family(self, family: str, depths: Dict[str, dict],
                          now: float) -> None:
        policy = self.policies[family]
        self._sync_inventory(family, now)
        live = [r for r in self.pods[family].values() if r.state == "running"]
        current = len(live)
        backlog = sum(depths.get(q, {}).get("ready", 0)
                      for q in policy.queues)
        desired = policy.desired_replicas(backlog, current)
        blocked = None                   # None | "at_quota" | "unreachable"
        if desired > current:
            cold = current == 0
            last = self._last_up.get(family)
            if cold or last is None or now - last >= policy.up_cooldown:
                spawned = 0
                for _ in range(desired - current):
                    cluster, blocked = self._spawn(family, policy, now)
                    if cluster is None:
                        break
                    spawned += 1
                if spawned:
                    self._last_up[family] = now
        elif desired < current:
            last = self._last_down.get(family)
            if last is None or now - last >= policy.down_cooldown:
                for rec in self._pick_victims(live, current - desired):
                    self._drain(rec, now)
                self._last_down[family] = now
        self._publish(family, policy, desired, backlog, blocked, now)

    # ------------------------------------------------------------- inventory
    def _sync_inventory(self, family: str, now: float) -> None:
        """Reconcile the pod table against overwatch placements: the actual
        side of the desired-vs-actual diff is what the global plane can SEE,
        not what this process remembers."""
        clusters = self.dispatcher.clusters()
        for name, rec in list(self.pods[family].items()):
            if rec.state != "running":
                continue
            placement = self.dispatcher.placement_of(rec.job_id)
            status = self.dispatcher.job_status(rec.job_id)
            gone = (
                placement is None
                or placement["cluster"] != rec.cluster   # recovery moved it
                or rec.cluster not in clusters           # cluster dead
                or (status or {}).get("status") in ("failed", "done"))
            if not gone:
                continue
            # forget the pod: its leased tasks redeliver via broker lease
            # expiry. Retire the job unconditionally (idempotent): if
            # recovery re-placed it elsewhere that zombie is stopped, and
            # either way its /jobs records are tombstoned so lost pods never
            # leak store keys or resurrect via a healed agent's heartbeat
            if placement is not None:
                self.dispatcher.retire(rec.job_id)
            self.composer.remove_worker(rec.worker, broadcast=False)
            del self.pods[family][name]
            self.events.append((now, family, "lost", name, rec.cluster))

    def _quota(self, cluster: str) -> Optional[int]:
        return self.quotas.get(cluster, self.default_quota)

    def _pod_counts(self) -> Counter:
        counts: Counter = Counter()
        for fam in self.pods.values():
            for rec in fam.values():
                if rec.state == "running":
                    counts[rec.cluster] += 1
        return counts

    def _eligible_clusters(self, policy: ScalingPolicy) -> List[str]:
        """Capability-eligible clusters for this family's pods (no quotas)."""
        needs = set(policy.requires)
        return [c for c, info in self.dispatcher.clusters().items()
                if needs <= set(info.get("capabilities", ()))]

    def allowed_clusters(self, policy: ScalingPolicy) -> List[str]:
        """Clusters a new pod of this family may land on right now:
        capability-eligible AND under quota, preferred tier first — the
        spillover decision. Empty means every eligible cluster is at quota
        (or nothing is eligible at all — see ``_spawn``'s blocked reason)."""
        eligible = self._eligible_clusters(policy)
        counts = self._pod_counts()
        under = [c for c in eligible
                 if self._quota(c) is None or counts[c] < self._quota(c)]
        pref = [c for c in under if c in self.preferred]
        return sorted(pref or under)

    # ----------------------------------------------------------------- actions
    def _spawn(self, family: str, policy: ScalingPolicy,
               now: float) -> Tuple[Optional[str], Optional[str]]:
        """One pod: returns ``(cluster, None)`` on success, else
        ``(None, reason)`` with reason ``"at_quota"`` (every eligible cluster
        is at capacity) or ``"unreachable"`` (placement targets exist but
        none could be dispatched to — e.g. partitioned while still leased)."""
        allowed = self.allowed_clusters(policy)
        if not allowed:
            # distinguish "everything eligible is full" from "nothing is
            # eligible at all" (missing capability / no registered cluster)
            return None, ("at_quota" if self._eligible_clusters(policy)
                          else "no_eligible_cluster")
        seq = next(self._seq)
        name = f"wp-{family}-{seq}"
        tags = {"requires": list(policy.requires),
                "queues": list(policy.queues), "family": family}
        if policy.cost_class is not None:
            # the dispatcher's cost-class steering covers the cold start,
            # when the family's queues have no published depth yet
            tags["cost_class"] = policy.cost_class
        job = {"job_id": name, "kind": "worker-pod", "arch": "",
               "steps": WORKER_POD_STEPS, "tags": tags, "payload": {}}
        # Pick-then-dispatch so an unreachable cluster (partitioned while its
        # registration lease is still live) can be EXCLUDED and the pick
        # re-run over the survivors — a plain retry could re-pick the same
        # cluster forever when it uniquely wins the depth score. Gives up
        # gracefully (next pass retries; the lease sweep deregisters the
        # cluster within its TTL) rather than crash the composer tick.
        rule = self._rules[family]
        tried: set = set()
        cluster = None
        try:
            while cluster is None:
                avail = [c for c in allowed if c not in tried]
                if not avail:
                    self.events.append((now, family, "spawn_failed",
                                        name, None))
                    return None, "unreachable"
                rule.clusters[:] = avail
                picked = self.dispatcher.pick(job)
                if picked is None:
                    self.events.append((now, family, "spawn_failed",
                                        name, None))
                    return None, "unreachable"
                try:
                    self.dispatcher.dispatch_to(picked, job)
                    cluster = picked
                except DeliveryError:
                    tried.add(picked)
        finally:
            # leave the rule capability-wide, not frozen at this spawn's
            # quota snapshot: dispatcher recovery re-places a dead cluster's
            # worker-pod jobs through the same rule, and a stale one-cluster
            # list would park them as unplaceable
            rule.clusters[:] = sorted(self._eligible_clusters(policy))
        worker = self.composer.add_worker(name, cluster, queues=policy.queues,
                                          broadcast=False)
        self.pods[family][name] = PodRecord(
            name=name, family=family, cluster=cluster, job_id=name,
            worker=worker, seq=seq)
        self.events.append((now, family, "scale_up", name, cluster))
        return cluster, None

    def _pick_victims(self, live: List[PodRecord], n: int) -> List[PodRecord]:
        """Retreat from the spillover tier first (non-preferred clusters),
        newest pods first within a tier."""
        ranked = sorted(live, key=lambda r: (r.cluster in self.preferred,
                                             -r.seq))
        return ranked[:n]

    def _drain(self, rec: PodRecord, now: float) -> None:
        rec.state = "draining"
        worker = rec.worker

        def finished(_w, _rec=rec, _now=now):
            _rec.state = "drained"
            self.dispatcher.retire(_rec.job_id)
            self.composer.remove_worker(_rec.worker, broadcast=False)
            self.pods[_rec.family].pop(_rec.name, None)
            self.events.append((_now, _rec.family, "scale_down",
                                _rec.name, _rec.cluster))

        worker.on_drained = finished
        try:
            worker.drain()
        except DeliveryError:
            # the pod's cluster went unreachable mid-drain: the graceful path
            # is gone — any uncommitted leases redeliver on expiry (back to
            # at-least-once, like a worker death). Retire in absentia and
            # forget the pod instead of leaving it stuck in "draining".
            worker.on_drained = None
            worker.state = "drained"
            self.dispatcher.retire(rec.job_id)
            self.composer.remove_worker(worker, broadcast=False)
            self.pods[rec.family].pop(rec.name, None)
            self.events.append((now, rec.family, "lost",
                                rec.name, rec.cluster))

    # ---------------------------------------------------------- crash adoption
    def adopt(self, workers) -> int:
        """Rebuild the pod table after a master crash: overwatch placements
        (recovered from the WAL) are the only surviving truth about which
        worker-pod jobs existed, and the composer's surviving
        ``PipelineWorker`` objects are the pods themselves. Match them by pod
        name (``wp-<family>-<seq>``), resume the sequence counter past the
        highest adopted seq (never reuse a live pod's name), finish any drain
        the crash interrupted, and retire orphan placements whose worker is
        gone. Returns the number of pods adopted as running."""
        now = self.plane.fabric.clock
        by_pod = {w.pod: w for w in workers}
        adopted = 0
        max_seq = 0
        for jid, placement in sorted(self.dispatcher.placements().items()):
            job = placement.get("job", {})
            if job.get("kind") != "worker-pod":
                continue
            family = job.get("tags", {}).get("family")
            if family not in self.pods:
                continue
            try:
                seq = int(jid.rsplit("-", 1)[1])
            except (IndexError, ValueError):
                continue
            max_seq = max(max_seq, seq)
            worker = by_pod.get(jid)
            if worker is None or worker.state == "drained":
                # the pod is gone (or finished draining mid-crash, before its
                # retirement landed): tombstone the job records
                self.dispatcher.retire(jid)
                if worker is not None:
                    self.composer.remove_worker(worker, broadcast=False)
                continue
            rec = PodRecord(name=jid, family=family,
                            cluster=placement["cluster"], job_id=jid,
                            worker=worker, seq=seq)
            self.pods[family][jid] = rec
            if worker.state == "draining":
                # the recovery barrier already retried its pending commit;
                # re-arm the drain closure (the crash cleared it) and finish
                self._drain(rec, now)
            else:
                adopted += 1
                self.events.append((now, family, "adopted", jid,
                                    rec.cluster))
        self._seq = itertools.count(max_seq + 1)
        return adopted

    # ------------------------------------------------------------ observability
    def _publish(self, family: str, policy: ScalingPolicy, desired: int,
                 backlog: float, blocked: Optional[str], now: float) -> None:
        pods = self.pods[family]
        state = {
            "desired": desired,
            "replicas": sum(1 for r in pods.values() if r.state == "running"),
            "draining": sum(1 for r in pods.values() if r.state == "draining"),
            "backlog": backlog,
            # why the fleet is below desired, if it is: quota exhaustion vs.
            # unreachable placement targets — an operator diagnosing capacity
            # must not be steered at a connectivity fault (or vice versa)
            "blocked": blocked,
            "at_quota": blocked == "at_quota",
            "max_replicas": policy.max_replicas,
            "pods": {r.name: r.cluster for r in pods.values()},
        }
        if state == self._last_published.get(family):
            return                      # coalesce-friendly: only deltas
        self._last_published[family] = state
        self.plane.master_agent.ow.put(f"/autoscale/{family}",
                                       {**state, "clock": now})

    @staticmethod
    def fleet_watch(agent, family: str, cb):
        """Subscribe a remote fleet-state observer on ``agent``'s cluster:
        ``cb(event, key, value, rev)`` fires for every published change to
        ``/autoscale/<family>`` off the cluster-local replica feed — the
        observer never dials the master, and any number of observers share
        the one shipped envelope per sweep. Raises on a cluster without a
        replica (fan-out off): there is deliberately NO silent cross-boundary
        fallback for subscriptions, only for reads."""
        return agent.watch_local(f"/autoscale/{family}", cb)

    def replicas(self, family: str) -> int:
        return sum(1 for r in self.pods[family].values()
                   if r.state == "running")
