"""Per queue-family scaling policy: backlog -> desired replica count.

A *family* is one class of worker pod: the queues its workers consume, the
capability tags the pod itself carries, and the sizing envelope. The policy
is a pure function of (published ready backlog, current replicas) — all the
flap protection lives here, so the reconciler stays a mechanical diff loop:

  * ``target_depth_per_worker`` — the ready backlog one worker is sized to
    absorb; the raw desired count is ``ceil(backlog / target)``.
  * ``min_replicas`` / ``max_replicas`` — hard clamp (``min_replicas=0``
    enables scale-to-zero).
  * ``scale_up_step`` / ``scale_down_step`` — at most this many replicas
    added/retired per reconcile pass, so one burst never slews the fleet
    instantaneously.
  * ``up_threshold`` / ``down_threshold`` — the hysteresis band, expressed
    as multiples of the per-worker target: the fleet grows only once the
    per-worker backlog exceeds ``target * up_threshold`` and shrinks only
    once it falls below ``target * down_threshold``. Between the two bands
    the current size is sticky, so a backlog hovering near the target never
    flaps the fleet.
  * ``up_cooldown`` / ``down_cooldown`` — minimum fabric-clock spacing
    between consecutive scaling actions in each direction (enforced by the
    reconciler; a cold start from zero replicas bypasses the up-cooldown so
    a queue that just appeared is not left stranded).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ScalingPolicy:
    family: str
    queues: Tuple[str, ...] = ("default",)
    requires: Tuple[str, ...] = ()       # capability tags of the worker pod
    # roofline cost class this family serves (compute | memory | io): folds
    # the class's steering capability (repro.roofline.cost.CLASS_CAPS) into
    # ``requires``, so the family's pods only land on — and its spawn jobs
    # carry the cost_class tag for — the matching cluster tier. None keeps
    # the family tier-agnostic (byte-identical to the pre-cost plane).
    cost_class: "str | None" = None
    target_depth_per_worker: float = 8.0
    min_replicas: int = 0
    max_replicas: int = 8
    scale_up_step: int = 4
    scale_down_step: int = 1
    up_threshold: float = 1.25
    down_threshold: float = 0.5
    up_cooldown: float = 1.0
    down_cooldown: float = 3.0

    def __post_init__(self):
        if self.cost_class is not None:
            from repro.roofline.cost import steering_cap
            cap = steering_cap(self.cost_class)
            if cap is None:
                raise ValueError(f"family {self.family}: unknown cost class "
                                 f"{self.cost_class!r}")
            if cap not in self.requires:
                # frozen dataclass: fold the steering capability in here
                object.__setattr__(self, "requires", self.requires + (cap,))
        if not self.queues:
            raise ValueError(f"family {self.family}: needs at least one queue")
        if self.target_depth_per_worker <= 0:
            raise ValueError(f"family {self.family}: target depth must be > 0")
        if not (0 <= self.min_replicas <= self.max_replicas):
            raise ValueError(f"family {self.family}: need "
                             "0 <= min_replicas <= max_replicas")
        if self.scale_up_step < 1 or self.scale_down_step < 1:
            raise ValueError(f"family {self.family}: steps must be >= 1")
        if self.up_threshold < 1.0:
            raise ValueError(f"family {self.family}: up_threshold < 1 would "
                             "scale up below the per-worker target (flaps "
                             "against down_threshold)")
        if not 0.0 <= self.down_threshold <= 1.0:
            raise ValueError(f"family {self.family}: down_threshold must be "
                             "in [0, 1]")

    def desired_replicas(self, backlog: float, current: int) -> int:
        """The next fleet size for ``backlog`` ready tasks and ``current``
        live replicas — clamped, hysteresis-gated, and step-limited. The
        reconciler applies cooldowns on top."""
        target = self.target_depth_per_worker
        raw = math.ceil(backlog / target) if backlog > 0 else 0
        want = min(max(raw, self.min_replicas), self.max_replicas)
        if want > current:
            # up-hysteresis: an existing fleet only grows once the per-worker
            # backlog clears the upper band. It never gates the clamp edges:
            # a cold start (current == 0) has no per-worker backlog to
            # measure, and a fleet knocked below its min_replicas floor
            # (pods lost to a dead cluster) must recover regardless of how
            # quiet the backlog is — the floor is availability, not sizing.
            if (self.min_replicas <= current
                    and current > 0
                    and backlog <= current * target * self.up_threshold):
                return current
            return min(current + self.scale_up_step, want)
        if want < current:
            # an EMPTY backlog always permits shrinking (otherwise
            # down_threshold=0.0 — "only shrink when fully drained" — would
            # pin the fleet at its peak forever: 0 >= 0 holds)
            if backlog and backlog >= current * target * self.down_threshold:
                return current
            return max(current - self.scale_down_step, want)
        return current
