"""Elastic autoscaling plane: queue-depth-driven worker fleets.

The paper splits management between a GLOBAL plane (the master cluster's
overwatch + dispatcher, deciding *where* work runs across the hybrid fleet)
and LOCAL planes (each cluster's control agent + its own scheduler, deciding
*how* pods run inside one partition). This subsystem closes the loop between
them for pipeline worker fleets:

  * the data plane publishes per-queue backlog under ``/queues/<name>``
    (broker ``changed_depths`` -> composer sweep-cadence publisher) — a
    LOCAL-plane fact surfaced into the GLOBAL plane's watch-materialized
    views;
  * a :class:`~repro.autoscale.policy.ScalingPolicy` per queue family turns
    that backlog into a desired replica count (target ready-depth per worker,
    min/max bounds, step limits, hysteresis bands, cooldowns);
  * the :class:`~repro.autoscale.reconciler.Reconciler` — a GLOBAL-plane
    control loop beside the dispatcher — diffs desired vs. actual worker-pod
    inventory (reconciled against the overwatch ``/jobs/<id>/placement``
    records, published under ``/autoscale/<family>`` for observability) and
    submits or retires worker-pod jobs through the dispatcher's existing
    depth-aware placement (``tags={"queues": [...]}``);
  * scale-down is loss-free: each victim runs the worker drain protocol
    (stop pulling, execute + commit the in-flight batch, final ack, publish
    drained state), so no broker lease is left to expire and no task is
    redelivered or double-executed;
  * per-cluster capacity quotas with preferred-first placement make the
    paper's hybrid story mechanical: bursts fill the preferred (on-prem)
    clusters to quota, then SPILL OVER into eligible public-cloud clusters,
    and scale-down retreats from the spillover clusters first.

The LOCAL plane still executes: a spawned worker-pod job lands on some
cluster's control agent exactly like any dispatched job, and the pipeline
composer materializes the corresponding :class:`PipelineWorker` there — the
reconciler never talks to a cluster directly, only through the dispatcher
and the overwatch, preserving the paper's thin-boundary discipline.
"""
from repro.autoscale.policy import ScalingPolicy
from repro.autoscale.reconciler import PodRecord, Reconciler

__all__ = ["ScalingPolicy", "Reconciler", "PodRecord"]
