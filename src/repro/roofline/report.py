"""Roofline report: three terms per (arch x shape x mesh) from dry-run artifacts.

Conventions (see EXPERIMENTS.md §Roofline for the full methodology):
  * All HLO quantities are PER DEVICE (post-SPMD HLO is the per-partition
    program); hardware peaks are per chip, so terms divide directly.
  * compute term    = hlo_flops / 197e12           (TPU v5e bf16 peak)
  * memory term     = framework_hbm_bytes / 819e9  (HBM bw). Framework bytes
    exclude while-depth >= kernel_depth buffers — flash/SSD inner-loop tiles
    that live in VMEM under the Pallas TPU kernels, not HBM.
  * collective term = in_pod_bytes / 50e9 + cross_pod_bytes / 6.25e9
    (ICI link bw; DCN per-host bw for the pod axis).
  * MODEL_FLOPS     = useful flops per device per step:
      train   6*N*D    prefill  2*N*D    decode  2*N*B     (N = active params)
  * roofline_fraction (the §Perf score) = (MODEL_FLOPS/peak) / max(terms):
    the fraction of the step's bound time doing useful model math. Also reported:
    compute_fraction = compute_s / max(terms) (how compute-bound the cell is)
    and MODEL/HLO (remat + redundancy waste).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.launch.mesh import (CHIPS_PER_POD, DCN_BW, HBM_BW, ICI_BW,
                               PEAK_FLOPS_BF16)

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


@dataclasses.dataclass
class RooflineRow:
    cell: str
    mesh: str
    tag: str
    step: str
    chips: int
    hlo_flops: float
    model_flops: float
    framework_bytes: float
    kernel_bytes: float
    ici_bytes: float
    dcn_bytes: float
    mem_gb: float

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def model_compute_s(self) -> float:
        return self.model_flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.framework_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.ici_bytes / ICI_BW + self.dcn_bytes / DCN_BW

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s, 1e-12)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        return self.model_compute_s / self.bound_s

    @property
    def compute_fraction(self) -> float:
        return self.compute_s / self.bound_s

    @property
    def model_over_hlo(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1e-12)

    def advice(self) -> str:
        if self.dominant == "memory":
            return ("memory-bound: cast collectives/intermediates to bf16, "
                    "sequence-shard the residual (sp=true), raise arithmetic "
                    "intensity (fewer, larger per-device matmuls — less TP)")
        if self.dominant == "collective":
            big = "dcn" if self.dcn_bytes / DCN_BW > self.ici_bytes / ICI_BW \
                else "ici"
            if big == "dcn":
                return ("DCN-bound: amortize the pod boundary — Titchener "
                        "local-sync (H local steps + int8 delta) instead of "
                        "per-step gradient all-reduce")
            return ("ICI-bound: replace TP all-reduces with reduce-scatter + "
                    "all-gather (sp=true), bf16 collectives, overlap with "
                    "compute")
        return ("compute-bound: reduce remat recompute (remat=dots), larger "
                "microbatches; near roofline otherwise")


def kernel_depth_for(rec: dict) -> Optional[int]:
    step = rec["step"]
    if step == "decode":
        return None
    opts = rec.get("options", {})
    if opts.get("dp_only"):
        mb = 1                       # dp_only forces a single microbatch
    else:
        mb = opts.get("num_microbatches", 0) or (8 if step == "train" else 1)
    if step == "train" and mb > 1:
        return 3
    return 2


def model_flops_per_device(rec: dict) -> float:
    n = rec["active_params"]
    toks = rec["tokens_per_step"]
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[rec["step"]]
    return mult * n * toks / rec["chips"]


def row_from_artifact(rec: dict) -> RooflineRow:
    hs = rec["hlo_stats"]
    kd = kernel_depth_for(rec)
    by_depth = {int(k): v for k, v in hs.get("hbm_by_depth", {}).items()}
    if kd is None:
        fw = sum(by_depth.values())
        kern = 0.0
    else:
        fw = sum(v for d, v in by_depth.items() if d < kd)
        kern = sum(v for d, v in by_depth.items() if d >= kd)
    mm = rec.get("memory_analysis", {})
    mem_gb = (mm.get("argument_size_in_bytes", 0)
              + mm.get("temp_size_in_bytes", 0)
              + mm.get("output_size_in_bytes", 0)
              - mm.get("alias_size_in_bytes", 0)) / 1e9
    return RooflineRow(
        cell=rec["cell"], mesh=rec["mesh"], tag=rec.get("tag", "baseline"),
        step=rec["step"], chips=rec["chips"], hlo_flops=hs["flops"],
        model_flops=model_flops_per_device(rec),
        framework_bytes=fw, kernel_bytes=kern,
        ici_bytes=hs["in_pod_bytes"], dcn_bytes=hs["cross_pod_bytes"],
        mem_gb=mem_gb)


def load_rows(mesh: str = "single", tag: str = "baseline") -> List[RooflineRow]:
    rows = []
    d = ARTIFACTS / mesh
    if not d.exists():
        return rows
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag", "baseline") != tag:
            continue
        rows.append(row_from_artifact(rec))
    return rows


def markdown_table(rows: List[RooflineRow]) -> str:
    hdr = ("| cell | step | compute s | memory s | collective s | bound s | "
           "dominant | RF | CF | MODEL/HLO | mem GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in sorted(rows, key=lambda r: r.cell):
        out.append(
            f"| {r.cell} | {r.step} | {r.compute_s:.3f} | {r.memory_s:.3f} | "
            f"{r.collective_s:.3f} | {r.bound_s:.3f} | {r.dominant} | "
            f"{r.roofline_fraction:.2f} | {r.compute_fraction:.2f} | "
            f"{r.model_over_hlo:.2f} | {r.mem_gb:.1f} |\n")
    return "".join(out)


def to_json(rows: List[RooflineRow]) -> list:
    return [{**dataclasses.asdict(r),
             "compute_s": r.compute_s, "memory_s": r.memory_s,
             "collective_s": r.collective_s, "bound_s": r.bound_s,
             "dominant": r.dominant,
             "roofline_fraction": r.roofline_fraction,
             "compute_fraction": r.compute_fraction,
             "model_over_hlo": r.model_over_hlo,
             "advice": r.advice()} for r in rows]
