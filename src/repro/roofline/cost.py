"""Roofline cost vectors: the placement signal for workload-aware routing.

Every pipeline ``Task`` can be priced as a :class:`CostVector` — (flops,
hbm_bytes, collective_bytes, io_bytes) for one execution of the task. The
vector comes from, in order of preference:

  1. ``Task.cost`` — an explicit dict, e.g. loaded from a dry-run artifact
     (``roofline.hlo_stats.stats_to_json`` output) committed next to the DAG;
  2. ``payload["hlo_stats"]`` — the same artifact inlined in the payload
     (``flops`` / ``hbm_bytes`` / ``collective_bytes`` keys are lifted);
  3. an analytic estimate from the arch registry + payload shapes — the
     ``6·N·D`` / ``2·N·D`` MFU conventions the roofline report uses, with N
     from ``ArchConfig.param_count()`` and D (tokens) from the payload's
     (steps, global_batch, seq_len), optionally resolved through a named
     ``configs.shapes`` entry (``payload["shape"]``);
  4. nothing — tasks with no recognizable shape (custom ``python`` kinds)
     price as ``None`` and are never steered, which keeps cost-aware routing
     a strict no-op for them.

Classification is the standard roofline split: a task with no flops is
IO-bound; otherwise arithmetic intensity (flops / hbm_byte) above
``MACHINE_BALANCE`` is compute-bound, below is memory-bound. Both compute-
and memory-bound classes want the accelerator tier (HBM bandwidth lives
there too); IO-bound stages want the cheap tier. The class maps to a
*steering capability tag* (``ACCEL_CAP`` / ``CHEAP_IO_CAP``): clusters
advertise the tags in their capability profiles, and because queue names ARE
capability sets (``scheduler.queue_for``), appending the steering tag to a
task's requires routes it — through the existing broker queues, dispatcher
depth-aware placement, and autoscaler families — with no new wire protocol.

This module is import-light on purpose (no jax): the scheduler, dispatcher
and autoscaler price tasks on the control-plane hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# Steering capability tags clusters advertise in their profiles.
ACCEL_CAP = "accel"          # accelerator tier: high flops + HBM bandwidth
CHEAP_IO_CAP = "cheap-io"    # cheap tier: storage/network heavy, few flops

#: cost class -> capability tag of the tier that should host it
CLASS_CAPS = {"compute": ACCEL_CAP, "memory": ACCEL_CAP, "io": CHEAP_IO_CAP}

# Arithmetic-intensity split (flops per HBM byte) between the tiers: the
# machine balance of the CHEAP tier — work denser than this gains from the
# accelerator tier, sparser work is bandwidth/IO and gains nothing there.
MACHINE_BALANCE = 8.0

# Analytic-estimate conventions (documented in benchmarks/README.md):
# per optimizer step each parameter moves ~20 bytes of HBM traffic
# (bf16 weights+grads read/write + f32 m/v read/write), and a sync-mode
# data-parallel step all-reduces one bf16 gradient copy both ways.
HBM_BYTES_PER_PARAM_STEP = 20.0
COLLECTIVE_BYTES_PER_PARAM_STEP = 4.0


@dataclasses.dataclass(frozen=True)
class CostVector:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    io_bytes: float = 0.0

    @property
    def intensity(self) -> float:
        """Arithmetic intensity in flops per HBM byte."""
        return self.flops / max(self.hbm_bytes, 1.0)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def classify(cv: CostVector) -> str:
    """Roofline class of one task execution: compute | memory | io."""
    if cv.flops <= 0.0:
        return "io"
    return "compute" if cv.intensity >= MACHINE_BALANCE else "memory"


def steering_cap(cost_class: str) -> Optional[str]:
    """Capability tag of the tier that should host ``cost_class`` work."""
    return CLASS_CAPS.get(cost_class)


def _vector_from_artifact(artifact: dict) -> CostVector:
    """Lift a dry-run artifact (``stats_to_json`` payload or an explicit
    ``Task.cost`` dict) into a CostVector; unknown keys are ignored."""
    return CostVector(
        flops=float(artifact.get("flops", 0.0)),
        hbm_bytes=float(artifact.get("hbm_bytes", 0.0)),
        collective_bytes=float(artifact.get("collective_bytes", 0.0)),
        io_bytes=float(artifact.get("io_bytes", 0.0)))


def _shape_of(payload: dict) -> tuple:
    """(seq_len, global_batch) from the payload, resolving a named
    ``configs.shapes`` entry when given (the dry-run shape registry)."""
    seq_len = payload.get("seq_len")
    batch = payload.get("global_batch")
    name = payload.get("shape")
    if name and (seq_len is None or batch is None):
        from repro.configs.shapes import SHAPES   # lazy: shapes imports jax
        spec = SHAPES.get(name)
        if spec is not None:
            seq_len = seq_len if seq_len is not None else spec.seq_len
            batch = batch if batch is not None else spec.global_batch
    return int(seq_len or 64), int(batch or 8)


def _param_count(payload: dict) -> float:
    from repro.configs import base as configs
    cfg = configs.get(payload.get("arch", "qwen3-0.6b"))
    if payload.get("reduced", True):
        cfg = cfg.reduced()
    return float(cfg.param_count())


def _estimate(kind: str, payload: dict) -> Optional[CostVector]:
    """Analytic cost estimate for the built-in task kinds (None: unpriced)."""
    if kind in ("train", "eval"):
        n = _param_count(payload)
        seq_len, batch = _shape_of(payload)
        if kind == "train":
            steps = int(payload.get("steps", 50))
            tokens = float(steps) * batch * seq_len
            sync = payload.get("mode", "sync") == "sync"
            return CostVector(
                flops=6.0 * n * tokens,
                hbm_bytes=steps * n * HBM_BYTES_PER_PARAM_STEP,
                collective_bytes=(steps * n * COLLECTIVE_BYTES_PER_PARAM_STEP
                                  if sync else n))
        tokens = float(batch) * seq_len          # eval: one forward batch
        return CostVector(flops=2.0 * n * tokens,
                          hbm_bytes=n * HBM_BYTES_PER_PARAM_STEP)
    if kind == "serve":
        n = _param_count(payload)
        slots = int(payload.get("slots", 4))
        new = int(payload.get("max_new", 16)) * max(
            int(payload.get("n_requests", slots)), 1)
        # decode reads the full weight set per generated token position:
        # intensity ≈ batch slots, the canonical memory-bound regime
        return CostVector(flops=2.0 * n * new * slots,
                          hbm_bytes=2.0 * n * new)
    if kind == "etl":
        seq_len, batch = _shape_of(payload)
        rows = int(payload.get("batches", 2)) * batch * seq_len
        return CostVector(io_bytes=4.0 * rows)
    if kind == "export":
        return CostVector(io_bytes=2.0 * _param_count(payload))
    return None


def task_cost(task) -> Optional[CostVector]:
    """Price a pipeline ``Task`` (duck-typed: needs .kind/.payload and an
    optional .cost). None means "no cost signal" — never steered."""
    explicit = getattr(task, "cost", None)
    if explicit:
        return _vector_from_artifact(explicit)
    payload = task.payload or {}
    if isinstance(payload.get("hlo_stats"), dict):
        return _vector_from_artifact(payload["hlo_stats"])
    try:
        return _estimate(task.kind, payload)
    except KeyError:                      # unknown arch: unpriced, unsteered
        return None


def steering_tag(task) -> Optional[str]:
    """The steering capability tag for a task, or None when it has no cost
    signal (cost-aware routing must be a no-op for unpriced tasks)."""
    cv = task_cost(task)
    if cv is None:
        return None
    return steering_cap(classify(cv))
