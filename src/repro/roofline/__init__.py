"""Roofline analysis: post-optimization HLO accounting + three-term roofline."""
