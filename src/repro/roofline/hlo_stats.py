"""Post-optimization HLO accounting (the dry-run "profiler").

``xla`` device-less cost analysis visits each ``while`` body ONCE, so scanned layer
stacks under-count FLOPs/bytes by a factor of the trip count (verified empirically —
see EXPERIMENTS.md §Roofline methodology). This module re-derives the three roofline
inputs from ``compiled.as_text()`` with proper loop multiplication:

  * flops             — dot products (2 * result_elems * contraction), x trip counts.
  * hbm_bytes         — per top-level instruction: result + unique operand bytes.
                        Fusion-internal buffers are excluded (they live in
                        registers/VMEM, not HBM) — post-fusion HLO boundaries are the
                        closest static proxy for real HBM traffic.
  * collectives       — operand bytes of every all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute, x trip
                        counts, classified in-pod (ICI) vs cross-pod (DCN) from
                        replica groups (pod = device_id // pod_size).

Conventions (documented for the §Roofline report):
  * All numbers are PER DEVICE — post-SPMD HLO is the per-partition program.
  * Elementwise/reduce flops are ignored (dots dominate; matches MFU convention).
  * ``to_apply`` reducer bodies are ignored (O(1) work per application).
  * Branches of conditionals contribute their max.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(dtype: str, dims_s: str) -> Tuple[int, int]:
    """(bytes, elems) for one dtype[dims] string."""
    elems = 1
    for d in dims_s.split(","):
        if d:
            elems *= int(d)
    return elems * DTYPE_BYTES.get(dtype, 4), elems


def _type_bytes(type_str: str) -> int:
    """Total bytes for a (possibly tuple) HLO type string."""
    return sum(_shape_bytes(m.group(1), m.group(2))[0]
               for m in _SHAPE_RE.finditer(type_str))


def _first_shape(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _balanced(s: str, start: int) -> int:
    """Index just past the paren that closes s[start] == '('."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    type_str: str
    operand_str: str
    attrs: str

    @property
    def result_bytes(self) -> int:
        return _type_bytes(self.type_str)

    def operand_names(self) -> List[str]:
        return [m.group(1) for m in _NAME_RE.finditer(self.operand_str)]

    def called(self) -> List[Tuple[str, str]]:
        out = []
        for kind, attr in (("while_cond", "condition"), ("while_body", "body"),
                           ("fusion", "calls"), ("call", "to_apply")):
            m = re.search(attr + r"=%([\w\.\-]+)", self.attrs)
            if m:
                k = "reducer" if (attr == "to_apply" and
                                  self.opcode not in ("call", "custom-call")) else kind
                out.append((k, m.group(1)))
        m = re.search(r"branch_computations=\{([^}]*)\}", self.attrs)
        if m:
            for name in _NAME_RE.finditer(m.group(1)):
                out.append(("branch", name.group(1)))
        return out

    def trip_count(self) -> int:
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', self.attrs)
        return int(m.group(1)) if m else 1

    def op_name(self) -> str:
        m = re.search(r'op_name="([^"]*)"', self.attrs)
        return m.group(1) if m else ""


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


# opcodes that move no HBM bytes of their own (bodies/consumers account for them)
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "domain",
             "opt-barrier", "while", "call", "conditional", "copy-done"}


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    """Parse HLO text -> ({name: Computation}, entry_name)."""
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        if line.endswith("{") and ("= " not in line.split("(")[0]):
            # computation header: [ENTRY] %name (params) -> type {
            is_entry = line.startswith("ENTRY")
            m = _NAME_RE.search(line)
            if m:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
                if is_entry:
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        body = line[5:] if line.startswith("ROOT ") else line
        if not body.startswith("%"):
            continue
        eq = body.find(" = ")
        if eq < 0:
            continue
        name = body[1:eq]
        rhs = body[eq + 3:]
        # type: balanced parens if tuple, else up to first space
        if rhs.startswith("("):
            t_end = _balanced(rhs, 0)
        else:
            t_end = rhs.find(" ")
            if t_end < 0:
                continue
        type_str = rhs[:t_end]
        rest = rhs[t_end:].lstrip()
        p = rest.find("(")
        if p < 0:
            continue
        opcode = rest[:p].strip()
        op_end = _balanced(rest, p)
        operand_str = rest[p + 1:op_end - 1]
        attrs = rest[op_end:]
        cur.instrs.append(Instr(name, opcode, type_str, operand_str, attrs))
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


# ------------------------------------------------------------------ replica groups
def _iota_groups(spec: str) -> Optional[List[List[int]]]:
    """Parse iota replica-group list: [G,S]<=[d0,d1,...]T(p0,p1,...) | [G,S]<=[N]."""
    m = re.match(r"\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", spec)
    if not m:
        return None
    g, s = int(m.group(1)), int(m.group(2))
    dims = [int(d) for d in m.group(3).split(",")]
    total = 1
    for d in dims:
        total *= d
    ids = list(range(total))
    if m.group(4):
        perm = [int(p) for p in m.group(4).split(",")]
        # reshape ids to dims, transpose by perm, flatten
        strides = [0] * len(dims)
        acc = 1
        for i in range(len(dims) - 1, -1, -1):
            strides[i] = acc
            acc *= dims[i]
        new_dims = [dims[p] for p in perm]
        out = []

        def rec(prefix):
            if len(prefix) == len(new_dims):
                idx = sum(prefix[i] * strides[perm[i]] for i in range(len(perm)))
                out.append(ids[idx])
                return
            for v in range(new_dims[len(prefix)]):
                rec(prefix + [v])

        rec([])
        ids = out
    return [ids[i * s:(i + 1) * s] for i in range(g)]


def _explicit_groups(spec: str) -> List[List[int]]:
    return [[int(x) for x in grp.split(",") if x]
            for grp in re.findall(r"\{([\d,]*)\}", spec)]


def groups_cross_pod(attrs: str, pod_size: int, n_devices: int) -> bool:
    """True if any replica group (or permute pair) spans a pod boundary."""
    if pod_size <= 0 or pod_size >= n_devices:
        return False
    m = re.search(r"source_target_pairs=\{([^=]*?)\}\}", attrs)
    if m:
        pairs = _explicit_groups("{" + m.group(1) + "}}")
        return any(len(p) == 2 and p[0] // pod_size != p[1] // pod_size
                   for p in pairs)
    m = re.search(r"replica_groups=(\[\d+,\d+\]<=\[[\d,]+\](?:T\([\d,]+\))?)", attrs)
    groups = _iota_groups(m.group(1)) if m else None
    if groups is None:
        m = re.search(r"replica_groups=\{(\{[\d,]*\}(?:,\{[\d,]*\})*)\}", attrs)
        if not m:
            return False
        groups = _explicit_groups(m.group(1))
    for g in groups:
        pods = {d // pod_size for d in g}
        if len(pods) > 1:
            return True
    return False


# ----------------------------------------------------------------------- accounting
@dataclasses.dataclass
class CollectiveRecord:
    opcode: str
    bytes: int          # operand bytes x executions
    cross_pod: bool
    op_name: str
    count: int


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: List[CollectiveRecord] = dataclasses.field(default_factory=list)
    # bytes keyed by while-nesting depth relative to the entry computation.
    # Deep loops (flash kv-block loop, SSD chunk loop) are kernel-internal tiles
    # that live in VMEM under the Pallas TPU kernels; report.py splits on this.
    hbm_by_depth: Dict[int, float] = dataclasses.field(default_factory=dict)

    def scaled(self, k: int, shift: int = 0) -> "HloStats":
        return HloStats(self.flops * k, self.hbm_bytes * k,
                        [dataclasses.replace(c, bytes=c.bytes * k,
                                             count=c.count * k)
                         for c in self.collectives],
                        {d + shift: b * k for d, b in self.hbm_by_depth.items()})

    def __iadd__(self, o: "HloStats") -> "HloStats":
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.collectives.extend(o.collectives)
        for d, b in o.hbm_by_depth.items():
            self.hbm_by_depth[d] = self.hbm_by_depth.get(d, 0.0) + b
        return self

    @property
    def collective_bytes(self) -> int:
        return sum(c.bytes for c in self.collectives)

    @property
    def cross_pod_bytes(self) -> int:
        return sum(c.bytes for c in self.collectives if c.cross_pod)

    @property
    def in_pod_bytes(self) -> int:
        return sum(c.bytes for c in self.collectives if not c.cross_pod)

    def by_opcode(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for c in self.collectives:
            key = c.opcode + (":dcn" if c.cross_pod else ":ici")
            out[key] = out.get(key, 0) + c.bytes
        return out

    def top_collectives(self, n: int = 12) -> List[dict]:
        merged: Dict[Tuple[str, str, bool], Tuple[int, int]] = {}
        for c in self.collectives:
            k = (c.opcode, c.op_name, c.cross_pod)
            b, cnt = merged.get(k, (0, 0))
            merged[k] = (b + c.bytes, cnt + c.count)
        rows = [{"opcode": k[0], "op_name": k[1][:120],
                 "link": "dcn" if k[2] else "ici", "bytes": v[0], "count": v[1]}
                for k, v in merged.items()]
        rows.sort(key=lambda r: -r["bytes"])
        return rows[:n]


# ops through which a fusion parameter is consumed lazily (per needed element)
_PASSTHROUGH = {"bitcast", "copy", "reshape", "convert", "transpose"}
_SLICING = {"dynamic-slice", "slice", "gather"}


def _fusion_param_usage(body: "Computation"):
    """Per-parameter read accounting inside a fusion computation.

    Fusions compute lazily per output element, so a parameter consumed ONLY
    through a (dynamic-)slice/gather is read only window-sized — critical for
    scan bodies, where consumers fuse the dynamic-slice of the full stacked
    [L, ...] weight/residual tensors (charging the stack per layer would
    over-count O(L) per iteration, O(L^2) per step).

    Returns (usage: {param_idx: bytes | "full"}, aliased: set of param_idx that
    are in-place DUS targets, dus_bytes: 2x update bytes total).
    """
    local = {i.name: i.type_str for i in body.instrs}
    src: Dict[str, int] = {}
    for i in body.instrs:
        if i.opcode == "parameter":
            tail = i.operand_str.strip()
            if tail.isdigit():
                src[i.name] = int(tail)
        elif i.opcode in _PASSTHROUGH:
            ops = i.operand_names()
            if len(ops) == 1 and ops[0] in src:
                src[i.name] = src[ops[0]]

    usage: Dict[int, object] = {}
    aliased: set = set()
    dus_bytes = 0.0
    for i in body.instrs:
        if i.opcode in ("parameter",) or i.opcode in _PASSTHROUGH:
            continue
        for j, op in enumerate(i.operand_names()):
            idx = src.get(op)
            if idx is None:
                continue
            if i.opcode in _SLICING and j == 0:
                prev = usage.get(idx, 0.0)
                if prev != "full":
                    usage[idx] = prev + _type_bytes(i.type_str)
            elif i.opcode == "dynamic-update-slice" and j == 0:
                aliased.add(idx)
            else:
                usage[idx] = "full"
        if i.opcode == "dynamic-update-slice":
            ops = i.operand_names()
            if len(ops) >= 2 and ops[1] in local:
                dus_bytes += 2.0 * _type_bytes(local[ops[1]])
    return usage, aliased, dus_bytes


def _instr_bytes(ins: Instr, shapes: Dict[str, str],
                 comps: Dict[str, "Computation"]) -> float:
    """HBM traffic of one top-level instruction.

    Slice-like ops move only the window (XLA cost-analysis convention); in-place
    dynamic-update-slice (incl. inside fusions — scan-stacked outputs, KV-cache
    writes) moves 2x the update, not the full carried buffer; fusion parameters
    consumed only through slices are charged window-sized (see
    _fusion_param_usage).
    """
    ops = ins.operand_names()

    def op_bytes(i: int) -> int:
        return _type_bytes(shapes[ops[i]]) if i < len(ops) and ops[i] in shapes \
            else 0

    if ins.opcode in ("dynamic-slice", "slice", "gather"):
        return 2.0 * ins.result_bytes
    if ins.opcode == "dynamic-update-slice":
        return 2.0 * op_bytes(1)
    if ins.opcode == "scatter":
        return 2.0 * op_bytes(2) + op_bytes(1)

    if ins.opcode == "fusion":
        called = [c for k, c in ins.called() if k == "fusion"]
        if called and called[0] in comps:
            usage, aliased, dus_bytes = _fusion_param_usage(comps[called[0]])
            charge = dus_bytes
            if not aliased:
                charge += float(ins.result_bytes)
            seen = set()
            for k, op in enumerate(ops):
                if op not in shapes or op in seen:
                    continue
                seen.add(op)
                if k in aliased:
                    continue                      # in-place DUS target
                u = usage.get(k, "full")
                charge += _type_bytes(shapes[op]) if u == "full" else u
            return charge

    default = float(ins.result_bytes)
    seen = set()
    for op in ops:
        if op in shapes and op not in seen:
            default += _type_bytes(shapes[op])
            seen.add(op)
    return default


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    res = _first_shape(ins.type_str)
    if res is None:
        return 0.0
    _, rdims = res
    relems = 1
    for d in rdims:
        relems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    ops = ins.operand_names()
    contr = 1
    if ops and ops[0] in shapes:
        lhs = _first_shape(shapes[ops[0]])
        if lhs:
            for c in cdims:
                if c < len(lhs[1]):
                    contr *= lhs[1][c]
    return 2.0 * relems * contr


def module_stats(text: str, *, pod_size: int = 0,
                 n_devices: int = 1) -> HloStats:
    """Aggregate stats for the entry computation, loops multiplied out."""
    comps, entry = parse_module(text)
    shapes: Dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            shapes[ins.name] = ins.type_str

    memo: Dict[Tuple[str, bool], HloStats] = {}

    def visit(name: str, in_fusion: bool) -> HloStats:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        memo[key] = HloStats()  # cycle guard (HLO has none, but be safe)
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        st = HloStats()
        for ins in comp.instrs:
            if ins.opcode == "dot" or ins.opcode == "convolution":
                st.flops += _dot_flops(ins, shapes)
            if not in_fusion and ins.opcode not in _FREE_OPS:
                b = _instr_bytes(ins, shapes, comps)
                st.hbm_bytes += b
                st.hbm_by_depth[0] = st.hbm_by_depth.get(0, 0.0) + b
            base = ins.opcode.replace("-start", "")
            if base in COLLECTIVE_OPS and not ins.opcode.endswith("-done"):
                ob = sum(_type_bytes(shapes[op]) for op in ins.operand_names()
                         if op in shapes)
                st.collectives.append(CollectiveRecord(
                    base, ob, groups_cross_pod(ins.attrs, pod_size, n_devices),
                    ins.op_name(), 1))
            branches: List[HloStats] = []
            for kind, cname in ins.called():
                if kind == "reducer":
                    continue
                sub = visit(cname, in_fusion or kind == "fusion")
                if kind in ("while_body", "while_cond"):
                    st += sub.scaled(ins.trip_count(), shift=1)
                elif kind == "branch":
                    branches.append(sub)
                else:
                    st += sub.scaled(1)
            if branches:
                st += max(branches, key=lambda s: s.flops + s.hbm_bytes)
        memo[key] = st
        return st

    return visit(entry, False)


def stats_to_json(st: HloStats) -> dict:
    return {
        "flops": st.flops,
        "hbm_bytes": st.hbm_bytes,
        "hbm_by_depth": {str(k): v for k, v in sorted(st.hbm_by_depth.items())},
        "collective_bytes": st.collective_bytes,
        "cross_pod_bytes": st.cross_pod_bytes,
        "in_pod_bytes": st.in_pod_bytes,
        "by_opcode": st.by_opcode(),
        "top_collectives": st.top_collectives(),
    }


if __name__ == "__main__":
    import sys
    print(json.dumps(stats_to_json(module_stats(open(sys.argv[1]).read(),
                                                pod_size=256, n_devices=512)),
                     indent=2))
