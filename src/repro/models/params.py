"""Parameter definition trees.

Every parameter is declared once as a ``ParamDef(shape, logical, init_scale)`` leaf in a
nested dict; the same tree drives
  * ``init_params``      — materialize arrays (smoke tests, real training),
  * ``abstract_params``  — ShapeDtypeStructs (multi-pod dry-run, no allocation),
  * ``partition_specs``  — logical axes -> PartitionSpec via the MeshPlan rules.

Repeated layer stacks carry a leading "layers" dimension and are consumed by
``jax.lax.scan`` (compact HLO for 64–100 layer models; see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import MeshPlan


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _stack(defs: dict, n: int) -> dict:
    """Prefix every ParamDef with a scanned 'layers' dimension of size n."""
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.logical, d.init, d.scale),
        defs, is_leaf=is_def)


def attn_defs(cfg: ArchConfig, cross: bool = False) -> dict:
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    d = {
        "wq": ParamDef((D, H, hd), ("embed", "heads", "qk_depth")),
        "wk": ParamDef((D, K, hd), ("embed", "kv_heads", "qk_depth")),
        "wv": ParamDef((D, K, hd), ("embed", "kv_heads", "qk_depth")),
        "wo": ParamDef((H, hd, D), ("heads", "qk_depth", "embed")),
    }
    if cfg.qk_norm and not cross:
        d["q_norm"] = ParamDef((hd,), (None,), "ones")
        d["k_norm"] = ParamDef((hd,), (None,), "ones")
    return d


def mlp_defs(cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": ParamDef((D, F), ("embed", "ffn")),
        "w_up": ParamDef((D, F), ("embed", "ffn")),
        "w_down": ParamDef((F, D), ("ffn", "embed")),
    }


def moe_defs(cfg: ArchConfig) -> dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    d = {
        "router": ParamDef((D, E), ("embed_nofsdp", "experts")),
        "we_gate": ParamDef((E, D, F), ("experts", "embed", "ffn_nofsdp")),
        "we_up": ParamDef((E, D, F), ("experts", "embed", "ffn_nofsdp")),
        "we_down": ParamDef((E, F, D), ("experts", "ffn_nofsdp", "embed")),
    }
    if cfg.num_shared_experts:
        d["shared"] = mlp_defs(cfg, cfg.num_shared_experts * cfg.d_ff_expert)
    return d


def ssm_defs(cfg: ArchConfig) -> dict:
    D, DI, N, Hs, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                       cfg.ssm_conv_width)
    return {
        "w_z": ParamDef((D, DI), ("embed", "ffn")),
        "w_x": ParamDef((D, DI), ("embed", "ffn")),
        "w_b": ParamDef((D, N), ("embed", None)),
        "w_c": ParamDef((D, N), ("embed", None)),
        "w_dt": ParamDef((D, Hs), ("embed", "ssm_heads")),
        "conv_x": ParamDef((W, DI), ("conv", "ffn")),
        "conv_b": ParamDef((W, N), ("conv", None)),
        "conv_c": ParamDef((W, N), ("conv", None)),
        "a_log": ParamDef((Hs,), ("ssm_heads",), "ssm_a"),
        "dt_bias": ParamDef((Hs,), ("ssm_heads",), "ssm_dt"),
        "d_skip": ParamDef((Hs,), ("ssm_heads",), "ones"),
        "gate_norm": ParamDef((DI,), ("ffn",), "ones"),
        "out_proj": ParamDef((DI, D), ("ffn", "embed")),
    }


def norm_def(cfg: ArchConfig) -> ParamDef:
    return ParamDef((cfg.d_model,), (None,), "ones")


def _decoder_layer_defs(cfg: ArchConfig) -> dict:
    """One repeated decoder layer (self-attn or ssm [+ moe]) for the scanned stack."""
    if cfg.family in ("ssm", "hybrid"):
        return {"ssm": ssm_defs(cfg), "ln1": norm_def(cfg)}
    d = {"attn": attn_defs(cfg), "ln1": norm_def(cfg), "ln2": norm_def(cfg)}
    if cfg.family == "moe":
        d["moe"] = moe_defs(cfg)
    else:
        d["mlp"] = mlp_defs(cfg)
    return d


def param_defs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    tree = {
        "embed": ParamDef((cfg.vocab_size, D), ("vocab", "embed"), "normal", 1.0),
        "final_norm": norm_def(cfg),
    }
    if not cfg.tie_embeddings:
        tree["unembed"] = ParamDef((D, cfg.vocab_size), ("embed", "vocab"))

    if cfg.family == "vlm":
        n_cross = cfg.num_layers // cfg.cross_attn_every
        n_self = cfg.num_layers - n_cross
        group = cfg.cross_attn_every - 1
        assert n_self == n_cross * group, "num_layers must tile into (self*,cross) groups"
        self_layer = {"attn": attn_defs(cfg), "mlp": mlp_defs(cfg),
                      "ln1": norm_def(cfg), "ln2": norm_def(cfg)}
        cross_layer = {"xattn": attn_defs(cfg, cross=True), "mlp": mlp_defs(cfg),
                       "ln1": norm_def(cfg), "ln2": norm_def(cfg),
                       "gate": ParamDef((), (), "zeros")}
        tree["self_layers"] = _stack(_stack(self_layer, group), n_cross)
        tree["cross_layers"] = _stack(cross_layer, n_cross)
        return tree

    tree["layers"] = _stack(_decoder_layer_defs(cfg), cfg.num_layers)

    if cfg.family == "hybrid":
        tree["shared_block"] = {"attn": attn_defs(cfg), "mlp": mlp_defs(cfg),
                                "ln1": norm_def(cfg), "ln2": norm_def(cfg)}
    if cfg.family == "encdec":
        enc_layer = {"attn": attn_defs(cfg), "mlp": mlp_defs(cfg),
                     "ln1": norm_def(cfg), "ln2": norm_def(cfg)}
        tree["enc_layers"] = _stack(enc_layer, cfg.encoder_layers)
        tree["enc_norm"] = norm_def(cfg)
        # decoder self layers get a cross-attn block
        dec = tree["layers"]
        dec["xattn"] = _stack(attn_defs(cfg, cross=True), cfg.num_layers)
        dec["ln3"] = _stack({"n": norm_def(cfg)}, cfg.num_layers)["n"]
    return tree


# ------------------------------------------------------------------ materialization
def _init_leaf(d: ParamDef, key, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "ssm_a":       # A in [-1, -0.5]
        u = jax.random.uniform(key, d.shape, jnp.float32, 0.5, 1.0)
        return jnp.log(u).astype(jnp.float32)  # a_log kept f32; A = -exp(a_log)
    if d.init == "ssm_dt":      # softplus^-1 of dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, d.shape, jnp.float32, 1e-3, 1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(jnp.float32)
    fan_in = d.shape[0] if len(d.shape) else 1
    if len(d.shape) >= 2:
        fan_in = 1
        for s, log in zip(d.shape[:-1], d.logical[:-1]):
            if log != "layers":  # scan dims are not fan-in dims
                fan_in *= s
    std = d.scale / max(fan_in, 1) ** 0.5
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)


def init_params(cfg: ArchConfig, key) -> dict:
    defs = param_defs(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.dtype)
    arrs = [_init_leaf(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_params(cfg: ArchConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    def to_struct(d: ParamDef):
        dt = jnp.float32 if d.init in ("ssm_a", "ssm_dt") else dtype
        return jax.ShapeDtypeStruct(d.shape, dt)
    return jax.tree_util.tree_map(to_struct, param_defs(cfg), is_leaf=is_def)


def partition_specs(cfg: ArchConfig, plan: MeshPlan) -> dict:
    return jax.tree_util.tree_map(
        lambda d: plan.spec(d.logical, d.shape), param_defs(cfg), is_leaf=is_def)
