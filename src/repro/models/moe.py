"""Mixture-of-Experts layer (DeepSeek-MoE / Qwen3-MoE style).

Train/prefill path: capacity-based token dispatch. Each batch row is a dispatch
group; position-in-expert comes from an exclusive cumsum over one-hot assignments
(GShard style), tokens past capacity are dropped (weight renormalized). Dispatch is
gather/scatter-free on the hot path: slot->token index tables are built once per
layer ([G, E, C] int32 — small), then expert inputs are pure gathers, which GSPMD
shards cleanly over (batch=groups, experts=model).

Decode path: with one token per row every expert is hit with high probability, so
the cheapest memory-roofline choice is to run all experts densely and mask by the
router weights (weights are read once either way; see DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import swiglu
from repro.parallel.sharding import MeshPlan, constrain


def router_probs(cfg: ArchConfig, p: dict, x: jax.Array):
    """x: [..., D] -> (weights [..., k], idx [..., k]) top-k routing."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.router_normalize:
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, idx, probs


def aux_load_balance_loss(cfg: ArchConfig, probs: jax.Array, idx: jax.Array):
    """Switch-style load-balance loss: E * sum_e f_e * p_e over the group."""
    E = cfg.num_experts
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)            # [..., k, E]
    frac_tokens = onehot.sum(-2).reshape(-1, E).mean(0)           # fraction routed
    mean_prob = probs.reshape(-1, E).mean(0)
    return E * jnp.sum(frac_tokens * mean_prob)


def moe_block(cfg: ArchConfig, p: dict, x: jax.Array, plan: MeshPlan):
    """x: [B, S, D] -> ([B, S, D], aux_loss). Capacity-based top-k dispatch."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = max(int(S * K * cfg.capacity_factor / E), K)              # per-group capacity

    weights, idx, probs = router_probs(cfg, p, x)                 # [B,S,K]
    aux = aux_load_balance_loss(cfg, probs, idx)

    # ---- slot assignment (per group = batch row) -------------------------------
    flat_idx = idx.reshape(B, S * K)                              # assignment -> expert
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)         # [B, S*K, E]
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot                # exclusive cumsum
    my_pos = jnp.take_along_axis(
        pos_in_e, flat_idx[..., None], axis=-1)[..., 0]           # [B, S*K]
    keep = my_pos < C
    slot = flat_idx * C + jnp.where(keep, my_pos, C)              # dropped -> sentinel

    # slot -> token table: scatter token ids into [E*C (+1 sentinel)] per group
    token_of_assign = jnp.broadcast_to(
        jnp.arange(S * K, dtype=jnp.int32)[None, :] // K, (B, S * K))
    slot_token = jnp.full((B, E * C + 1), S, jnp.int32)
    slot_token = jax.vmap(
        lambda st, s, t: st.at[s].set(t, mode="drop"))(
            slot_token, jnp.where(keep, slot, E * C), token_of_assign)
    slot_token = slot_token[:, : E * C]                           # [B, E*C]

    # ---- dispatch: gather token activations into expert buffers ----------------
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    buf = jnp.take_along_axis(
        x_pad, slot_token[..., None], axis=1)                     # [B, E*C, D]
    buf = buf.reshape(B, E, C, D)
    buf = constrain(buf, plan, ("batch", "experts", None, None))

    # ---- expert compute (grouped SwiGLU) ----------------------------------------
    h = jnp.einsum("becd,edf->becf", buf, p["we_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["we_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, plan, ("batch", "experts", None, "ffn_nofsdp"))
    out_buf = jnp.einsum("becf,efd->becd", h, p["we_down"])
    out_buf = constrain(out_buf, plan, ("batch", "experts", None, None))
    out_buf = out_buf.reshape(B, E * C, D)

    # ---- combine: gather each token's k slots back, weight, and sum -------------
    if getattr(plan, "moe_combine_reshard", False):
        # Reshard the slot buffer back to batch-sharded BEFORE the token gather.
        # Gathering straight from the experts-sharded buffer makes GSPMD emit a
        # [B,S,K,D] f32 all-reduce per layer (masked partial gathers summed
        # across the model axis) — measured 2.6 TB/step on qwen3-moe-235b;
        # resharding first moves only the slot buffer through an all-to-all.
        out_buf = constrain(out_buf, plan, ("batch", None, None))
    gslot = jnp.where(keep, slot, E * C).reshape(B, S, K)
    out_pad = jnp.concatenate([out_buf, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    tok_out = jax.vmap(lambda ob, s: ob[s])(out_pad, gslot)       # [B, S, K, D]
    w = (weights * keep.reshape(B, S, K)).astype(x.dtype)
    y = jnp.einsum("bskd,bsk->bsd", tok_out, w)

    if cfg.num_shared_experts:
        y = y + swiglu(p["shared"], x, plan)
    return constrain(y, plan, ("batch", "seq", None)), aux


def moe_block_decode(cfg: ArchConfig, p: dict, x: jax.Array, plan: MeshPlan):
    """x: [B, 1, D]. Dense all-experts evaluation masked by router weights —
    memory-optimal at decode batch sizes (every expert's weights load anyway)."""
    B, S, D = x.shape
    E = cfg.num_experts
    weights, idx, _ = router_probs(cfg, p, x)                     # [B,1,K]
    w_full = jnp.zeros((B, S, E), jnp.float32)
    w_full = jax.vmap(jax.vmap(lambda w, i, ww: w.at[i].add(ww), (0, 0, 0)),
                      (0, 0, 0))(w_full, idx, weights)            # [B,1,E]

    h = jnp.einsum("bsd,edf->besf", x, p["we_gate"])
    u = jnp.einsum("bsd,edf->besf", x, p["we_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, plan, ("batch", "experts", None, "ffn_nofsdp"))
    y_e = jnp.einsum("besf,efd->besd", h, p["we_down"])           # [B,E,1,D]
    y = jnp.einsum("besd,bse->bsd", y_e.astype(jnp.float32),
                   w_full).astype(x.dtype)
    if cfg.num_shared_experts:
        y = y + swiglu(p["shared"], x, plan)
    return constrain(y, plan, ("batch", "seq", None))
