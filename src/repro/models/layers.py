"""Shared layer primitives: RMSNorm, RoPE, SwiGLU MLP, GQA attention (train/prefill
via the flash kernel, decode via cache attention).

All functions are pure; parameters arrive as dicts produced by ``models.params`` and
activations carry logical-axis sharding constraints through the ``MeshPlan``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.parallel.sharding import MeshPlan, constrain


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    return ops.rmsnorm(x, scale, eps=eps)


# ------------------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D] (D even), positions: [B, S] int32."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                                  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs     # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------------------- MLP
def swiglu(p: dict, x: jax.Array, plan: MeshPlan) -> jax.Array:
    pet = plan.reduce_dtype
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"], preferred_element_type=pet)
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"], preferred_element_type=pet)
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u.astype(x.dtype)
    h = constrain(h, plan, ("batch", "seq", "ffn"))
    # w_down contracts over the TP-sharded ffn dim: its output dtype IS the
    # all-reduce dtype (bf16 under plan.bf16_reduce)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"], preferred_element_type=pet)
    return constrain(out.astype(x.dtype), plan, ("batch", "seq", None))


# -------------------------------------------------------------------------- attention
def _qk_norm(p: dict, q: jax.Array, k: jax.Array, eps: float):
    if "q_norm" in p:
        q = ops.rmsnorm(q, p["q_norm"], eps=eps)
        k = ops.rmsnorm(k, p["k_norm"], eps=eps)
    return q, k


def qkv_project(p: dict, x: jax.Array, plan: MeshPlan, *,
                positions: Optional[jax.Array], theta: float, eps: float,
                kv_from: Optional[jax.Array] = None,
                kv_positions: Optional[jax.Array] = None):
    """Project q from x and k/v from ``kv_from`` (cross-attn) or x (self-attn)."""
    src = x if kv_from is None else kv_from
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    q, k = _qk_norm(p, q, k, eps)
    if positions is not None:
        q = apply_rope(q, positions, theta)
        kp = kv_positions if kv_positions is not None else positions
        k = apply_rope(k, kp, theta)
    q = constrain(q, plan, ("batch", "seq", "heads", None))
    k = constrain(k, plan, ("batch", "seq", "kv_heads", None))
    v = constrain(v, plan, ("batch", "seq", "kv_heads", None))
    return q, k, v


def attn_out(p: dict, o: jax.Array, plan: MeshPlan) -> jax.Array:
    # wo contracts over TP-sharded heads: output dtype = all-reduce dtype
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=plan.reduce_dtype)
    return constrain(out.astype(o.dtype), plan, ("batch", "seq", None))


def attention(p: dict, x: jax.Array, plan: MeshPlan, *,
              positions: jax.Array, theta: float, eps: float,
              causal: bool = True, window: int = 0) -> jax.Array:
    """Full self-attention over a [B, S, D] block (train / prefill)."""
    q, k, v = qkv_project(p, x, plan, positions=positions, theta=theta, eps=eps)
    o = ops.flash_attention(q, k, v, causal=causal, window=window)
    o = constrain(o, plan, ("batch", "seq", "heads", None))
    return attn_out(p, o, plan)


def cross_attention(p: dict, x: jax.Array, memory: jax.Array, plan: MeshPlan, *,
                    eps: float) -> jax.Array:
    """Cross-attention of x [B, S, D] onto memory [B, M, D] (no mask, no RoPE)."""
    q, k, v = qkv_project(p, x, plan, positions=None, theta=0.0, eps=eps,
                          kv_from=memory)
    o = ops.flash_attention(q, k, v, causal=False)
    o = constrain(o, plan, ("batch", "seq", "heads", None))
    return attn_out(p, o, plan)


def decode_attention(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                     plan: MeshPlan, *, theta: float, eps: float,
                     window: int = 0) -> tuple:
    """One-token self-attention against a KV cache.

    x: [B, 1, D]; cache: {"k","v": [B, Smax, K, Dh]}; pos: [B] int32 (next index).
    Returns (out [B,1,D], new_cache).
    """
    positions = pos[:, None]
    q, k_new, v_new = qkv_project(p, x, plan, positions=positions, theta=theta,
                                  eps=eps)
    k_cache = _cache_update(cache["k"], k_new, pos)
    v_cache = _cache_update(cache["v"], v_new, pos)
    k_cache = constrain(k_cache, plan, ("batch", "cache_seq", "kv_heads", None))
    v_cache = constrain(v_cache, plan, ("batch", "cache_seq", "kv_heads", None))
    o = ops.attend_cache(q, k_cache, v_cache, pos[:, None, None, None],
                         window=window)
    o = constrain(o, plan, ("batch", "seq", "heads", None))
    return attn_out(p, o, plan), {"k": k_cache, "v": v_cache}


def _cache_update(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write new [B, 1, K, D] into cache [B, Smax, K, D] at per-row position pos."""
    B, Smax = cache.shape[0], cache.shape[1]
    onehot = jax.nn.one_hot(pos, Smax, dtype=cache.dtype)        # [B, Smax]
    return cache * (1 - onehot[:, :, None, None]) + onehot[:, :, None, None] * new
