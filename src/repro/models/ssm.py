"""Mamba-2 (SSD) block: in-proj -> causal depthwise conv -> selective state-space
scan (kernels.ops.ssd_scan) -> gated RMSNorm -> out-proj.

Single B/C group (G=1) as in the assigned mamba2/zamba2 configs. The scan runs
chunked (SSD dual form) for train/prefill; decode carries a [B, H, N, P] state and a
(W-1)-token conv tail.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.parallel.sharding import MeshPlan, constrain


def _causal_conv(x: jax.Array, kernel: jax.Array,
                 tail: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: [B, S, C], kernel: [W, C], tail: [B, W-1, C]
    (previous tokens, for decode). Returns (y [B,S,C], new_tail [B,W-1,C])."""
    W = kernel.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)                      # [B, S+W-1, C]
    S = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for w in range(W):
        y = y + xp[:, w : w + S].astype(jnp.float32) * kernel[w].astype(jnp.float32)
    new_tail = xp[:, S:]                                         # last W-1 inputs
    return y.astype(x.dtype), new_tail


def ssm_block(cfg: ArchConfig, p: dict, x: jax.Array, plan: MeshPlan, *,
              state: Optional[dict] = None, return_state: bool = False):
    """x: [B, S, D]. state (decode): {"conv": [B,W-1,DI+2N], "ssd": [B,H,N,P]}.
    Returns y [B,S,D] (and the new state when ``return_state``)."""
    B, S, D = x.shape
    DI, N, Hs, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    z = jnp.einsum("bsd,de->bse", x, p["w_z"])                   # gate branch
    xs = jnp.einsum("bsd,de->bse", x, p["w_x"])
    bm = jnp.einsum("bsd,dn->bsn", x, p["w_b"])
    cm = jnp.einsum("bsd,dn->bsn", x, p["w_c"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    z = constrain(z, plan, ("batch", "seq", "ffn"))
    xs = constrain(xs, plan, ("batch", "seq", "ffn"))

    conv_in = jnp.concatenate([xs, bm.astype(xs.dtype), cm.astype(xs.dtype)], -1)
    conv_k = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], -1)
    conv_tail = None if state is None else state["conv"]
    conv_out, new_tail = _causal_conv(conv_in, conv_k, conv_tail)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(xs.dtype)
    xs, bm, cm = (conv_out[..., :DI], conv_out[..., DI : DI + N],
                  conv_out[..., DI + N :])

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # [B,S,Hs] > 0
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                 # [Hs] < 0

    xh = xs.reshape(B, S, Hs, P)
    xh = constrain(xh, plan, ("batch", "seq", "ssm_heads", None))
    if state is None:
        y, new_ssd = ops.ssd_scan(xh, dt, a, bm, cm, chunk=cfg.ssm_chunk,
                                  return_state=True)
    else:
        y, new_ssd = ops.ssd_decode_step(xh, dt, a, bm, cm, state["ssd"])
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(B, S, DI)

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)   # gated
    y = ops.rmsnorm(y, p["gate_norm"], eps=cfg.norm_eps)
    y = constrain(y, plan, ("batch", "seq", "ffn"))
    out = jnp.einsum("be,ed->bd", y.reshape(B * S, DI),
                     p["out_proj"]).reshape(B, S, D)
    out = constrain(out, plan, ("batch", "seq", None))
    if return_state:
        return out, {"conv": new_tail, "ssd": new_ssd}
    return out


def abstract_ssm_state(cfg: ArchConfig, batch: int) -> dict:
    DI, N = cfg.d_inner, cfg.ssm_state
    W = cfg.ssm_conv_width
    return {
        "conv": jax.ShapeDtypeStruct((batch, W - 1, DI + 2 * N),
                                     jnp.dtype(cfg.dtype)),
        "ssd": jax.ShapeDtypeStruct((batch, cfg.ssm_heads, N, cfg.ssm_head_dim),
                                    jnp.float32),
    }


def init_ssm_state(cfg: ArchConfig, batch: int) -> dict:
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  abstract_ssm_state(cfg, batch))
