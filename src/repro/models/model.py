"""Model facade: one ``Model`` class covering all assigned architecture families.

Forward structure per family (all stacks are ``lax.scan``-rolled over stacked layer
params; patterned archs reshape to (groups, period) and unroll the period inside the
scan body so per-position static attributes — sliding window, cross-attn — stay
static):

  dense   : [attn -> mlp] x L        (gemma3: period = local:global pattern)
  moe     : [attn -> moe] x L        (+ aux load-balance loss through the scan carry)
  ssm     : [mamba2 SSD] x L
  hybrid  : [[ssd x k] -> shared attn+mlp block] x G, then tail ssd layers
  encdec  : encoder [attn -> mlp] x Le  ->  decoder [attn -> xattn -> mlp] x L
  vlm     : [[attn -> mlp] x (k-1) -> gated xattn -> mlp] x (L/k)

Three entry points per model: ``forward`` (train), ``prefill`` (KV/state cache
build + last-token logits) and ``decode_step`` (one token against the cache). Cache
layouts are declared once as ``TensorDef`` trees, giving abstract/materialized/
PartitionSpec views from the same declaration (mirroring models.params).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import layers as LY
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.params import (abstract_params, init_params, param_defs,
                                 partition_specs)
from repro.parallel.sharding import MeshPlan, constrain

tmap = jax.tree_util.tree_map


# ------------------------------------------------------------------- cache declaration
@dataclasses.dataclass(frozen=True)
class TensorDef:
    shape: Tuple[int, ...]
    dtype: Any
    logical: Tuple[Optional[str], ...]

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_tdef(x) -> bool:
    return isinstance(x, TensorDef)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if mode == "dots" else jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=policy)


def _period(cfg: ArchConfig) -> int:
    return cfg.local_global_pattern + 1 if cfg.local_global_pattern else 1


def _window_for(cfg: ArchConfig, j: int) -> int:
    """Static sliding window for period position j (gemma3: j<pattern => local)."""
    if cfg.local_global_pattern and j < cfg.local_global_pattern:
        return cfg.sliding_window or 0
    return 0


def _ring_slice(k: jax.Array, W: int) -> jax.Array:
    """Convert full-sequence K/V [B,S,...] to ring layout [B,W,...] (slot = pos%W)."""
    S = k.shape[1]
    if S < W:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, W - S)
        return jnp.pad(k, pad)
    assert S % W == 0, f"prefill length {S} must be a multiple of window {W}"
    return k[:, -W:]


# ----------------------------------------------------------------------- layer blocks
def _self_attn(cfg: ArchConfig, plan: MeshPlan, p: dict, h: jax.Array,
               positions: jax.Array, window: int, causal: bool = True):
    q, k, v = LY.qkv_project(p, h, plan, positions=positions,
                             theta=cfg.rope_theta, eps=cfg.norm_eps)
    o = ops.flash_attention(q, k, v, causal=causal, window=window)
    o = constrain(o, plan, ("batch", "seq", "heads", None))
    return LY.attn_out(p, o, plan), k, v


def _cross_attn(cfg: ArchConfig, plan: MeshPlan, p: dict, h: jax.Array,
                memory: jax.Array):
    q, k, v = LY.qkv_project(p, h, plan, positions=None, theta=0.0,
                             eps=cfg.norm_eps, kv_from=memory)
    o = ops.flash_attention(q, k, v, causal=False)
    o = constrain(o, plan, ("batch", "seq", "heads", None))
    return LY.attn_out(p, o, plan), k, v


def _cross_attn_cached(cfg: ArchConfig, plan: MeshPlan, p: dict, h: jax.Array,
                       k: jax.Array, v: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    M = k.shape[1]
    full = jnp.full((h.shape[0],), M - 1, jnp.int32)
    o = ops.attend_cache(q, k, v, full[:, None, None, None],
                         packed=cfg.packed_decode)
    return LY.attn_out(p, o, plan)


def _ff(cfg: ArchConfig, plan: MeshPlan, p: dict, h: jax.Array, decode: bool):
    """Feed-forward: SwiGLU or MoE (returns (y, aux))."""
    if cfg.family == "moe" and "moe" in p:
        if decode:
            return MOE.moe_block_decode(cfg, p["moe"], h, plan), 0.0
        return MOE.moe_block(cfg, p["moe"], h, plan)
    return LY.swiglu(p["mlp"], h, plan), 0.0


def _block(cfg: ArchConfig, plan: MeshPlan, p: dict, x: jax.Array,
           positions: jax.Array, window: int, want_kv: bool,
           memory: Optional[jax.Array] = None, causal: bool = True):
    """attn [-> xattn] -> ff. Returns (x, kv, xkv, aux)."""
    h = LY.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, k, v = _self_attn(cfg, plan, p["attn"], h, positions, window, causal)
    x = x + a
    kv = {"k": k, "v": v} if want_kv else None
    xkv = None
    if "xattn" in p:
        h = LY.rmsnorm(x, p["ln3"], cfg.norm_eps)
        a, xk, xv = _cross_attn(cfg, plan, p["xattn"], h, memory)
        x = x + a
        xkv = {"k": xk, "v": xv} if want_kv else None
    h = LY.rmsnorm(x, p["ln2"], cfg.norm_eps)
    y, aux = _ff(cfg, plan, p, h, decode=False)
    return x + y, kv, xkv, aux


def _block_decode(cfg: ArchConfig, plan: MeshPlan, p: dict, x: jax.Array,
                  cache: dict, pos: jax.Array, window: int,
                  xkv: Optional[dict] = None):
    """Decode variant of ``_block``; cache is {"k","v"} (ring when window > 0)."""
    h = LY.rmsnorm(x, p["ln1"], cfg.norm_eps)
    positions = pos[:, None]
    q, k_new, v_new = LY.qkv_project(p["attn"], h, plan, positions=positions,
                                     theta=cfg.rope_theta, eps=cfg.norm_eps)
    if window > 0:
        W = cache["k"].shape[1]
        slot = jnp.mod(pos, W)
        k_c = LY._cache_update(cache["k"], k_new, slot)
        v_c = LY._cache_update(cache["v"], v_new, slot)
        k_c = constrain(k_c, plan, ("batch", "cache_seq", "kv_heads", None))
        v_c = constrain(v_c, plan, ("batch", "cache_seq", "kv_heads", None))
        o = ops.attend_cache_ring(q, k_c, v_c, pos)
    else:
        k_c = LY._cache_update(cache["k"], k_new, pos)
        v_c = LY._cache_update(cache["v"], v_new, pos)
        k_c = constrain(k_c, plan, ("batch", "cache_seq", "kv_heads", None))
        v_c = constrain(v_c, plan, ("batch", "cache_seq", "kv_heads", None))
        o = ops.attend_cache(q, k_c, v_c, pos[:, None, None, None],
                             packed=cfg.packed_decode)
    o = constrain(o, plan, ("batch", "seq", "heads", None))
    x = x + LY.attn_out(p["attn"], o, plan)
    if "xattn" in p:
        h = LY.rmsnorm(x, p["ln3"], cfg.norm_eps)
        x = x + _cross_attn_cached(cfg, plan, p["xattn"], h, xkv["k"], xkv["v"])
    h = LY.rmsnorm(x, p["ln2"], cfg.norm_eps)
    y, _ = _ff(cfg, plan, p, h, decode=True)
    return x + y, {"k": k_c, "v": v_c}


# ----------------------------------------------------------- attention-family stacks
def _grouped(cfg: ArchConfig, params_layers: dict):
    period = _period(cfg)
    if period == 1:
        return params_layers
    G = cfg.num_layers // period
    assert G * period == cfg.num_layers
    return tmap(lambda a: a.reshape((G, period) + a.shape[1:]), params_layers)


def _stack_fwd(cfg: ArchConfig, plan: MeshPlan, params: dict, x: jax.Array,
               positions: jax.Array, memory: Optional[jax.Array] = None,
               want_kv: bool = False, causal: bool = True):
    """dense / moe / encdec-decoder stack. Returns (x, kvs, xkvs, aux)."""
    period = _period(cfg)
    lp = _grouped(cfg, params["layers"])
    windows = [_window_for(cfg, j) for j in range(period)]

    def body(carry, layer_p):
        x, aux = carry
        kvs, xkvs = [], []
        for j in range(period):
            pj = tmap(lambda a: a[j], layer_p) if period > 1 else layer_p
            x, kv, xkv, a = _block(cfg, plan, pj, x, positions, windows[j],
                                   want_kv, memory, causal)
            aux = aux + a
            if want_kv and windows[j] > 0:
                kv = tmap(lambda t: _ring_slice(t, windows[j]), kv)
            kvs.append(kv)
            xkvs.append(xkv)
        ys = (tuple(kvs), tuple(xkvs)) if want_kv else None
        return (x, aux), ys

    body = _remat(body, cfg.remat)
    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), lp)
    kvs, xkvs = ys if want_kv else (None, None)
    return x, kvs, xkvs, aux


def _stack_decode(cfg: ArchConfig, plan: MeshPlan, params: dict, x: jax.Array,
                  cache_layers: tuple, pos: jax.Array,
                  cross_kvs: Optional[tuple] = None):
    period = _period(cfg)
    lp = _grouped(cfg, params["layers"])
    windows = [_window_for(cfg, j) for j in range(period)]

    def body(x, inp):
        layer_p, caches, xkvs = inp
        new = []
        for j in range(period):
            pj = tmap(lambda a: a[j], layer_p) if period > 1 else layer_p
            xkv = None if xkvs is None else xkvs[j]
            x, nc = _block_decode(cfg, plan, pj, x, caches[j], pos, windows[j],
                                  xkv)
            new.append(nc)
        return x, tuple(new)

    xs = (lp, cache_layers, cross_kvs)
    x, new_cache = jax.lax.scan(body, x, xs)
    return x, new_cache


# ------------------------------------------------------------------------- ssm stacks
def _ssm_fwd(cfg: ArchConfig, plan: MeshPlan, params: dict, x: jax.Array,
             want_state: bool = False):
    def body(x, inp):
        lp = inp
        h = LY.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if want_state:
            y, st = SSM.ssm_block(cfg, lp["ssm"], h, plan, return_state=True)
            return x + y, st
        return x + SSM.ssm_block(cfg, lp["ssm"], h, plan), None

    body = _remat(body, cfg.remat)
    x, states = jax.lax.scan(body, x, params["layers"])
    return x, states


def _ssm_decode(cfg: ArchConfig, plan: MeshPlan, params: dict, x: jax.Array,
                states: dict):
    def body(x, inp):
        lp, st = inp
        h = LY.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        y, new = SSM.ssm_block(cfg, lp["ssm"], h, plan, state=st,
                               return_state=True)
        return x + y, new

    x, new_states = jax.lax.scan(body, x, (params["layers"], states))
    return x, new_states


# ----------------------------------------------------------------------- hybrid stack
def _hybrid_split(cfg: ArchConfig, params: dict):
    k = cfg.shared_block_every
    G = cfg.num_layers // k
    main = tmap(lambda a: a[: G * k].reshape((G, k) + a.shape[1:]),
                params["layers"])
    tail = tmap(lambda a: a[G * k :], params["layers"])
    return main, tail, G, cfg.num_layers - G * k


def _shared_block_fwd(cfg, plan, shared, x, positions, want_kv):
    h = LY.rmsnorm(x, shared["ln1"], cfg.norm_eps)
    a, k, v = _self_attn(cfg, plan, shared["attn"], h, positions, 0)
    x = x + a
    h = LY.rmsnorm(x, shared["ln2"], cfg.norm_eps)
    x = x + LY.swiglu(shared["mlp"], h, plan)
    return x, ({"k": k, "v": v} if want_kv else None)


def _hybrid_fwd(cfg: ArchConfig, plan: MeshPlan, params: dict, x: jax.Array,
                positions: jax.Array, want_state: bool = False):
    main, tail, G, n_tail = _hybrid_split(cfg, params)
    shared = params["shared_block"]
    k = cfg.shared_block_every

    def group_body(x, lp):
        states, kvs = [], None
        for j in range(k):
            pj = tmap(lambda a: a[j], lp)
            h = LY.rmsnorm(x, pj["ln1"], cfg.norm_eps)
            if want_state:
                y, st = SSM.ssm_block(cfg, pj["ssm"], h, plan, return_state=True)
                states.append(st)
            else:
                y = SSM.ssm_block(cfg, pj["ssm"], h, plan)
            x = x + y
        x, kv = _shared_block_fwd(cfg, plan, shared, x, positions, want_state)
        ys = ((tmap(lambda *s: jnp.stack(s), *states) if states else None), kv)
        return x, ys if want_state else None

    gb = _remat(group_body, cfg.remat)
    x, ys = jax.lax.scan(gb, x, main)
    main_states, shared_kv = ys if want_state else (None, None)

    def tail_body(x, lp):
        h = LY.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if want_state:
            y, st = SSM.ssm_block(cfg, lp["ssm"], h, plan, return_state=True)
            return x + y, st
        return x + SSM.ssm_block(cfg, lp["ssm"], h, plan), None

    tb = _remat(tail_body, cfg.remat)
    x, tail_states = jax.lax.scan(tb, x, tail)
    return x, main_states, shared_kv, tail_states


def _hybrid_decode(cfg: ArchConfig, plan: MeshPlan, params: dict, x: jax.Array,
                   cache: dict, pos: jax.Array):
    main, tail, G, n_tail = _hybrid_split(cfg, params)
    shared = params["shared_block"]
    k = cfg.shared_block_every

    def group_body(x, inp):
        lp, sts, skv = inp
        new_states = []
        for j in range(k):
            pj = tmap(lambda a: a[j], lp)
            st = tmap(lambda a: a[j], sts)
            h = LY.rmsnorm(x, pj["ln1"], cfg.norm_eps)
            y, new = SSM.ssm_block(cfg, pj["ssm"], h, plan, state=st,
                                   return_state=True)
            new_states.append(new)
            x = x + y
        x, new_skv = _shared_decode(cfg, plan, shared, x, skv, pos)
        return x, (tmap(lambda *s: jnp.stack(s), *new_states), new_skv)

    x, (new_main, new_skv) = jax.lax.scan(
        group_body, x, (main, cache["main"], cache["shared"]))

    def tail_body(x, inp):
        lp, st = inp
        h = LY.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        y, new = SSM.ssm_block(cfg, lp["ssm"], h, plan, state=st,
                               return_state=True)
        return x + y, new

    x, new_tail = jax.lax.scan(tail_body, x, (tail, cache["tail"]))
    return x, {"main": new_main, "shared": new_skv, "tail": new_tail}


def _shared_decode(cfg, plan, shared, x, skv, pos):
    h = LY.rmsnorm(x, shared["ln1"], cfg.norm_eps)
    positions = pos[:, None]
    q, k_new, v_new = LY.qkv_project(shared["attn"], h, plan,
                                     positions=positions, theta=cfg.rope_theta,
                                     eps=cfg.norm_eps)
    k_c = LY._cache_update(skv["k"], k_new, pos)
    v_c = LY._cache_update(skv["v"], v_new, pos)
    k_c = constrain(k_c, plan, ("batch", "cache_seq", "kv_heads", None))
    v_c = constrain(v_c, plan, ("batch", "cache_seq", "kv_heads", None))
    o = ops.attend_cache(q, k_c, v_c, pos[:, None, None, None],
                         packed=cfg.packed_decode)
    x = x + LY.attn_out(shared["attn"], o, plan)
    h = LY.rmsnorm(x, shared["ln2"], cfg.norm_eps)
    x = x + LY.swiglu(shared["mlp"], h, plan)
    return x, {"k": k_c, "v": v_c}


# -------------------------------------------------------------------------- vlm stack
def _vlm_cross_layer(cfg, plan, p, x, patches, want_kv):
    h = LY.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, k, v = _cross_attn(cfg, plan, p["xattn"], h, patches)
    x = x + jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * a
    h = LY.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + LY.swiglu(p["mlp"], h, plan)
    return x, ({"k": k, "v": v} if want_kv else None)


def _vlm_fwd(cfg: ArchConfig, plan: MeshPlan, params: dict, x: jax.Array,
             positions: jax.Array, patches: jax.Array, want_kv: bool = False):
    group = cfg.cross_attn_every - 1

    def body(x, inp):
        slp, clp = inp
        kvs = []
        for j in range(group):
            pj = tmap(lambda a: a[j], slp)
            x, kv, _, _ = _block(cfg, plan, pj, x, positions, 0, want_kv)
            kvs.append(kv)
        x, xkv = _vlm_cross_layer(cfg, plan, clp, x, patches, want_kv)
        return x, ((tuple(kvs), xkv) if want_kv else None)

    body = _remat(body, cfg.remat)
    x, ys = jax.lax.scan(body, x, (params["self_layers"], params["cross_layers"]))
    if not want_kv:
        return x, None, None
    kvs, xkvs = ys
    return x, kvs, xkvs


def _vlm_decode(cfg: ArchConfig, plan: MeshPlan, params: dict, x: jax.Array,
                cache: dict, pos: jax.Array):
    group = cfg.cross_attn_every - 1

    def body(x, inp):
        slp, clp, caches, xkv = inp
        new = []
        for j in range(group):
            pj = tmap(lambda a: a[j], slp)
            cj = tmap(lambda a: a[j], caches)
            x, nc = _block_decode(cfg, plan, pj, x, cj, pos, 0)
            new.append(nc)
        h = LY.rmsnorm(x, clp["ln1"], cfg.norm_eps)
        a = _cross_attn_cached(cfg, plan, clp["xattn"], h, xkv["k"], xkv["v"])
        x = x + jnp.tanh(clp["gate"].astype(jnp.float32)).astype(x.dtype) * a
        h = LY.rmsnorm(x, clp["ln2"], cfg.norm_eps)
        x = x + LY.swiglu(clp["mlp"], h, plan)
        return x, tmap(lambda *t: jnp.stack(t), *new)

    xs = (params["self_layers"], params["cross_layers"], cache["self"],
          cache["cross"])
    x, new_self = jax.lax.scan(body, x, xs)
    return x, new_self


# =============================================================================== Model
class Model:
    """Family-dispatched model bound to an ArchConfig and a MeshPlan."""

    def __init__(self, cfg: ArchConfig, plan: MeshPlan):
        self.cfg = cfg
        self.plan = plan

    # ------------------------------------------------------------------ params views
    def init_params(self, key) -> dict:
        return init_params(self.cfg, key)

    def abstract_params(self) -> dict:
        return abstract_params(self.cfg)

    def param_specs(self) -> dict:
        return partition_specs(self.cfg, self.plan)

    # --------------------------------------------------------------------- embedding
    def _embed(self, params: dict, tokens: jax.Array) -> jax.Array:
        x = params["embed"][tokens]
        return constrain(x, self.plan, ("batch", "seq", None))

    def _unembed(self, params: dict, x: jax.Array) -> jax.Array:
        x = LY.rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        table = (params["embed"].T if self.cfg.tie_embeddings
                 else params["unembed"])
        logits = jnp.einsum("bsd,dv->bsv", x, table)
        return constrain(logits, self.plan, ("batch", "seq", "vocab"))

    def _encode(self, params: dict, frames: jax.Array) -> jax.Array:
        """Whisper-style encoder over stub frame embeddings [B, M, D]."""
        cfg, plan = self.cfg, self.plan
        M = frames.shape[1]
        positions = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32)[None],
                                     frames.shape[:2])
        x = frames

        def body(x, lp):
            x, _, _, _ = _block(cfg, plan, lp, x, positions, 0, False,
                                causal=False)
            return x, None

        body = _remat(body, cfg.remat)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return LY.rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    # ----------------------------------------------------------------------- forward
    def forward(self, params: dict, batch: Dict[str, jax.Array],
                return_hidden: bool = False):
        """Full-sequence forward. Returns (logits [B,S,V], aux_loss) — or the
        final-normed hidden states when ``return_hidden`` (chunked-CE path)."""
        cfg, plan = self.cfg, self.plan
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        aux = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense", "moe"):
            x, _, _, aux = _stack_fwd(cfg, plan, params, x, positions)
        elif cfg.family == "ssm":
            x, _ = _ssm_fwd(cfg, plan, params, x)
        elif cfg.family == "hybrid":
            x, _, _, _ = _hybrid_fwd(cfg, plan, params, x, positions)
        elif cfg.family == "encdec":
            memory = self._encode(params, batch["frames"])
            x, _, _, aux = _stack_fwd(cfg, plan, params, x, positions,
                                      memory=memory)
        elif cfg.family == "vlm":
            x, _, _ = _vlm_fwd(cfg, plan, params, x, positions,
                               batch["patches"])
        else:
            raise ValueError(cfg.family)
        if return_hidden:
            return LY.rmsnorm(x, params["final_norm"], cfg.norm_eps), aux
        return self._unembed(params, x), aux

    def loss_fn(self, params: dict, batch: Dict[str, jax.Array]):
        """Masked CE (+ MoE aux). Returns (loss, metrics).

        CE uses a gather (take_along_axis), NOT a one-hot einsum — the one-hot
        materializes a [B,S,V] f32 tensor whose HBM traffic rivals a layer's
        (measured in the roofline pass; see EXPERIMENTS.md §Perf iteration 1).

        With cfg.loss_chunk > 0 the full [B,S,V] logits are NEVER materialized:
        the sequence is processed in chunks under jax.checkpoint (per-chunk
        logits recomputed in the backward) — the memory lever that makes
        dp_only viable for small models (§Perf cell 3).
        """
        mask = batch["loss_mask"].astype(jnp.float32)
        denom = jnp.maximum(mask.sum(), 1.0)
        if self.cfg.loss_chunk:
            hidden, aux = self.forward(params, batch, return_hidden=True)
            ce = self._chunked_ce(params, hidden, batch["targets"],
                                  mask) / denom
        else:
            logits, aux = self.forward(params, batch)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            ll = jnp.take_along_axis(logp, batch["targets"][..., None],
                                     axis=-1)[..., 0]            # [B, S]
            ce = -(ll * mask).sum() / denom
        loss = ce + 0.01 * aux
        metrics = {"loss": ce, "aux_loss": aux, "tokens": mask.sum()}
        return loss, metrics

    def _chunked_ce(self, params: dict, hidden: jax.Array, targets: jax.Array,
                    mask: jax.Array) -> jax.Array:
        """Sum of masked -log p over [B,S] in sequence chunks of cfg.loss_chunk."""
        cfg, plan = self.cfg, self.plan
        table = (params["embed"].T if cfg.tie_embeddings
                 else params["unembed"])
        B, S, D = hidden.shape
        c = min(cfg.loss_chunk, S)
        n = S // c
        assert n * c == S, f"loss_chunk {c} must divide seq {S}"

        def body(args):
            xc, tc, mc = args                                   # [B,c,D] ...
            logits = jnp.einsum("bsd,dv->bsv", xc, table)
            logits = constrain(logits.astype(jnp.float32),
                               plan, ("batch", "seq", "vocab"))
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, tc[..., None],
                                     axis=-1)[..., 0] - lse
            return -(ll * mc).sum()

        body = jax.checkpoint(body)
        xs = (hidden.reshape(B, n, c, D).swapaxes(0, 1),
              targets.reshape(B, n, c).swapaxes(0, 1),
              mask.reshape(B, n, c).swapaxes(0, 1))
        return jnp.sum(jax.lax.map(body, xs))

    # ----------------------------------------------------------------------- prefill
    def prefill(self, params: dict, batch: Dict[str, jax.Array],
                max_len: Optional[int] = None):
        """Build the decode cache from a full prompt; returns (last_logits, cache)."""
        cfg, plan = self.cfg, self.plan
        tokens = batch["tokens"]
        B, S = tokens.shape
        max_len = max_len or S
        x = self._embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        pos = jnp.full((B,), S, jnp.int32)

        def pad_seq(t, target):
            if t.shape[2] == target:
                return t
            pad = [(0, 0)] * t.ndim
            pad[2] = (0, target - t.shape[2])
            return jnp.pad(t, pad)

        if cfg.family in ("dense", "moe"):
            x, kvs, _, _ = _stack_fwd(cfg, plan, params, x, positions,
                                      want_kv=True)
            layers = tuple(
                tmap(lambda t: t if _window_for(cfg, j) else pad_seq(t, max_len),
                     kvs[j])
                for j in range(_period(cfg)))
            cache = {"pos": pos, "layers": layers}
        elif cfg.family == "ssm":
            x, states = _ssm_fwd(cfg, plan, params, x, want_state=True)
            cache = {"pos": pos, "layers": states}
        elif cfg.family == "hybrid":
            x, main, skv, tail = _hybrid_fwd(cfg, plan, params, x, positions,
                                             want_state=True)
            cache = {"pos": pos, "main": main,
                     "shared": tmap(lambda t: pad_seq(t, max_len), skv),
                     "tail": tail}
        elif cfg.family == "encdec":
            memory = self._encode(params, batch["frames"])
            x, kvs, xkvs, _ = _stack_fwd(cfg, plan, params, x, positions,
                                         memory=memory, want_kv=True)
            cache = {"pos": pos,
                     "self": tmap(lambda t: pad_seq(t, max_len), kvs[0]),
                     "cross": xkvs[0]}
        elif cfg.family == "vlm":
            x, kvs, xkvs = _vlm_fwd(cfg, plan, params, x, positions,
                                    batch["patches"], want_kv=True)
            self_c = tmap(lambda *t: jnp.stack(t, axis=1),
                          *[tmap(lambda u: pad_seq(u, max_len), kv)
                            for kv in kvs])
            cache = {"pos": pos, "self": self_c, "cross": xkvs}
        else:
            raise ValueError(cfg.family)
        last_logits = self._unembed(params, x[:, -1:])[:, 0]
        return last_logits, cache

    # ------------------------------------------------------------------- decode step
    def decode_step(self, params: dict, tokens: jax.Array, cache: dict):
        """tokens [B, 1] -> (logits [B, V], new_cache)."""
        cfg, plan = self.cfg, self.plan
        pos = cache["pos"]
        x = self._embed(params, tokens)

        if cfg.family in ("dense", "moe"):
            x, new_layers = _stack_decode(cfg, plan, params, x,
                                          cache["layers"], pos)
            new_cache = {"pos": pos + 1, "layers": new_layers}
        elif cfg.family == "ssm":
            x, new_states = _ssm_decode(cfg, plan, params, x, cache["layers"])
            new_cache = {"pos": pos + 1, "layers": new_states}
        elif cfg.family == "hybrid":
            x, new = _hybrid_decode(cfg, plan, params, x, cache, pos)
            new_cache = dict(new, pos=pos + 1)
        elif cfg.family == "encdec":
            x, new_self = _stack_decode(cfg, plan, params, x,
                                        (cache["self"],), pos,
                                        cross_kvs=(cache["cross"],))
            new_cache = {"pos": pos + 1, "self": new_self[0],
                         "cross": cache["cross"]}
        elif cfg.family == "vlm":
            x, new_self = _vlm_decode(cfg, plan, params, x, cache, pos)
            new_cache = {"pos": pos + 1, "self": new_self,
                         "cross": cache["cross"]}
        else:
            raise ValueError(cfg.family)
        logits = self._unembed(params, x)[:, 0]
        return logits, new_cache

    # ------------------------------------------------------------------- cache views
    def cache_defs(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        K, hd = cfg.num_kv_heads, cfg.head_dim
        kv_log = (None, "batch", "cache_seq", "kv_heads", None)

        def kv(G, S):
            return {"k": TensorDef((G, batch, S, K, hd), dt, kv_log),
                    "v": TensorDef((G, batch, S, K, hd), dt, kv_log)}

        def ssm_state(*lead):
            DI, N = cfg.d_inner, cfg.ssm_state
            W = cfg.ssm_conv_width
            lead_log = (None,) * len(lead)
            return {
                "conv": TensorDef(lead + (batch, W - 1, DI + 2 * N), dt,
                                  lead_log + ("batch", None, "ffn")),
                "ssd": TensorDef(lead + (batch, cfg.ssm_heads, cfg.ssm_state,
                                         cfg.ssm_head_dim), jnp.float32,
                                 lead_log + ("batch", "ssm_heads", None, None)),
            }

        pos = TensorDef((batch,), jnp.int32, ("batch",))
        if cfg.family in ("dense", "moe"):
            period = _period(cfg)
            G = cfg.num_layers // period
            layers = tuple(
                kv(G, _window_for(cfg, j) or max_len) for j in range(period))
            return {"pos": pos, "layers": layers}
        if cfg.family == "ssm":
            return {"pos": pos, "layers": ssm_state(cfg.num_layers)}
        if cfg.family == "hybrid":
            k = cfg.shared_block_every
            G = cfg.num_layers // k
            return {"pos": pos, "main": ssm_state(G, k),
                    "shared": kv(G, max_len),
                    "tail": ssm_state(cfg.num_layers - G * k)}
        if cfg.family == "encdec":
            L = cfg.num_layers
            return {"pos": pos,
                    "self": {k_: v_ for k_, v_ in kv(L, max_len).items()},
                    "cross": kv(L, cfg.encoder_frames)}
        if cfg.family == "vlm":
            nc = cfg.num_layers // cfg.cross_attn_every
            grp = cfg.cross_attn_every - 1
            self_kv = {
                "k": TensorDef((nc, grp, batch, max_len, K, hd), dt,
                               (None,) + kv_log),
                "v": TensorDef((nc, grp, batch, max_len, K, hd), dt,
                               (None,) + kv_log)}
            return {"pos": pos, "self": self_kv,
                    "cross": kv(nc, cfg.num_patches)}
        raise ValueError(cfg.family)

    def abstract_cache(self, batch: int, max_len: int) -> dict:
        return tmap(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
                    self.cache_defs(batch, max_len), is_leaf=_is_tdef)

    def init_cache(self, batch: int, max_len: int) -> dict:
        return tmap(lambda d: jnp.zeros(d.shape, d.dtype),
                    self.cache_defs(batch, max_len), is_leaf=_is_tdef)

    def cache_specs(self, batch: int, max_len: int) -> dict:
        return tmap(lambda d: self.plan.spec(d.logical, d.shape),
                    self.cache_defs(batch, max_len), is_leaf=_is_tdef)
