"""Pipeline data-plane throughput: tasks/second and RPCs-per-task for the
scheduler -> broker -> worker -> taskdb loop (paper §5), batched vs per-task.

Two DAG shapes, swept over task-instance counts:

  * ``wide``   — one root fanning out to N-1 independent tasks (the frontier
    lands on the broker in one coalesced flush; workers drain it in
    ``pull_many`` batches);
  * ``chains`` — N/64 parallel chains of depth 64 (deep dependency structure:
    every level must round-trip through the taskdb before the next frontier
    exists, so batching only amortizes across sibling chains).

``RPCs-per-task`` counts every broker + taskdb service op the whole pipeline
issues (scheduler probes/flushes, worker pulls/commits/acks, empty polls, the
run loop's status probes) divided by task instances executed. The batched
protocol's acceptance gates, recorded under ``flatness`` / ``gains``:

  * flat RPCs-per-task from 1k -> 50k instances (ratio <= 1.5) per shape;
  * >= 5x fewer RPCs-per-task than the per-task protocol (measured at the
    largest scale the unbatched baseline runs, 10k).

Like the control-plane sweep, absolute wall-times vary with the host — the
RPC ratios are the signal.
"""
from __future__ import annotations

import time
from typing import List

from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.pipelines import DAG, Task, HybridComposer

SCALES = (1_000, 10_000, 50_000)
BASELINE_SCALES = (1_000, 10_000)     # the per-task protocol is too slow at 50k
CHAIN_DEPTH = 64
WORKER_BATCH = 64


def _make_dag(shape: str, n_tasks: int) -> DAG:
    if shape == "wide":
        tasks = [Task("root", kind="python")]
        tasks += [Task(f"t{i}", kind="python", upstream=("root",))
                  for i in range(n_tasks - 1)]
        return DAG("bench", tasks)
    if shape == "chains":
        n_chains = max(n_tasks // CHAIN_DEPTH, 1)
        tasks = []
        for c in range(n_chains):
            for d in range(CHAIN_DEPTH):
                up = (f"c{c}_s{d - 1}",) if d else ()
                tasks.append(Task(f"c{c}_s{d}", kind="python", upstream=up))
        return DAG("bench", tasks)
    raise ValueError(f"unknown shape {shape}")


def run_pipeline(shape: str, n_tasks: int, pipelined: bool) -> dict:
    """One full DAG execution over the hybrid fabric; returns throughput and
    the broker+taskdb RPC ledger."""
    plane = ManagementPlane(message_log_limit=1_000, op_log_limit=1_000)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("compute-a")
    comp = HybridComposer(
        plane, workers={"master": ["w0"], "compute-a": ["w1"]},
        worker_batch=WORKER_BATCH, pipelined=pipelined)
    dag = _make_dag(shape, n_tasks)
    comp.add_dag(dag)
    actual = len(dag.tasks)
    # generous tick budget: batched drains ~2*WORKER_BATCH tasks/tick, the
    # per-task protocol exactly 2
    max_ticks = actual + 200 if not pipelined else \
        (actual // WORKER_BATCH + CHAIN_DEPTH * 8 + 200)
    t0 = time.perf_counter()
    ok = comp.run_dag("bench", max_ticks=max_ticks)
    wall = time.perf_counter() - t0
    rpcs = (sum(comp.broker.op_counts.values())
            + sum(comp.taskdb.op_counts.values()))
    return {
        "shape": shape, "tasks": actual, "pipelined": pipelined, "ok": ok,
        "wall_s": wall, "tasks_per_s": actual / max(wall, 1e-9),
        "broker_rpcs": sum(comp.broker.op_counts.values()),
        "taskdb_rpcs": sum(comp.taskdb.op_counts.values()),
        "rpcs_per_task": rpcs / actual,
    }


_CACHE: dict = {}


def run_sweep() -> dict:
    """Batched sweep + per-task baseline + the flatness/gain gates."""
    if "sweep" in _CACHE:
        return _CACHE["sweep"]
    after_rows: List[dict] = []
    before_rows: List[dict] = []
    for shape in ("wide", "chains"):
        for n in SCALES:
            after_rows.append(run_pipeline(shape, n, pipelined=True))
        for n in BASELINE_SCALES:
            before_rows.append(run_pipeline(shape, n, pipelined=False))
    by = {(r["shape"], r["tasks"]): r for r in after_rows}
    base = {(r["shape"], r["tasks"]): r for r in before_rows}
    flat, gains = {}, {}
    for shape in ("wide", "chains"):
        lo = _make_dag(shape, min(SCALES))
        hi = _make_dag(shape, max(SCALES))
        lo_r = by[(shape, len(lo.tasks))]
        hi_r = by[(shape, len(hi.tasks))]
        flat[f"rpcs_per_task_ratio_{shape}_50k_over_1k"] = (
            hi_r["rpcs_per_task"] / max(lo_r["rpcs_per_task"], 1e-9))
        cmp_n = len(_make_dag(shape, max(BASELINE_SCALES)).tasks)
        gains[f"rpcs_per_task_gain_{shape}_10k"] = (
            base[(shape, cmp_n)]["rpcs_per_task"]
            / max(by[(shape, cmp_n)]["rpcs_per_task"], 1e-9))
    result = {
        "label": "batched broker protocol + worker commit pipelining",
        "after": after_rows,
        "before": {"label": "per-task protocol (pipelined=False)",
                   "rows": before_rows},
        "flatness": flat,          # lower is better; gate <= 1.5
        "gains": gains,            # higher is better; gate >= 5
    }
    _CACHE["sweep"] = result
    return result


def run() -> List[tuple]:
    rows = []
    sweep = run_sweep()
    for r in sweep["after"] + sweep["before"]["rows"]:
        mode = "batched" if r["pipelined"] else "per-task"
        tag = f"[{r['shape']},{r['tasks']}tasks,{mode}]"
        rows.append((f"rpcs_per_task{tag}", r["rpcs_per_task"]))
        rows.append((f"tasks_per_s{tag}", r["tasks_per_s"]))
    for k, v in sweep["flatness"].items():
        rows.append((k, v))
    for k, v in sweep["gains"].items():
        rows.append((k, v))
    return rows


def run_json() -> dict:
    """Structured payload for ``benchmarks/run.py --json``."""
    return run_sweep()
