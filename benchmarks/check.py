"""Perf-regression gate (``make bench-check``): re-run the benchmark sweeps
and compare against the committed ``BENCH_<suite>.json`` trajectory.

Two kinds of gated numbers, discovered generically anywhere in the payload:

  * ``flatness`` dicts — scaling ratios, LOWER is better. A fresh ratio more
    than 20% above the committed one fails.
  * ``gains`` dicts — batching/overhaul multipliers, HIGHER is better. A
    fresh gain more than 20% below the committed one fails.

Only ratio-of-ratios is compared — absolute microseconds/walltimes vary with
the host, the growth shape does not. Suites without a committed file (or
without ``run_json``) are skipped.

A suite spec may name a single payload PART as ``suite:part`` (e.g.
``control_plane:locality``): only the committed ratios under that top-level
key are gated, and the fresh numbers come from the suite's
``run_json_<part>()`` — so CI can gate a deterministic sub-block (byte
counts) without paying for, or flaking on, the suite's wall-clock sweeps.

  PYTHONPATH=src python -m benchmarks.check                 # all gated suites
  PYTHONPATH=src python -m benchmarks.check pipeline_plane  # one suite
  PYTHONPATH=src python -m benchmarks.check control_plane:locality
  PYTHONPATH=src python -m benchmarks.check control_plane:notify
  ... --dir DIR   # where the committed BENCH_*.json live (default ".")
"""
from __future__ import annotations

import json
import os
import sys
import traceback
from typing import Dict, List, Tuple

GATED_SUITES = ("control_plane", "pipeline_plane", "autoscale", "durability",
                "workloads", "observability")
TOLERANCE = 1.2          # a gated number may move 20% the wrong way


def _collect(payload, path="") -> List[Tuple[str, str, float]]:
    """(path, direction, value) for every number under a flatness/gains dict."""
    out: List[Tuple[str, str, float]] = []
    if isinstance(payload, dict):
        for k, v in payload.items():
            sub = f"{path}.{k}" if path else str(k)
            if k in ("flatness", "gains") and isinstance(v, dict):
                direction = "lower" if k == "flatness" else "higher"
                for name, num in v.items():
                    if isinstance(num, (int, float)):
                        out.append((f"{sub}.{name}", direction, float(num)))
            else:
                out.extend(_collect(v, sub))
    elif isinstance(payload, list):
        for i, v in enumerate(payload):
            out.extend(_collect(v, f"{path}[{i}]"))
    return out


def _incomplete_runs(payload, path="") -> List[str]:
    """Paths of result rows carrying ``"ok": False`` — a stalled sweep issues
    FEWER RPCs per task, which would otherwise make the ratios look better."""
    out: List[str] = []
    if isinstance(payload, dict):
        if payload.get("ok") is False:
            out.append(path or "<root>")
        for k, v in payload.items():
            out.extend(_incomplete_runs(v, f"{path}.{k}" if path else str(k)))
    elif isinstance(payload, list):
        for i, v in enumerate(payload):
            out.extend(_incomplete_runs(v, f"{path}[{i}]"))
    return out


def check_suite(spec: str, committed_dir: str) -> List[str]:
    """Return a list of failure messages (empty = pass) for one suite spec
    (``name`` or ``name:part``)."""
    name, _, part = spec.partition(":")
    committed_path = os.path.join(committed_dir, f"BENCH_{name}.json")
    if not os.path.exists(committed_path):
        print(f"{spec}: no committed {committed_path}, skipping")
        return []
    with open(committed_path) as f:
        committed = json.load(f)
    if part:
        # an explicitly named part is a promise: its absence (typo'd spec,
        # stale committed file) must FAIL, not silently gate nothing
        if part not in committed:
            return [f"{spec}: committed {committed_path} has no "
                    f"'{part}' block"]
        committed = {part: committed[part]}
    baseline = {p: (d, v) for p, d, v in _collect(committed)}
    if not baseline:
        if part:
            return [f"{spec}: '{part}' block has no gated ratios"]
        print(f"{spec}: committed payload has no gated ratios, skipping")
        return []
    mod = __import__(f"benchmarks.{name}", fromlist=["run_json"])
    if part:
        fn = getattr(mod, f"run_json_{part}", None)
        if fn is None:
            return [f"{spec}: benchmarks.{name} has no run_json_{part}()"]
        fresh_payload = {part: fn()}
    else:
        fresh_payload = mod.run_json()
    fresh = {p: v for p, _, v in _collect(fresh_payload)}
    failures: List[str] = [
        f"{spec}: run did not complete (ok=false) at {p}"
        for p in _incomplete_runs(fresh_payload)]
    for path, (direction, committed_v) in sorted(baseline.items()):
        fresh_v = fresh.get(path)
        if fresh_v is None:
            failures.append(f"{spec}: {path} missing from fresh run")
            continue
        if direction == "lower":
            ok = fresh_v <= committed_v * TOLERANCE
        else:
            ok = fresh_v >= committed_v / TOLERANCE
        status = "ok" if ok else "REGRESSED"
        print(f"{spec}: {path} committed={committed_v:.4g} "
              f"fresh={fresh_v:.4g} ({direction} is better) {status}")
        if not ok:
            failures.append(
                f"{spec}: {path} regressed >20%: committed {committed_v:.4g} "
                f"-> fresh {fresh_v:.4g}")
    return failures


def main() -> int:
    argv = sys.argv[1:]
    committed_dir = "."
    if "--dir" in argv:
        i = argv.index("--dir")
        if i + 1 >= len(argv):
            print("usage: --dir requires a directory argument",
                  file=sys.stderr)
            return 2
        committed_dir = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    suites = argv or GATED_SUITES
    failures: List[str] = []
    for name in suites:
        try:
            failures += check_suite(name, committed_dir)
        except Exception:                    # noqa: BLE001
            failures.append(f"{name}: check crashed")
            traceback.print_exc()
    if failures:
        print("\nbench-check FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nbench-check passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
