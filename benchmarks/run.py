"""Benchmark harness. One module per "table" (the paper is qualitative, so the
tables are: control-plane op costs, boundary-traffic locality, the roofline
table, kernel micro-benches, and reduced-config throughput).

Prints ``name,us_per_call,derived`` CSV (derived column empty where N/A).

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run control_plane roofline_bench
  PYTHONPATH=src python -m benchmarks.run --json control_plane

``--json`` additionally writes a machine-readable ``BENCH_<suite>.json`` per
suite (into --out-dir, default the current directory), so successive PRs can
track the perf trajectory. A suite that defines ``run_json()`` controls its
own payload (e.g. control_plane embeds its before/after scaling sweep);
otherwise the CSV rows are serialized.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

SUITES = ("control_plane", "pipeline_plane", "autoscale", "durability",
          "workloads", "observability", "collective_locality",
          "roofline_bench", "kernels_bench", "train_throughput")


def _rows_to_json(rows) -> dict:
    out = []
    for row in rows:
        n, v, d = (tuple(row) + ("",))[:3]
        out.append({"name": n, "us_per_call": v, "derived": d})
    return {"rows": out}


def main() -> int:
    argv = sys.argv[1:]
    as_json = "--json" in argv
    argv = [a for a in argv if a != "--json"]
    out_dir = "."
    if "--out-dir" in argv:
        i = argv.index("--out-dir")
        if i + 1 >= len(argv):
            print("usage: --out-dir requires a directory argument",
                  file=sys.stderr)
            return 2
        out_dir = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    picked = argv or SUITES
    failed = 0
    print("name,us_per_call,derived")
    for name in picked:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run()
            for row in rows:
                n, v, d = (tuple(row) + ("",))[:3]
                d = f"{d:.4g}" if isinstance(d, float) else d
                v = f"{v:.4g}" if isinstance(v, float) else v
                print(f"{name}.{n},{v},{d}", flush=True)
            if as_json:
                payload = (mod.run_json() if hasattr(mod, "run_json")
                           else _rows_to_json(rows))
                payload = {"suite": name, **payload}
                os.makedirs(out_dir, exist_ok=True)
                path = os.path.join(out_dir, f"BENCH_{name}.json")
                with open(path, "w") as f:
                    json.dump(payload, f, indent=2)
                print(f"# wrote {path}", flush=True)
        except Exception:                    # noqa: BLE001
            failed += 1
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
