"""Benchmark harness. One module per "table" (the paper is qualitative, so the
tables are: control-plane op costs, boundary-traffic locality, the roofline
table, kernel micro-benches, and reduced-config throughput).

Prints ``name,us_per_call,derived`` CSV (derived column empty where N/A).

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run control_plane roofline_bench
"""
from __future__ import annotations

import sys
import traceback

SUITES = ("control_plane", "collective_locality", "roofline_bench",
          "kernels_bench", "train_throughput")


def main() -> int:
    picked = sys.argv[1:] or SUITES
    failed = 0
    print("name,us_per_call,derived")
    for name in picked:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run():
                n, v, d = (row + ("",))[:3] if len(row) < 3 else row[:3]
                d = f"{d:.4g}" if isinstance(d, float) else d
                v = f"{v:.4g}" if isinstance(v, float) else v
                print(f"{name}.{n},{v},{d}", flush=True)
        except Exception:                    # noqa: BLE001
            failed += 1
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
