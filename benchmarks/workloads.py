"""Workloads on the plane: what the hybrid pipeline plane costs (and buys)
when the tasks are the real JAX train/serve workloads, not sim stubs.

Four blocks, three acceptance gates (ISSUE 8):

  * ``overhead``      — DETERMINISTIC: broker+taskdb RPCs per executed task
    for a wide instant-handler DAG (the pure control-plane price of running
    a task through scheduler -> broker -> worker -> taskdb). Host-independent
    counts; this is the ``workloads:overhead`` part CI gates.
  * ``overhead_wall`` — gate (a): wall-clock for a 4-stage same-family train
    chain THROUGH the plane (warm compiled-step cache) vs one bare
    ``Trainer.run()`` doing the identical total steps. Both sides pay one
    model build + jit compile; the plane adds scheduling, queue hops and
    taskdb commits. Gate: ratio <= 1.3x.
  * ``cache``         — gate (b): wall-clock for a 12-stage same-family train
    DAG, cold (``step_cache=0``: every task rebuilds + re-jits a Trainer —
    the seed's behavior) vs warm (``step_cache=4``: one build, 11 rebinds).
    Gate: >= 3x.
  * ``placement``     — gate (c): makespan of a mixed compute/IO DAG over a
    2-tier fleet (accel-tier + cheap-io-tier clusters), naive least-load
    (``cost_aware=False``, every task in the shared default queue) vs
    roofline-cost-aware steering (``cost_aware=True``: compute-bound tasks
    ride the ``accel`` queue, IO-bound the ``cheap-io`` queue). Tasks carry
    explicit cost vectors (the committed-artifact path); the makespan is
    computed deterministically from the ACTUAL terminal taskdb placements
    with a fixed service-time table (ticks per kind x tier), so the gain is
    host-independent. Gate: >= 1.5x.

Wall-clock blocks vary with the host; only ``make bench-check`` (full) gates
them. The ``overhead`` block is deterministic and CI-gated via
``workloads:overhead`` (see benchmarks/check.py's suite:part specs).
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.pipelines import DAG, Task, HybridComposer

# same-family train shape shared by every wall-clock block (reduced config:
# the compile cost is real, the steps are CPU-sized)
TRAIN_KW = dict(arch="qwen3-0.6b", seq_len=16, global_batch=2, mode="sync")
CHAIN_STAGES = 4
CHAIN_STEPS = 30
CACHE_STAGES = 12
CACHE_STEPS = 6

OVERHEAD_TASKS = 512
WORKER_BATCH = 64

# placement sim: ticks one task occupies a worker, per kind x hosting tier
SERVICE_TICKS = {"sim_train": {"accel": 1, "cheap-io": 6},
                 "sim_etl": {"cheap-io": 1, "accel": 2}}
# explicit cost vectors (the committed dry-run artifact path): intensity
# 1000 flops/HBM-byte >> MACHINE_BALANCE -> compute-bound -> accel tier;
# zero flops -> IO-bound -> cheap tier
SIM_COSTS = {"sim_train": {"flops": 1e12, "hbm_bytes": 1e9},
             "sim_etl": {"io_bytes": 1e9}}
N_MIXED = 24                    # per kind; 48 tasks total
PLACEMENT_BATCH = 2             # small pulls so naive spreads across the fleet


def _train_plane() -> ManagementPlane:
    plane = ManagementPlane(message_log_limit=1_000, op_log_limit=1_000)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("compute-a")
    return plane


def _chain(n: int, steps: int) -> DAG:
    tasks = [Task(f"s{i}", kind="train",
                  payload={**TRAIN_KW, "steps": steps},
                  upstream=(f"s{i - 1}",) if i else ())
             for i in range(n)]
    return DAG("chain", tasks)


# --------------------------------------------------------------- gate (a)
def run_overhead_wall() -> dict:
    """Plane-overhead ratio: a same-family train chain through the hybrid
    plane (warm cache) vs one bare Trainer doing the identical step count."""
    from repro.runtime.train_loop import Trainer, TrainJobConfig

    t0 = time.perf_counter()
    tr = Trainer(TrainJobConfig(steps=CHAIN_STAGES * CHAIN_STEPS, **TRAIN_KW))
    tr.run()
    bare = time.perf_counter() - t0

    plane = _train_plane()
    comp = HybridComposer(plane, workers={"compute-a": ["w0"]},
                          worker_batch=WORKER_BATCH, step_cache=4)
    comp.add_dag(_chain(CHAIN_STAGES, CHAIN_STEPS))
    t0 = time.perf_counter()
    ok = comp.run_dag("chain", max_ticks=CHAIN_STAGES * 4 + 100)
    through_plane = time.perf_counter() - t0
    ratio = through_plane / max(bare, 1e-9)
    return {
        "label": (f"{CHAIN_STAGES}-stage train chain through the plane vs "
                  f"bare Trainer.run(), {CHAIN_STAGES * CHAIN_STEPS} steps"),
        "bare_wall_s": bare, "plane_wall_s": through_plane,
        "tasks": CHAIN_STAGES, "steps_per_task": CHAIN_STEPS,
        "plane_overhead_ratio_raw": ratio,
        "ok": bool(ok) and ratio <= 1.3,
        # gate (a): <= 1.3. The GATED value floors at 1.0: a lucky sub-1.0
        # measurement (compile-time jitter) must not tighten the committed
        # baseline below what any honest re-run can meet — with the floor,
        # bench-check's 1.2x tolerance gates fresh runs at ~the issue gate.
        "flatness": {"plane_overhead_ratio": max(ratio, 1.0)},
    }


# --------------------------------------------------------------- gate (b)
def _run_cache_dag(step_cache: int) -> dict:
    plane = _train_plane()
    comp = HybridComposer(plane, workers={"compute-a": ["w0"]},
                          worker_batch=WORKER_BATCH, step_cache=step_cache)
    comp.add_dag(_chain(CACHE_STAGES, CACHE_STEPS))
    t0 = time.perf_counter()
    ok = comp.run_dag("chain", max_ticks=CACHE_STAGES * 4 + 100)
    wall = time.perf_counter() - t0
    worker = comp.workers[0]
    cache = worker._trainer_cache
    return {"step_cache": step_cache, "ok": bool(ok), "wall_s": wall,
            "cache_stats": cache.stats() if cache is not None else None}


def run_cache() -> dict:
    """Compiled-step cache gain on a 12-stage same-family DAG: cold rebuilds
    (and re-jits) a Trainer per task; warm builds once and rebinds."""
    cold = _run_cache_dag(step_cache=0)
    warm = _run_cache_dag(step_cache=4)
    gain = cold["wall_s"] / max(warm["wall_s"], 1e-9)
    return {
        "label": (f"{CACHE_STAGES}-stage same-family train DAG, "
                  f"cold per-task builds vs warm compiled-step cache"),
        "cold": cold, "warm": warm,
        "ok": cold["ok"] and warm["ok"] and gain >= 3.0,
        "gains": {"compiled_step_cache_gain": gain},    # gate (b): >= 3
    }


# --------------------------------------------------------------- gate (c)
def _mixed_dag() -> DAG:
    # interleaved so naive FIFO distribution hands every worker a mix
    tasks = []
    for i in range(N_MIXED):
        tasks.append(Task(f"train{i}", kind="sim_train",
                          cost=SIM_COSTS["sim_train"]))
        tasks.append(Task(f"etl{i}", kind="sim_etl",
                          cost=SIM_COSTS["sim_etl"]))
    return DAG("mixed", tasks)


def run_placement_fleet(cost_aware: bool) -> dict:
    """One mixed-DAG execution over the 2-tier fleet; makespan is derived
    from the terminal taskdb rows (which worker ran what) with the fixed
    SERVICE_TICKS table — fully deterministic."""
    plane = ManagementPlane(message_log_limit=1_000, op_log_limit=1_000)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("accel-a",
                      local_plane=SimLocalPlane(caps=("cpu", "accel")))
    plane.add_cluster("cheap-a",
                      local_plane=SimLocalPlane(caps=("cpu", "cheap-io")))
    tier_of = {}
    workers: Dict[str, list] = {"accel-a": [], "cheap-a": []}
    queues = {}
    for i in range(2):
        wa, wc = f"wa{i}", f"wc{i}"
        workers["accel-a"].append(wa)
        workers["cheap-a"].append(wc)
        tier_of[wa], tier_of[wc] = "accel", "cheap-io"
        # steered queue names are the steering tags themselves (the tasks
        # declare no other requires); every worker also covers default
        queues[wa] = ("accel", "default")
        queues[wc] = ("cheap-io", "default")

    def setup(worker):
        worker.register("sim_train", lambda p: {"ok": 1})
        worker.register("sim_etl", lambda p: {"ok": 1})

    comp = HybridComposer(plane, workers=workers, worker_queues=queues,
                          worker_batch=PLACEMENT_BATCH, worker_setup=setup,
                          cost_aware=cost_aware)
    comp.add_dag(_mixed_dag())
    ok = comp.run_dag("mixed", max_ticks=N_MIXED * 8 + 200)

    busy: Dict[str, int] = {}
    misrouted = 0
    for (dag, name, _try), row in comp.taskdb.rows.items():
        if row.get("status") != "success":
            continue
        kind = "sim_train" if name.startswith("train") else "sim_etl"
        tier = tier_of[row["worker"]]
        busy[row["worker"]] = busy.get(row["worker"], 0) \
            + SERVICE_TICKS[kind][tier]
        best_tier = "accel" if kind == "sim_train" else "cheap-io"
        if cost_aware and tier != best_tier:
            misrouted += 1
    return {
        "cost_aware": cost_aware, "ok": bool(ok) and misrouted == 0,
        "tasks": 2 * N_MIXED,
        "makespan_ticks": max(busy.values()) if busy else 0,
        "busy_ticks_per_worker": dict(sorted(busy.items())),
        "misrouted": misrouted,
    }


def run_placement() -> dict:
    naive = run_placement_fleet(cost_aware=False)
    aware = run_placement_fleet(cost_aware=True)
    gain = naive["makespan_ticks"] / max(aware["makespan_ticks"], 1)
    return {
        "label": ("mixed compute/IO DAG over a 2-tier fleet: naive "
                  "least-load vs roofline-cost-aware queue steering"),
        "naive": naive, "cost_aware": aware,
        "ok": naive["ok"] and aware["ok"] and gain >= 1.5,
        "gains": {"cost_aware_makespan_gain": gain},    # gate (c): >= 1.5
    }


# --------------------------------------------------- deterministic CI part
def run_json_overhead() -> dict:
    """Control-plane RPCs per executed task — deterministic counts (the
    ``workloads:overhead`` CI gate; the wall-clock ratio lives in
    ``overhead_wall`` and is only gated by the full ``make bench-check``)."""
    plane = _train_plane()

    def setup(worker):
        worker.register("sim", lambda p: {"ok": 1})

    comp = HybridComposer(plane, workers={"compute-a": ["w0"]},
                          worker_batch=WORKER_BATCH, worker_setup=setup)
    tasks = [Task("root", kind="sim")]
    tasks += [Task(f"t{i}", kind="sim", upstream=("root",))
              for i in range(OVERHEAD_TASKS - 1)]
    comp.add_dag(DAG("wide", tasks))
    ok = comp.run_dag("wide", max_ticks=OVERHEAD_TASKS // WORKER_BATCH + 200)
    rpcs = (sum(comp.broker.op_counts.values())
            + sum(comp.taskdb.op_counts.values()))
    return {
        "label": ("broker+taskdb RPCs per executed task, wide "
                  f"{OVERHEAD_TASKS}-task instant-handler DAG"),
        "tasks": OVERHEAD_TASKS, "ok": bool(ok),
        "broker_rpcs": sum(comp.broker.op_counts.values()),
        "taskdb_rpcs": sum(comp.taskdb.op_counts.values()),
        "flatness": {"plane_rpcs_per_task": rpcs / OVERHEAD_TASKS},
    }


_CACHE: dict = {}


def run_sweep() -> dict:
    if "sweep" in _CACHE:
        return _CACHE["sweep"]
    result = {
        "label": "train/serve workloads on the hybrid pipeline plane",
        "overhead": run_json_overhead(),
        "placement": run_placement(),
        "overhead_wall": run_overhead_wall(),
        "cache": run_cache(),
    }
    _CACHE["sweep"] = result
    return result


def run() -> List[tuple]:
    sweep = run_sweep()
    ov, ow = sweep["overhead"], sweep["overhead_wall"]
    ca, pl = sweep["cache"], sweep["placement"]
    return [
        ("plane_rpcs_per_task", ov["flatness"]["plane_rpcs_per_task"]),
        ("plane_overhead_ratio", ow["flatness"]["plane_overhead_ratio"]),
        ("bare_train_wall_s", ow["bare_wall_s"]),
        ("plane_train_wall_s", ow["plane_wall_s"]),
        ("cache_cold_wall_s", ca["cold"]["wall_s"]),
        ("cache_warm_wall_s", ca["warm"]["wall_s"]),
        ("compiled_step_cache_gain", ca["gains"]["compiled_step_cache_gain"]),
        ("naive_makespan_ticks", float(pl["naive"]["makespan_ticks"])),
        ("cost_aware_makespan_ticks",
         float(pl["cost_aware"]["makespan_ticks"])),
        ("cost_aware_makespan_gain",
         pl["gains"]["cost_aware_makespan_gain"]),
    ]


def run_json() -> dict:
    """Structured payload for ``benchmarks/run.py --json``."""
    return run_sweep()
