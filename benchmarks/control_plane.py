"""Control-plane op latencies + scaling (the paper has no perf tables; these are
the management-plane numbers a production deployment is sized with).

  * register/discover/dispatch/heartbeat wall-time per op at 2..64 clusters
  * configuration-phase cost: Algorithm 5 runtime + messages for growing S
  * failure recovery: ticks from partition to re-dispatch
"""
from __future__ import annotations

import time
from typing import Callable, List

from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.core.service_graph import AppSpec, Pod, Service


def _time_us(fn: Callable[[], None], n: int = 50) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def bench_plane_ops(n_clusters: int = 8) -> List[tuple]:
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    for i in range(n_clusters - 1):
        plane.add_cluster(f"c{i}")
    rows = []
    agent = plane.agents["c0"]
    rows.append((f"overwatch_put[{n_clusters}]",
                 _time_us(lambda: agent.ow.put("/bench/k", {"v": 1}))))
    rows.append((f"overwatch_get[{n_clusters}]",
                 _time_us(lambda: agent.ow.get("/bench/k"))))
    rows.append((f"heartbeat[{n_clusters}]",
                 _time_us(lambda: agent.heartbeat())))
    jid = [0]

    def dispatch():
        jid[0] += 1
        plane.submit_job("sim", steps=1, job_id=f"bench-{jid[0]}")

    rows.append((f"dispatch[{n_clusters}]", _time_us(dispatch, n=20)))
    return rows


def bench_configuration_phase(n_services: int = 16, n_clusters: int = 4):
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    clusters = ["master"] + [f"c{i}" for i in range(n_clusters - 1)]
    for c in clusters[1:]:
        plane.add_cluster(c)
    pods, services, partition = [], [], {}
    for k in range(n_services):
        host = clusters[k % len(clusters)]
        sname, bname = f"svc{k}", f"back{k}"
        services.append(Service(sname, 7000 + k, (bname,)))
        pods.append(Pod(bname, needs=()))
        partition[bname] = host
        cname = f"cons{k}"
        pods.append(Pod(cname, needs=(sname,)))
        partition[cname] = clusters[(k + 1) % len(clusters)]
    spec = AppSpec(tuple(services), tuple(pods), partition)
    t0 = time.perf_counter()
    plane.upload_spec(spec)
    dt = (time.perf_counter() - t0) * 1e6
    return [(f"configure[{n_services}svc,{n_clusters}cl]", dt)]


def bench_failure_recovery() -> List[tuple]:
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("c0", local_plane=SimLocalPlane(rate=0.2))
    plane.add_cluster("c1", local_plane=SimLocalPlane(rate=0.2))
    jid = plane.submit_job("sim", steps=100)
    plane.tick(n=3)
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    plane.fabric.partition_cluster(placed)
    ticks = 0
    while ticks < 100:
        plane.tick()
        ticks += 1
        st = plane.overwatch.handle(
            {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]
        if st["cluster"] != placed:
            break
    return [("recovery_ticks_to_redispatch", float(ticks))]


def run() -> List[tuple]:
    rows = []
    for n in (2, 8, 32):
        rows += bench_plane_ops(n)
    rows += bench_configuration_phase(8, 4)
    rows += bench_configuration_phase(32, 4)
    rows += bench_failure_recovery()
    return rows
