"""Control-plane op latencies + scaling (the paper has no perf tables; these are
the management-plane numbers a production deployment is sized with).

  * register/discover/dispatch/heartbeat wall-time per op at 2..64 clusters
  * scaling sweep: dispatch / overwatch-range / heartbeat / batched-submit
    per-op latency at 2..256 clusters with a keyspace preloaded to ~20 jobs
    per cluster (5k+ jobs at the top of the sweep) — the hot-path overhaul's
    acceptance gate is that per-op latency stays flat (within 2x) from 32 to
    256 clusters
  * sharded sweep: the same point measured at 32 -> 1024 clusters with a 4-shard
    overwatch + coalesced watch delivery and ~50k preloaded jobs at the top —
    the sharding overhaul's gate is dispatch within ~1.5x of the 32-cluster
    point across that 32x scale-up
  * recovery storm: watch-callback invocations when a cluster holding 5k jobs
    dies — O(mutations) with synchronous notify, O(watchers) with coalesced
    batch delivery
  * locality block: cross-boundary bytes per remote telemetry/depth read,
    round-trip baseline vs per-cluster replica fan-out — DETERMINISTIC byte
    counts, gated in CI (``benchmarks.check control_plane:locality``); the
    fan-out's acceptance bar is a >= 5x bytes/read cut at 256 clusters
  * notify block: cross-boundary bytes per delivered watch EVENT, per-watcher
    refresh round trips vs the replica-fed watch plane (N watchers share one
    shipped envelope) — gated in CI (``control_plane:notify``); acceptance
    bar is a >= 5x bytes/event cut at 64+ clusters, O(1) in watcher count
  * configuration-phase cost: Algorithm 5 runtime + messages for growing S
  * failure recovery: ticks from partition to re-dispatch

``run_json()`` emits the sweeps plus the frozen pre-overhaul baseline
(SEED_BASELINE, measured on the seed implementation whose per-op cost grew
with total keyspace size) — that is what ``benchmarks/run.py --json``
records into BENCH_control_plane.json.
"""
from __future__ import annotations

import gc
import time
from typing import Callable, List

from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.core.service_graph import AppSpec, Pod, Service

SWEEP_SCALES = (2, 8, 32, 64, 128, 256)
JOBS_PER_CLUSTER = 20
# sharded sweep: 4 shards + coalesced watches, 1024 clusters / ~64k jobs on
# top — pushed past the 50k point once replica fan-out stopped remote readers
# from hammering the primary
SHARDED_SWEEP_SCALES = (32, 256, 1024)
SHARDED_JOBS_PER_CLUSTER = 64            # 1024 * 64 = 65,536 jobs
SHARDED_OW_SHARDS = 4

# locality block: remote telemetry/depth readers, replica fan-out off vs on
LOCALITY_SCALES = (8, 64, 256)
LOCALITY_TICKS = 6                       # heartbeat/ship rounds measured
# remote reads per cluster per tick: agent telemetry probes + per-queue
# worker depth checks + fleet observers — the many-readers regime the
# fan-out exists for (one ship amortizes across ALL of a cluster's readers)
LOCALITY_READS_PER_TICK = 16
LOCALITY_QUEUES = 8                      # published /queues/<name> rows
# notify block: remote watch subscribers per cluster — N watchers share one
# shipped envelope under the replica-fed watch plane, vs one bounded-stale
# refresh round trip per watcher per tick without it
NOTIFY_WATCHERS = 8

# Pre-overhaul numbers (seed implementation, same sweep, same machine class):
# per-op cost grew ~14x from 32 to 256 clusters because every dispatch sorted
# the entire keyspace several times. Frozen here so BENCH_control_plane.json
# always carries the before/after comparison. NOTE: these were measured with
# single-run means (the seed harness); current sweeps use best-of-3 minima,
# so cross-compare the within-sweep growth RATIOS, not absolute microseconds.
SEED_BASELINE = {
    "label": "before (seed, full-keyspace scans)",
    "rows": [
        {"clusters": 2, "jobs": 40, "overwatch_range_us": 15.6,
         "dispatch_us": 63.6, "heartbeat_us": 18.8},
        {"clusters": 8, "jobs": 160, "overwatch_range_us": 59.7,
         "dispatch_us": 160.8, "heartbeat_us": 19.3},
        {"clusters": 32, "jobs": 640, "overwatch_range_us": 184.7,
         "dispatch_us": 655.6, "heartbeat_us": 17.7},
        {"clusters": 64, "jobs": 1280, "overwatch_range_us": 260.4,
         "dispatch_us": 1196.7, "heartbeat_us": 20.7},
        {"clusters": 128, "jobs": 2560, "overwatch_range_us": 1122.3,
         "dispatch_us": 3435.4, "heartbeat_us": 32.3},
        {"clusters": 256, "jobs": 5120, "overwatch_range_us": 2738.5,
         "dispatch_us": 8935.6, "heartbeat_us": 39.8},
    ],
}


def _time_us(fn: Callable[[], None], n: int = 50, repeats: int = 3,
             per_call: int = 1) -> float:
    """Best-of-``repeats`` mean over ``n`` calls, GC paused while timing;
    ``per_call`` divides further when ``fn`` itself performs a batch of ops.

    One scheduler hiccup inside a single 50-call chunk would dominate the
    microsecond-scale numbers; and at the 1024-cluster/50k-job point the heap
    holds millions of live objects, so a gen-2 GC pass landing inside a chunk
    would wreck the flatness ratios with cost that is neither per-op nor
    scale-dependent in the algorithmic sense being measured.
    """
    best = float("inf")
    gc_was = gc.isenabled()
    gc.disable()
    try:
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _ in range(n):
                fn()
            best = min(best, (time.perf_counter() - t0) / (n * per_call) * 1e6)
    finally:
        if gc_was:
            gc.enable()
    return best


def bench_plane_ops(n_clusters: int = 8) -> List[tuple]:
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    for i in range(n_clusters - 1):
        plane.add_cluster(f"c{i}")
    rows = []
    agent = plane.agents["c0"]
    rows.append((f"overwatch_put[{n_clusters}]",
                 _time_us(lambda: agent.ow.put("/bench/k", {"v": 1}))))
    rows.append((f"overwatch_get[{n_clusters}]",
                 _time_us(lambda: agent.ow.get("/bench/k"))))
    rows.append((f"heartbeat[{n_clusters}]",
                 _time_us(lambda: agent.heartbeat())))
    jid = [0]

    def dispatch():
        jid[0] += 1
        plane.submit_job("sim", steps=1, job_id=f"bench-{jid[0]}")

    rows.append((f"dispatch[{n_clusters}]", _time_us(dispatch, n=20)))
    return rows


# ------------------------------------------------------------- scaling sweep
def sweep_point(n_clusters: int,
                jobs_per_cluster: int = JOBS_PER_CLUSTER,
                ow_shards: int = 1,
                coalesce_watches: bool = False) -> dict:
    """Per-op latency at one scale, with the keyspace preloaded the way a
    long-running deployment looks (a placement + status row per job)."""
    plane = ManagementPlane(message_log_limit=10_000, op_log_limit=10_000,
                            ow_shards=ow_shards,
                            coalesce_watches=coalesce_watches)
    plane.add_cluster("master", is_master=True)
    for i in range(n_clusters - 1):
        plane.add_cluster(f"c{i}")
    names = ["master"] + [f"c{i}" for i in range(n_clusters - 1)]
    n_jobs = n_clusters * jobs_per_cluster
    for j in range(n_jobs):
        c = names[j % len(names)]
        plane.overwatch.handle(
            {"op": "put", "key": f"/jobs/pre-{j}/placement",
             "value": {"cluster": c,
                       "job": {"job_id": f"pre-{j}", "kind": "sim",
                               "steps": 10, "tags": {}, "payload": {}},
                       "clock": 0.0}})
        plane.overwatch.handle(
            {"op": "put", "key": f"/jobs/pre-{j}/status",
             "value": {"cluster": c, "status": "running", "progress": 1.0,
                       "rate": 1.0, "clock": 0.0}})
    agent = plane.agents["c0"]
    row = {"clusters": n_clusters, "jobs": n_jobs}
    agent.ow.range("/clusters/master")       # warm: one-time index compaction
    row["overwatch_range_us"] = _time_us(
        lambda: agent.ow.range("/clusters/master"), n=100)
    jid = [0]

    def dispatch():
        jid[0] += 1
        plane.submit_job("sim", steps=1, job_id=f"bench-{jid[0]}")

    # warm every dispatch relay channel (round-robin covers each cluster once)
    # so the timed region measures steady-state dispatch, not channel setup
    plane.submit_jobs([{"kind": "sim", "steps": 1, "job_id": f"warm-{k}"}
                       for k in range(n_clusters)])
    plane.overwatch.sweep()                  # drain the warm batch's events
    row["dispatch_us"] = _time_us(dispatch, n=50)

    def submit_batch():                      # batched admission (submit_many)
        jid[0] += 1
        plane.submit_jobs([{"kind": "sim", "steps": 1,
                            "job_id": f"batch-{jid[0]}-{k}"}
                           for k in range(32)])

    # best-of-6 single batches: a 32-job batch is small enough that one
    # hiccup would dominate the per-job number
    row["submit_many_per_job_us"] = _time_us(submit_batch, n=1, repeats=6,
                                             per_call=32)
    row["heartbeat_us"] = _time_us(agent.heartbeat, n=50)
    return row


_SWEEP_CACHE: dict = {}


def run_sweep(scales=SWEEP_SCALES) -> dict:
    # memoized per-process: --json mode consumes the sweep twice (CSV rows +
    # JSON payload) and the 256-cluster point is the expensive part; caching
    # also keeps the printed CSV and the recorded JSON from disagreeing
    key = tuple(scales)
    if key in _SWEEP_CACHE:
        return _SWEEP_CACHE[key]
    rows = [_median_point(n, JOBS_PER_CLUSTER, ow_shards=1,
                          coalesce_watches=False) for n in scales]
    by_n = {r["clusters"]: r for r in rows}
    flat = {}
    if 32 in by_n and 256 in by_n:
        for metric in ("dispatch_us", "overwatch_range_us"):
            flat[metric + "_ratio_256_over_32"] = (
                by_n[256][metric] / max(by_n[32][metric], 1e-9))
    result = {"label": "after (indexed overwatch + cached dispatcher views)",
              "rows": rows, "flatness": flat}
    _SWEEP_CACHE[key] = result
    return result


def _median_point(n: int, jobs_per_cluster: int, ow_shards: int,
                  trials: int = 5, coalesce_watches: bool = True) -> dict:
    """Per-metric median over independently constructed planes: host jitter
    on shared machines spans whole seconds, so repeating inside one plane
    (best-of chunks) cannot filter a slow window that covers a whole point.
    Both sweeps (plain and sharded) run through this — single-plane points
    made the plain sweep's flatness ratios swing ±30% run to run."""
    samples = [sweep_point(n, jobs_per_cluster, ow_shards=ow_shards,
                           coalesce_watches=coalesce_watches)
               for _ in range(trials)]
    row = dict(samples[0])
    for metric in ("overwatch_range_us", "dispatch_us",
                   "submit_many_per_job_us", "heartbeat_us"):
        row[metric] = sorted(s[metric] for s in samples)[trials // 2]
    return row


def run_sharded_sweep(scales=SHARDED_SWEEP_SCALES,
                      jobs_per_cluster=SHARDED_JOBS_PER_CLUSTER,
                      ow_shards=SHARDED_OW_SHARDS) -> dict:
    """The sharding overhaul's gate: with a 4-shard overwatch and coalesced
    watch delivery, per-op dispatch cost at 1024 clusters / ~50k jobs stays
    within ~1.5x of the 32-cluster point."""
    key = ("sharded", tuple(scales), jobs_per_cluster, ow_shards)
    if key in _SWEEP_CACHE:
        return _SWEEP_CACHE[key]
    rows = [_median_point(n, jobs_per_cluster, ow_shards) for n in scales]
    by_n = {r["clusters"]: r for r in rows}
    flat = {}
    lo, hi = min(scales), max(scales)
    if lo in by_n and hi in by_n:
        for metric in ("dispatch_us", "overwatch_range_us",
                       "submit_many_per_job_us"):
            flat[f"{metric}_ratio_{hi}_over_{lo}"] = (
                by_n[hi][metric] / max(by_n[lo][metric], 1e-9))
    result = {"label": f"sharded ({ow_shards} shards, coalesced watches)",
              "ow_shards": ow_shards, "rows": rows, "flatness": flat}
    _SWEEP_CACHE[key] = result
    return result


# ------------------------------------------------------------ locality block
def bench_locality_point(n_clusters: int, fanout: bool,
                         ticks: int = LOCALITY_TICKS,
                         reads_per_tick: int = LOCALITY_READS_PER_TICK) -> dict:
    """Cross-boundary bytes per remote read with and without replica fan-out.

    Workload: every remote cluster's agent probes fleet telemetry and the
    published queue-depth view ``reads_per_tick`` times per tick while the
    fleet heartbeats (every telemetry row churns every tick — the worst case
    for delta shipping). Byte counts are DETERMINISTIC (simulated fabric, both
    request and response accounted), so the reduction ratio is CI-gateable.

    ``fanout=False``: every read round-trips through gateway channels to the
    primary and hauls the full directory back across the boundary.
    ``fanout=True``: the master ships each cluster one coalesced delta
    envelope per tick and all in-bound reads are replica-local — the shipped
    envelopes are the only read-path cross-boundary traffic.
    """
    plane = ManagementPlane(message_log_limit=0, op_log_limit=1_000,
                            coalesce_watches=True, replica_fanout=fanout)
    plane.add_cluster("master", is_master=True)
    for i in range(n_clusters - 1):
        plane.add_cluster(f"c{i}")
    ow = plane.agents["master"].ow
    for k in range(LOCALITY_QUEUES):     # a composer-like depth publisher
        ow.put(f"/queues/fam{k}", {"ready": 10 * (k + 1), "inflight": k,
                                   "clock": 0.0})
    plane.tick(n=2)                      # settle; first ships land
    fabric = plane.fabric
    base_cross = fabric.cross_cluster_bytes()
    base_ships = dict(plane.shipper.stats) if fanout else {}
    agents = [plane.agents[f"c{i}"] for i in range(n_clusters - 1)]
    reads = 0
    per_agent = max(reads_per_tick // 2, 1)
    for _ in range(ticks):
        plane.tick()
        for agent in agents:
            for _ in range(per_agent):
                agent.fleet_telemetry(max_lag=2.0)
                agent.queue_depths(max_lag=2.0)
                reads += 2
    cross = fabric.cross_cluster_bytes() - base_cross
    row = {"clusters": n_clusters, "reads": reads,
           "cross_bytes": cross,
           "cross_bytes_per_read": cross / max(reads, 1),
           "locality_ratio": fabric.locality_ratio()}
    if fanout:
        # window-scoped like cross_bytes, so the recorded ship traffic is
        # directly comparable to (and bounded by) the cross-byte delta
        row["replica_ships"] = {k: v - base_ships.get(k, 0)
                                for k, v in plane.shipper.stats.items()}
        # a healthy fan-out serves every in-bound read locally: primary
        # fallbacks (out-of-bound replica) must stay rare. Surfaced via the
        # fabric's named counter and FAILED (ok=False trips the CI gate's
        # incomplete-run check) if they stop being rare.
        fallbacks = fabric.stats["fallback_reads"]
        row["fallback_reads"] = fallbacks
        row["ok"] = fallbacks <= max(1, reads // 100)
    return row


def run_locality(scales=LOCALITY_SCALES) -> dict:
    """Before/after fan-out at each scale + the gated reduction ratios.

    The ``gains`` entries (HIGHER is better, guarded by ``make bench-check``
    and the CI ``control_plane:locality`` gate) are the cross-boundary
    bytes-per-read reduction factors; the acceptance bar for the overhaul is
    >= 5x at the 256-cluster point.
    """
    key = ("locality", tuple(scales))
    if key in _SWEEP_CACHE:
        return _SWEEP_CACHE[key]
    rows = []
    gains = {}
    for n in scales:
        baseline = bench_locality_point(n, fanout=False)
        fanout = bench_locality_point(n, fanout=True)
        reduction = (baseline["cross_bytes_per_read"]
                     / max(fanout["cross_bytes_per_read"], 1e-9))
        rows.append({"clusters": n, "baseline": baseline, "fanout": fanout,
                     "cross_bytes_per_read_reduction": reduction})
        # the locality_ratio of each mode is RECORDED in the rows but not
        # gated: replica-local reads bypass the fabric entirely (0 bytes on
        # either ledger), so fan-out lowers the ratio while lowering absolute
        # cross traffic — bytes/read is the honest gate
        gains[f"cross_bytes_per_read_reduction_{n}"] = reduction
    result = {"label": "remote telemetry/depth reads: round-trip vs "
                       "per-cluster replica fan-out",
              "reads_per_cluster_per_tick": LOCALITY_READS_PER_TICK,
              "ticks": LOCALITY_TICKS, "rows": rows, "gains": gains}
    _SWEEP_CACHE[key] = result
    return result


def run_json_locality() -> dict:
    """The locality block alone — the deterministic CI gate's entry point
    (``benchmarks.check control_plane:locality``) skips the wall-clock
    sweeps entirely."""
    return run_locality()


# -------------------------------------------------------------- notify block
def bench_notify_point(n_clusters: int, fanout: bool,
                       watchers: int = NOTIFY_WATCHERS,
                       ticks: int = LOCALITY_TICKS) -> dict:
    """Cross-boundary bytes per delivered watch EVENT with and without the
    replica-fed watch plane.

    Workload: ``watchers`` observers on every remote cluster follow the
    published ``/queues/`` directory while every row churns every tick (the
    composer's depth-publish worst case). Byte counts are DETERMINISTIC, so
    the reduction ratio is CI-gateable.

    ``fanout=False``: the pre-overhaul remote-observer protocol — there is
    no cross-boundary subscription, so each watcher keeps its view current
    with one bounded-staleness range round trip per tick, hauling the
    directory across the boundary per watcher.
    ``fanout=True``: every watcher subscribes on its cluster's replica
    (``agent.watch_local``); the ONE shipped delta envelope per cluster per
    sweep feeds all of them, so notify bytes are O(1) in the watcher count
    — the cross-boundary cost of N watchers equals that of zero. The feed is
    scoped to the watched vocabulary (``/queues/`` plus ``/clusters/``
    membership) so the watch plane is charged only for what the observers
    subscribe to — the locality block measures the full default feed.
    """
    plane = ManagementPlane(message_log_limit=0, op_log_limit=1_000,
                            coalesce_watches=True, replica_fanout=fanout,
                            replica_prefixes=("/clusters/", "/queues/"))
    plane.add_cluster("master", is_master=True)
    for i in range(n_clusters - 1):
        plane.add_cluster(f"c{i}")
    ow = plane.agents["master"].ow
    for k in range(LOCALITY_QUEUES):
        ow.put(f"/queues/fam{k}", {"ready": 10 * (k + 1), "inflight": k,
                                   "clock": 0.0})
    plane.tick(n=2)                      # settle; first ships land
    fabric = plane.fabric
    agents = [plane.agents[f"c{i}"] for i in range(n_clusters - 1)]
    delivered = [0]
    if fanout:
        def observe(events):
            delivered[0] += len(events)
        for agent in agents:
            for _ in range(watchers):
                agent.watch_local("/queues/", observe, batch=True)
    base_cross = fabric.cross_cluster_bytes()
    base_ships = dict(plane.shipper.stats) if fanout else {}
    for t in range(ticks):
        for k in range(LOCALITY_QUEUES):     # every watched row churns
            ow.put(f"/queues/fam{k}", {"ready": 10 * (k + 1) + t + 1,
                                       "inflight": k, "clock": float(t)})
        plane.tick()
        if not fanout:
            for agent in agents:
                for _ in range(watchers):
                    items = agent.ow.range_stale("/queues/", max_lag=2.0)
                    delivered[0] += len(items)
    cross = fabric.cross_cluster_bytes() - base_cross
    events = delivered[0]
    row = {"clusters": n_clusters, "watchers_per_cluster": watchers,
           "events_delivered": events, "cross_bytes": cross,
           "cross_bytes_per_event": cross / max(events, 1)}
    if fanout:
        row["replica_ships"] = {k: v - base_ships.get(k, 0)
                                for k, v in plane.shipper.stats.items()}
        # subscribed watchers never read across the boundary at all — any
        # fallback here means the notify plane silently degraded to polling
        fallbacks = fabric.stats["fallback_reads"]
        row["fallback_reads"] = fallbacks
        row["ok"] = fallbacks == 0
    return row


def run_notify(scales=LOCALITY_SCALES) -> dict:
    """Per-watcher round trips vs the replica-fed watch plane at each scale.

    The ``gains`` entries (HIGHER is better, guarded by ``make bench-check``
    and the CI ``control_plane:notify`` gate) are the cross-boundary
    bytes-per-event reduction factors; the watch plane's acceptance bar is
    >= 5x at the 64- and 256-cluster points. The smallest scale also runs
    the fan-out side with ONE watcher per cluster: identical shipped bytes
    at 1 and ``NOTIFY_WATCHERS`` watchers is the recorded O(1)-in-watchers
    evidence (exact equality is asserted by tests/test_locality.py).
    """
    key = ("notify", tuple(scales))
    if key in _SWEEP_CACHE:
        return _SWEEP_CACHE[key]
    rows = []
    gains = {}
    for n in scales:
        baseline = bench_notify_point(n, fanout=False)
        fanout = bench_notify_point(n, fanout=True)
        reduction = (baseline["cross_bytes_per_event"]
                     / max(fanout["cross_bytes_per_event"], 1e-9))
        row = {"clusters": n, "baseline": baseline, "fanout": fanout,
               "cross_bytes_per_event_reduction": reduction}
        if n == min(scales):
            one = bench_notify_point(n, fanout=True, watchers=1)
            row["fanout_single_watcher_cross_bytes"] = one["cross_bytes"]
        rows.append(row)
        gains[f"notify_bytes_per_event_reduction_{n}"] = reduction
    result = {"label": "remote /queues/ watchers: per-watcher round trips "
                       "vs replica-fed watch plane",
              "watchers_per_cluster": NOTIFY_WATCHERS,
              "ticks": LOCALITY_TICKS, "rows": rows, "gains": gains}
    _SWEEP_CACHE[key] = result
    return result


def run_json_notify() -> dict:
    """The notify block alone — the deterministic CI gate's entry point
    (``benchmarks.check control_plane:notify``), no wall-clock sweeps."""
    return run_notify()


# ----------------------------------------------------------- recovery storm
def bench_recovery_storm(n_clusters: int = 32, n_jobs: int = 5000) -> dict:
    """Watch-callback invocations when a cluster holding ``n_jobs`` dies.

    Synchronous notify fires one callback per mutation (O(jobs)); coalesced
    delivery batches each flush round into one callback per watcher
    (O(watchers)). Both configs recover every job; only the delivery shape
    differs.
    """
    key = ("storm", n_clusters, n_jobs)
    if key in _SWEEP_CACHE:
        return _SWEEP_CACHE[key]
    out = {"jobs": n_jobs, "clusters": n_clusters}
    # both configs run the same shard count so the callback/timing delta
    # isolates the delivery mode, not sharding
    for label, coalesce in (("sync", False), ("coalesced", True)):
        plane = ManagementPlane(message_log_limit=10_000, op_log_limit=10_000,
                                ow_shards=SHARDED_OW_SHARDS,
                                coalesce_watches=coalesce)
        plane.add_cluster("master", is_master=True,
                          local_plane=SimLocalPlane(caps=("control",)))
        for i in range(n_clusters - 1):
            plane.add_cluster(f"c{i}")
        for j in range(n_jobs):
            plane.overwatch.handle(
                {"op": "put", "key": f"/jobs/pre-{j}/placement",
                 "value": {"cluster": "c0",
                           "job": {"job_id": f"pre-{j}", "kind": "sim",
                                   "steps": 10, "tags": {}, "payload": {}},
                           "clock": 0.0}})
            plane.overwatch.handle(
                {"op": "put", "key": f"/jobs/pre-{j}/status",
                 "value": {"cluster": "c0", "status": "running",
                           "progress": 1.0, "rate": 1.0, "clock": 0.0}})
        plane.tick(n=2)
        before = dict(plane.overwatch.watch_stats)
        plane.fabric.partition_cluster("c0")
        t0 = time.perf_counter()
        plane.tick(n=8)                      # lease expiry -> recovery storm
        dt = time.perf_counter() - t0
        after = plane.overwatch.watch_stats
        out[label] = {
            "watch_callbacks": after.get("callbacks", 0)
            - before.get("callbacks", 0),
            "watch_events": after.get("events", 0) - before.get("events", 0),
            "storm_s": dt,
        }
    _SWEEP_CACHE[key] = out
    return out


def bench_configuration_phase(n_services: int = 16, n_clusters: int = 4):
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    clusters = ["master"] + [f"c{i}" for i in range(n_clusters - 1)]
    for c in clusters[1:]:
        plane.add_cluster(c)
    pods, services, partition = [], [], {}
    for k in range(n_services):
        host = clusters[k % len(clusters)]
        sname, bname = f"svc{k}", f"back{k}"
        services.append(Service(sname, 7000 + k, (bname,)))
        pods.append(Pod(bname, needs=()))
        partition[bname] = host
        cname = f"cons{k}"
        pods.append(Pod(cname, needs=(sname,)))
        partition[cname] = clusters[(k + 1) % len(clusters)]
    spec = AppSpec(tuple(services), tuple(pods), partition)
    t0 = time.perf_counter()
    plane.upload_spec(spec)
    dt = (time.perf_counter() - t0) * 1e6
    return [(f"configure[{n_services}svc,{n_clusters}cl]", dt)]


def bench_failure_recovery() -> List[tuple]:
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("c0", local_plane=SimLocalPlane(rate=0.2))
    plane.add_cluster("c1", local_plane=SimLocalPlane(rate=0.2))
    jid = plane.submit_job("sim", steps=100)
    plane.tick(n=3)
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    plane.fabric.partition_cluster(placed)
    ticks = 0
    while ticks < 100:
        plane.tick()
        ticks += 1
        st = plane.overwatch.handle(
            {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]
        if st["cluster"] != placed:
            break
    return [("recovery_ticks_to_redispatch", float(ticks))]


def run() -> List[tuple]:
    rows = []
    for n in (2, 8, 32):
        rows += bench_plane_ops(n)
    for r in run_sweep()["rows"]:
        tag = f"[{r['clusters']}cl,{r['jobs']}jobs]"
        rows.append((f"sweep_dispatch{tag}", r["dispatch_us"]))
        rows.append((f"sweep_overwatch_range{tag}", r["overwatch_range_us"]))
        rows.append((f"sweep_heartbeat{tag}", r["heartbeat_us"]))
        rows.append((f"sweep_submit_many{tag}", r["submit_many_per_job_us"]))
    for r in run_sharded_sweep()["rows"]:
        tag = f"[{r['clusters']}cl,{r['jobs']}jobs,sharded]"
        rows.append((f"sweep_dispatch{tag}", r["dispatch_us"]))
        rows.append((f"sweep_overwatch_range{tag}", r["overwatch_range_us"]))
        rows.append((f"sweep_submit_many{tag}", r["submit_many_per_job_us"]))
    storm = bench_recovery_storm()
    for label in ("sync", "coalesced"):
        rows.append((f"storm_watch_callbacks[{label},{storm['jobs']}jobs]",
                     float(storm[label]["watch_callbacks"])))
    for r in run_locality()["rows"]:
        tag = f"[{r['clusters']}cl]"
        rows.append((f"locality_bytes_per_read_baseline{tag}",
                     r["baseline"]["cross_bytes_per_read"]))
        rows.append((f"locality_bytes_per_read_fanout{tag}",
                     r["fanout"]["cross_bytes_per_read"]))
        rows.append((f"locality_reduction{tag}",
                     r["cross_bytes_per_read_reduction"]))
    for r in run_notify()["rows"]:
        tag = f"[{r['clusters']}cl]"
        rows.append((f"notify_bytes_per_event_baseline{tag}",
                     r["baseline"]["cross_bytes_per_event"]))
        rows.append((f"notify_bytes_per_event_fanout{tag}",
                     r["fanout"]["cross_bytes_per_event"]))
        rows.append((f"notify_reduction{tag}",
                     r["cross_bytes_per_event_reduction"]))
    rows += bench_configuration_phase(8, 4)
    rows += bench_configuration_phase(32, 4)
    rows += bench_failure_recovery()
    return rows


def run_json() -> dict:
    """Structured payload for ``benchmarks/run.py --json``."""
    return {"before": SEED_BASELINE, "after": run_sweep(),
            "after_sharded": run_sharded_sweep(),
            "storm": bench_recovery_storm(),
            "locality": run_locality(),
            "notify": run_notify(),
            "ops": [{"name": n, "us_per_call": v}
                    for n, v in bench_plane_ops(8)],
            "recovery": dict(bench_failure_recovery())}
