"""Control-plane op latencies + scaling (the paper has no perf tables; these are
the management-plane numbers a production deployment is sized with).

  * register/discover/dispatch/heartbeat wall-time per op at 2..64 clusters
  * scaling sweep: dispatch / overwatch-range / heartbeat per-op latency at
    2..256 clusters with a keyspace preloaded to ~20 jobs per cluster (5k+
    jobs at the top of the sweep) — the hot-path overhaul's acceptance gate is
    that per-op latency stays flat (within 2x) from 32 to 256 clusters
  * configuration-phase cost: Algorithm 5 runtime + messages for growing S
  * failure recovery: ticks from partition to re-dispatch

``run_json()`` emits the sweep plus the frozen pre-overhaul baseline
(SEED_BASELINE, measured on the seed implementation whose per-op cost grew
with total keyspace size) — that is what ``benchmarks/run.py --json``
records into BENCH_control_plane.json.
"""
from __future__ import annotations

import time
from typing import Callable, List

from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.core.service_graph import AppSpec, Pod, Service

SWEEP_SCALES = (2, 8, 32, 64, 128, 256)
JOBS_PER_CLUSTER = 20

# Pre-overhaul numbers (seed implementation, same sweep, same machine class):
# per-op cost grew ~14x from 32 to 256 clusters because every dispatch sorted
# the entire keyspace several times. Frozen here so BENCH_control_plane.json
# always carries the before/after comparison.
SEED_BASELINE = {
    "label": "before (seed, full-keyspace scans)",
    "rows": [
        {"clusters": 2, "jobs": 40, "overwatch_range_us": 15.6,
         "dispatch_us": 63.6, "heartbeat_us": 18.8},
        {"clusters": 8, "jobs": 160, "overwatch_range_us": 59.7,
         "dispatch_us": 160.8, "heartbeat_us": 19.3},
        {"clusters": 32, "jobs": 640, "overwatch_range_us": 184.7,
         "dispatch_us": 655.6, "heartbeat_us": 17.7},
        {"clusters": 64, "jobs": 1280, "overwatch_range_us": 260.4,
         "dispatch_us": 1196.7, "heartbeat_us": 20.7},
        {"clusters": 128, "jobs": 2560, "overwatch_range_us": 1122.3,
         "dispatch_us": 3435.4, "heartbeat_us": 32.3},
        {"clusters": 256, "jobs": 5120, "overwatch_range_us": 2738.5,
         "dispatch_us": 8935.6, "heartbeat_us": 39.8},
    ],
}


def _time_us(fn: Callable[[], None], n: int = 50) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def bench_plane_ops(n_clusters: int = 8) -> List[tuple]:
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    for i in range(n_clusters - 1):
        plane.add_cluster(f"c{i}")
    rows = []
    agent = plane.agents["c0"]
    rows.append((f"overwatch_put[{n_clusters}]",
                 _time_us(lambda: agent.ow.put("/bench/k", {"v": 1}))))
    rows.append((f"overwatch_get[{n_clusters}]",
                 _time_us(lambda: agent.ow.get("/bench/k"))))
    rows.append((f"heartbeat[{n_clusters}]",
                 _time_us(lambda: agent.heartbeat())))
    jid = [0]

    def dispatch():
        jid[0] += 1
        plane.submit_job("sim", steps=1, job_id=f"bench-{jid[0]}")

    rows.append((f"dispatch[{n_clusters}]", _time_us(dispatch, n=20)))
    return rows


# ------------------------------------------------------------- scaling sweep
def sweep_point(n_clusters: int,
                jobs_per_cluster: int = JOBS_PER_CLUSTER) -> dict:
    """Per-op latency at one scale, with the keyspace preloaded the way a
    long-running deployment looks (a placement + status row per job)."""
    plane = ManagementPlane(message_log_limit=10_000, op_log_limit=10_000)
    plane.add_cluster("master", is_master=True)
    for i in range(n_clusters - 1):
        plane.add_cluster(f"c{i}")
    names = ["master"] + [f"c{i}" for i in range(n_clusters - 1)]
    n_jobs = n_clusters * jobs_per_cluster
    for j in range(n_jobs):
        c = names[j % len(names)]
        plane.overwatch.handle(
            {"op": "put", "key": f"/jobs/pre-{j}/placement",
             "value": {"cluster": c,
                       "job": {"job_id": f"pre-{j}", "kind": "sim",
                               "steps": 10, "tags": {}, "payload": {}},
                       "clock": 0.0}})
        plane.overwatch.handle(
            {"op": "put", "key": f"/jobs/pre-{j}/status",
             "value": {"cluster": c, "status": "running", "progress": 1.0,
                       "rate": 1.0, "clock": 0.0}})
    agent = plane.agents["c0"]
    row = {"clusters": n_clusters, "jobs": n_jobs}
    row["overwatch_range_us"] = _time_us(
        lambda: agent.ow.range("/clusters/master"), n=100)
    jid = [0]

    def dispatch():
        jid[0] += 1
        plane.submit_job("sim", steps=1, job_id=f"bench-{jid[0]}")

    dispatch()                               # warm the dispatch relay channels
    row["dispatch_us"] = _time_us(dispatch, n=50)
    row["heartbeat_us"] = _time_us(agent.heartbeat, n=50)
    return row


_SWEEP_CACHE: dict = {}


def run_sweep(scales=SWEEP_SCALES) -> dict:
    # memoized per-process: --json mode consumes the sweep twice (CSV rows +
    # JSON payload) and the 256-cluster point is the expensive part; caching
    # also keeps the printed CSV and the recorded JSON from disagreeing
    key = tuple(scales)
    if key in _SWEEP_CACHE:
        return _SWEEP_CACHE[key]
    rows = [sweep_point(n) for n in scales]
    by_n = {r["clusters"]: r for r in rows}
    flat = {}
    if 32 in by_n and 256 in by_n:
        for metric in ("dispatch_us", "overwatch_range_us"):
            flat[metric + "_ratio_256_over_32"] = (
                by_n[256][metric] / max(by_n[32][metric], 1e-9))
    result = {"label": "after (indexed overwatch + cached dispatcher views)",
              "rows": rows, "flatness": flat}
    _SWEEP_CACHE[key] = result
    return result


def bench_configuration_phase(n_services: int = 16, n_clusters: int = 4):
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    clusters = ["master"] + [f"c{i}" for i in range(n_clusters - 1)]
    for c in clusters[1:]:
        plane.add_cluster(c)
    pods, services, partition = [], [], {}
    for k in range(n_services):
        host = clusters[k % len(clusters)]
        sname, bname = f"svc{k}", f"back{k}"
        services.append(Service(sname, 7000 + k, (bname,)))
        pods.append(Pod(bname, needs=()))
        partition[bname] = host
        cname = f"cons{k}"
        pods.append(Pod(cname, needs=(sname,)))
        partition[cname] = clusters[(k + 1) % len(clusters)]
    spec = AppSpec(tuple(services), tuple(pods), partition)
    t0 = time.perf_counter()
    plane.upload_spec(spec)
    dt = (time.perf_counter() - t0) * 1e6
    return [(f"configure[{n_services}svc,{n_clusters}cl]", dt)]


def bench_failure_recovery() -> List[tuple]:
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("c0", local_plane=SimLocalPlane(rate=0.2))
    plane.add_cluster("c1", local_plane=SimLocalPlane(rate=0.2))
    jid = plane.submit_job("sim", steps=100)
    plane.tick(n=3)
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    plane.fabric.partition_cluster(placed)
    ticks = 0
    while ticks < 100:
        plane.tick()
        ticks += 1
        st = plane.overwatch.handle(
            {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]
        if st["cluster"] != placed:
            break
    return [("recovery_ticks_to_redispatch", float(ticks))]


def run() -> List[tuple]:
    rows = []
    for n in (2, 8, 32):
        rows += bench_plane_ops(n)
    for r in run_sweep()["rows"]:
        tag = f"[{r['clusters']}cl,{r['jobs']}jobs]"
        rows.append((f"sweep_dispatch{tag}", r["dispatch_us"]))
        rows.append((f"sweep_overwatch_range{tag}", r["overwatch_range_us"]))
        rows.append((f"sweep_heartbeat{tag}", r["heartbeat_us"]))
    rows += bench_configuration_phase(8, 4)
    rows += bench_configuration_phase(32, 4)
    rows += bench_failure_recovery()
    return rows


def run_json() -> dict:
    """Structured payload for ``benchmarks/run.py --json``."""
    return {"before": SEED_BASELINE, "after": run_sweep(),
            "ops": [{"name": n, "us_per_call": v}
                    for n, v in bench_plane_ops(8)],
            "recovery": dict(bench_failure_recovery())}
