"""Reduced-config train/serve step throughput on CPU (one row per family) +
the Titchener local-SGD vs sync-DP step-cost comparison at equal tokens.
"""
from __future__ import annotations

import time
from typing import List


def _steps_us(trainer, n=3) -> float:
    trainer.step_once()                      # compile + warm
    t0 = time.perf_counter()
    for _ in range(n):
        trainer.step_once()
    return (time.perf_counter() - t0) / n * 1e6


def run() -> List[tuple]:
    from repro.runtime.train_loop import Trainer, TrainJobConfig
    rows = []
    for arch in ("qwen3-0.6b", "deepseek-moe-16b", "mamba2-2.7b", "zamba2-7b",
                 "whisper-medium", "llama-3.2-vision-90b"):
        tr = Trainer(TrainJobConfig(arch=arch, steps=5, seq_len=32,
                                    global_batch=4))
        us = _steps_us(tr)
        toks = 4 * 32
        rows.append((f"train_step[{arch}/reduced]", us, toks / (us / 1e6)))

    sync = Trainer(TrainJobConfig(arch="qwen3-0.6b", steps=5, seq_len=32,
                                  global_batch=8, mode="sync"))
    us_sync = _steps_us(sync)
    lsgd = Trainer(TrainJobConfig(arch="qwen3-0.6b", steps=5, seq_len=32,
                                  global_batch=8, mode="local_sgd"))
    us_round = _steps_us(lsgd)
    H = lsgd.cfg.local_sgd.inner_steps
    rows.append(("sync_dp_step[qwen3-0.6b]", us_sync))
    rows.append((f"local_sgd_round[qwen3-0.6b,H={H}]", us_round,
                 us_round / (H * us_sync)))

    from repro.runtime.serve_loop import Server, ServeJobConfig
    sv = Server(ServeJobConfig(arch="qwen3-0.6b", slots=4, max_len=64))
    for i in range(4):
        sv.submit([1, 2, 3], max_new=8)
    sv.step()                                 # compile + warm
    t0 = time.perf_counter()
    n0 = sv.steps
    sv.run()
    dt = time.perf_counter() - t0
    steps = max(sv.steps - n0, 1)
    rows.append(("decode_step[qwen3-0.6b,slots=4]", dt / steps * 1e6,
                 4 * steps / dt))
    return rows
