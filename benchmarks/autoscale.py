"""Elastic autoscaling plane: time-to-drain a 50k-task backlog and the
replica trajectory, autoscaled fleet vs. an optimally-sized static fleet.

Two runs over the same hybrid topology (master + preferred on-prem cluster
with a capacity quota + public-cloud cluster):

  * ``static``     — ``MAX_REPLICAS`` workers pre-provisioned from tick 0,
    split across the clusters (the best a hand-sized fleet can do: it knows
    the final answer in advance);
  * ``autoscaled`` — ZERO workers at tick 0; the reconciler watches the
    published ``/queues/default`` depth, ramps the fleet under the policy's
    step/cooldown limits (filling the on-prem quota first, spilling the rest
    into the cloud cluster), drains the backlog, then scales back to zero.

Everything is driven by the deterministic fabric clock, so ticks-to-drain
is the signal (host-independent); wall seconds are recorded for context.
Gates, recorded under ``flatness`` (lower is better, checked by
``make bench-check`` against the committed BENCH_autoscale.json):

  * ``drain_ticks_ratio_autoscaled_over_static`` — the elastic fleet must
    drain the backlog within 1.5x the static fleet's ticks;
  * ``peak_replicas_frac_of_max`` — provisioning never exceeds the policy's
    max-replica bound (<= 1.0 by construction; gated so it stays there).

Loss accounting is first-class: every task kind increments a per-task
counter, and a run is only ``ok`` if every task executed EXACTLY once —
zero lost, zero double-executed — across every scale-down/drain event, with
zero broker lease-expiry redeliveries (graceful drains leave no lease to
expire). The same properties are asserted in tests/test_autoscale.py.
"""
from __future__ import annotations

import time
from collections import Counter
from typing import List

from repro.autoscale import ScalingPolicy
from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.pipelines import DAG, Task, HybridComposer

N_TASKS = 50_000
WORKER_BATCH = 64
MAX_REPLICAS = 16
ONPREM_QUOTA = 8                 # the preferred tier's capacity: half the fleet
TARGET_DEPTH = 4 * WORKER_BATCH  # one worker per 4 batches of ready backlog


def _plane() -> ManagementPlane:
    plane = ManagementPlane(message_log_limit=1_000, op_log_limit=1_000)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a",
                      local_plane=SimLocalPlane(caps=("cpu", "onprem")))
    plane.add_cluster("cloud-a", local_plane=SimLocalPlane(caps=("cpu",)))
    return plane


def _backlog_dag(n: int) -> DAG:
    return DAG("backlog", [Task(f"t{i}", kind="count", payload={"i": i})
                           for i in range(n)])


def run_fleet(autoscaled: bool, n_tasks: int = N_TASKS) -> dict:
    plane = _plane()
    counts: Counter = Counter()

    def setup(worker):
        worker.register(
            "count", lambda p, _c=counts: {"n": _c.update([p["i"]]) or 1})

    if autoscaled:
        comp = HybridComposer(plane, workers={}, worker_batch=WORKER_BATCH,
                              worker_setup=setup)
        asc = comp.attach_autoscaler(
            [ScalingPolicy(family="default", queues=("default",),
                           requires=("cpu",),
                           target_depth_per_worker=TARGET_DEPTH,
                           min_replicas=0, max_replicas=MAX_REPLICAS,
                           scale_up_step=MAX_REPLICAS // 2,
                           scale_down_step=4,
                           up_cooldown=1.0, down_cooldown=1.0)],
            quotas={"onprem-a": ONPREM_QUOTA, "master": 0},
            preferred=("onprem-a",))
    else:
        half = MAX_REPLICAS // 2
        comp = HybridComposer(
            plane,
            workers={"onprem-a": [f"ws-{i}" for i in range(half)],
                     "cloud-a": [f"ws-{i + half}" for i in range(half)]},
            worker_batch=WORKER_BATCH, worker_setup=setup)
        asc = None

    comp.add_dag(_backlog_dag(n_tasks))

    trajectory: List[int] = []
    ticks_to_drain = None
    t0 = time.perf_counter()
    max_ticks = n_tasks // (MAX_REPLICAS * WORKER_BATCH) + 400
    for tick in range(1, max_ticks + 1):
        comp.tick()
        replicas = (asc.replicas("default") if asc is not None
                    else len(comp.workers))
        trajectory.append(replicas)
        if ticks_to_drain is None and comp.scheduler.dag_done("backlog",
                                                              probe=False):
            ticks_to_drain = tick
            if asc is None:
                break
        if ticks_to_drain is not None and asc is not None and replicas == 0:
            break                        # backlog drained AND fleet scaled away
    wall = time.perf_counter() - t0
    if ticks_to_drain is None:
        ticks_to_drain = max_ticks       # never drained: the ratio gate fails

    success = comp.scheduler.dag_success("backlog", probe=False)
    duplicates = sum(1 for c in counts.values() if c > 1)
    lost = n_tasks - len(counts)
    peak = max(trajectory) if trajectory else 0
    spilled = 0
    scale_ups = scale_downs = 0
    if asc is not None:
        scale_ups = sum(1 for e in asc.events if e[2] == "scale_up")
        scale_downs = sum(1 for e in asc.events if e[2] == "scale_down")
        spilled = sum(1 for e in asc.events
                      if e[2] == "scale_up" and e[4] == "cloud-a")
    ok = (success and lost == 0 and duplicates == 0
          and peak <= MAX_REPLICAS
          and comp.broker.stats.get("redelivered", 0) == 0)
    return {
        "mode": "autoscaled" if autoscaled else "static",
        "tasks": n_tasks, "ok": ok,
        "ticks_to_drain": ticks_to_drain,
        "wall_s": wall,
        "peak_replicas": peak, "max_replicas": MAX_REPLICAS,
        "end_replicas": trajectory[-1] if trajectory else 0,
        "trajectory": trajectory,
        "scale_ups": scale_ups, "scale_downs": scale_downs,
        "spilled_pods": spilled,
        "lost": lost, "duplicate_executions": duplicates,
        "lease_expiry_redeliveries": comp.broker.stats.get("redelivered", 0),
    }


_CACHE: dict = {}


def run_sweep() -> dict:
    if "sweep" in _CACHE:
        return _CACHE["sweep"]
    static = run_fleet(autoscaled=False)
    auto = run_fleet(autoscaled=True)
    result = {
        "label": ("queue-depth-driven elastic worker fleet vs. "
                  "optimally-sized static fleet"),
        "autoscaled": auto,
        "static": static,
        "flatness": {                    # lower is better; gate <= 1.5 / 1.0
            "drain_ticks_ratio_autoscaled_over_static":
                auto["ticks_to_drain"] / max(static["ticks_to_drain"], 1),
            "peak_replicas_frac_of_max":
                auto["peak_replicas"] / MAX_REPLICAS,
        },
    }
    _CACHE["sweep"] = result
    return result


def run() -> List[tuple]:
    sweep = run_sweep()
    rows = []
    for r in (sweep["autoscaled"], sweep["static"]):
        tag = f"[{r['mode']},{r['tasks']}tasks]"
        rows.append((f"ticks_to_drain{tag}", float(r["ticks_to_drain"])))
        rows.append((f"peak_replicas{tag}", float(r["peak_replicas"])))
        rows.append((f"wall_s{tag}", r["wall_s"]))
    a = sweep["autoscaled"]
    rows.append(("spilled_pods", float(a["spilled_pods"])))
    rows.append(("lost_tasks", float(a["lost"])))
    rows.append(("duplicate_executions", float(a["duplicate_executions"])))
    for k, v in sweep["flatness"].items():
        rows.append((k, v))
    return rows


def run_json() -> dict:
    """Structured payload for ``benchmarks/run.py --json``."""
    return run_sweep()
