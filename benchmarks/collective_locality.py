"""Paper-claim benchmark: cross-boundary traffic is thin.

Quantifies the claim twice:
  1. Management plane: cross-cluster vs local bytes while running a hybrid
     pipeline (the paper's qualitative claim, measured).
  2. Data plane (SPMD): per-axis collective bytes from the compiled multi-pod
     HLO — DCN (pod-axis) vs ICI (in-pod) — plus the Titchener local-sync
     amortization factor (sync-DP DCN bytes / local-SGD DCN bytes).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def management_plane_locality() -> List[tuple]:
    from repro.core.plane import ManagementPlane
    from repro.pipelines import DAG, Task, HybridComposer
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    comp = HybridComposer(plane, workers={"master": ["w0"],
                                          "onprem-a": ["w1"]},
                          worker_queues={"w0": ("default",),
                                         "w1": ("onprem", "default")})
    dag = DAG("loc", [
        Task("e", kind="etl", payload={"batches": 2}),
        Task("t", kind="python", upstream=("e",)),
        Task("l", kind="python", upstream=("t",), requires=("onprem",)),
    ])
    comp.add_dag(dag)
    assert comp.run_dag("loc", max_ticks=60)
    rep = plane.boundary_report()
    total = rep["cross_cluster_bytes"] + rep["local_bytes"]
    return [("mgmt_cross_cluster_bytes", float(rep["cross_cluster_bytes"])),
            ("mgmt_local_bytes", float(rep["local_bytes"])),
            ("mgmt_locality_ratio", rep["locality_ratio"])]


def data_plane_locality(cell: str = "qwen3-32b__train_4k") -> List[tuple]:
    p = ARTIFACTS / "multi" / f"{cell}.json"
    if not p.exists():
        return [("dataplane_missing_artifact", 0.0)]
    rec = json.loads(p.read_text())
    hs = rec["hlo_stats"]
    rows = [(f"dcn_bytes[{rec['cell']}]", float(hs["cross_pod_bytes"])),
            (f"ici_bytes[{rec['cell']}]", float(hs["in_pod_bytes"]))]
    if hs["cross_pod_bytes"]:
        rows.append((f"ici_to_dcn_ratio[{rec['cell']}]",
                     hs["in_pod_bytes"] / hs["cross_pod_bytes"]))
    return rows


def titchener_amortization() -> List[tuple]:
    import jax
    from repro.configs import base as configs
    from repro.models.params import abstract_params
    from repro.optim.local_sgd import LocalSGDConfig, dcn_bytes_per_round
    cfg = configs.get("qwen3-32b")
    params = abstract_params(cfg)
    lcfg = LocalSGDConfig()
    local, sync = dcn_bytes_per_round(params, lcfg)
    return [("local_sgd_dcn_bytes_per_round", float(local)),
            ("sync_dp_dcn_bytes_per_H_steps", float(sync)),
            ("titchener_dcn_amortization_x", sync / local)]


def run() -> List[tuple]:
    return (management_plane_locality() + data_plane_locality()
            + titchener_amortization())
