"""Roofline bench: emit the per-cell three-term table from dry-run artifacts
(writes artifacts/roofline_{single,multi}.md + .json for EXPERIMENTS.md)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import List

ROOT = Path(__file__).resolve().parents[1]


def run() -> List[tuple]:
    from repro.roofline.report import load_rows, markdown_table, to_json
    rows_out: List[tuple] = []
    for mesh in ("single", "multi"):
        rows = load_rows(mesh)
        if not rows:
            continue
        md = markdown_table(rows)
        (ROOT / "artifacts" / f"roofline_{mesh}.md").write_text(md)
        (ROOT / "artifacts" / f"roofline_{mesh}.json").write_text(
            json.dumps(to_json(rows), indent=1))
        worst = min(rows, key=lambda r: r.roofline_fraction)
        best = max(rows, key=lambda r: r.roofline_fraction)
        rows_out += [
            (f"cells[{mesh}]", float(len(rows))),
            (f"best_roofline_fraction[{mesh}]({best.cell})",
             best.roofline_fraction),
            (f"worst_roofline_fraction[{mesh}]({worst.cell})",
             worst.roofline_fraction),
            (f"memory_bound_cells[{mesh}]",
             float(sum(r.dominant == "memory" for r in rows))),
            (f"collective_bound_cells[{mesh}]",
             float(sum(r.dominant == "collective" for r in rows))),
            (f"compute_bound_cells[{mesh}]",
             float(sum(r.dominant == "compute" for r in rows))),
        ]
    return rows_out
