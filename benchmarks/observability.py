"""What the flight recorder costs: tracing overhead, export traffic, and the
span-accounting guarantees, priced on the same wide instant-handler DAG the
``workloads`` suite uses (512 tasks, batch 64 — pure control-plane work).

Blocks (ISSUE 9):

  * ``overhead``      — DETERMINISTIC (the ``observability:overhead`` CI
    part). Three runs of the wide DAG: OFF (no tracer anywhere), TRACE
    (``trace_sample=1.0``), FULL (trace + ``metrics_every`` export over the
    replica feed). Gates: exactly 5 spans per executed task (task /
    schedule / queue / execute / commit — no lost spans, no duplicates,
    nothing left open); the accounting identity ``opened == closed +
    truncated + open``; trace bytes per task (the price of the ``trace``
    ctx riding each staged message); fleet metrics readable from a remote
    cluster via ``range_stale("/metrics/")`` with per-queue-family
    service-time p50/p99 present, at HARD-ZERO cross-boundary bytes per
    read. A crash sub-block re-runs the DAG under ``ChaosHarness`` with one
    injected master crash and gates hard zeros: lost spans, double-closed
    spans, spans leaked open — truncation-then-WAL-replay must balance the
    books exactly.
  * ``overhead_wall`` — wall-clock ratio, tracing on vs off, interleaved
    medians with GC parked outside the timed region (full ``make
    bench-check`` only). Gate: <= 1.05x at the production default sampling
    rate (``DEFAULT_SAMPLE``) — the recorder must be cheap enough to leave
    on. The full-sampling (1.0, debug-rate) ratio is reported ungated.
  * ``report``        — demo payload for ``make trace-report``: the
    critical-path decomposition of the slowest trace (where did the time
    go: queue-wait vs execute vs commit).

  PYTHONPATH=src python -m benchmarks.observability --report   # human view
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.durability import LogStore
from repro.core.faults import ChaosHarness, FaultPlan
from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.observability import critical_path, format_trace_report
from repro.observability.trace import DEFAULT_SAMPLE
from repro.pipelines import DAG, HybridComposer, Task

OVERHEAD_TASKS = 512
WORKER_BATCH = 64
CRASH_TASKS = 128
SPANS_PER_TASK = 5          # task, schedule, queue, execute, commit


def _wide_plane(trace_sample: float = 0.0, export: bool = False,
                durability=None) -> ManagementPlane:
    kw: dict = dict(message_log_limit=1_000, op_log_limit=1_000,
                    trace_sample=trace_sample, durability=durability)
    if export:
        kw.update(coalesce_watches=True, replica_fanout=True,
                  metrics_every=0.5)
    plane = ManagementPlane(**kw)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("compute-a")
    return plane


def _wide_dag(n_tasks: int) -> DAG:
    tasks = [Task("root", kind="sim")]
    tasks += [Task(f"t{i}", kind="sim", upstream=("root",))
              for i in range(n_tasks - 1)]
    return DAG("wide", tasks)


def _run_wide(plane: ManagementPlane, n_tasks: int = OVERHEAD_TASKS,
              durability=None) -> dict:
    def setup(worker):
        worker.register("sim", lambda p: {"ok": 1})

    comp = HybridComposer(plane, workers={"compute-a": ["w0"]},
                          worker_batch=WORKER_BATCH, worker_setup=setup,
                          durability=durability)
    comp.add_dag(_wide_dag(n_tasks))
    t0 = time.perf_counter()
    ok = comp.run_dag("wide", max_ticks=n_tasks // WORKER_BATCH + 200)
    wall = time.perf_counter() - t0
    fabric = plane.fabric
    return {"ok": bool(ok), "wall_s": wall, "comp": comp,
            "bytes": fabric.cross_cluster_bytes()
            + sum(fabric.local_bytes.values()),
            "cross_bytes": fabric.cross_cluster_bytes()}


def _span_books(tracer) -> dict:
    st = tracer.stats
    lost = (st["opened"] - st["closed"] - st["truncated"]
            - tracer.open_count)
    return {"opened": st["opened"], "closed": st["closed"],
            "truncated": st["truncated"], "leaked_open": tracer.open_count,
            "double_closed": st["double_close"], "lost": lost}


# --------------------------------------------------- deterministic CI part
def run_json_overhead() -> dict:
    """Byte/span counts for OFF vs TRACE vs FULL, plus the crash-accounting
    sub-block — deterministic, host-independent (the CI gate)."""
    off = _run_wide(_wide_plane())
    traced = _run_wide(_wide_plane(trace_sample=1.0))
    tr = traced["comp"].tracer
    books = _span_books(tr)
    spans_per_task = tr.stats["opened"] / OVERHEAD_TASKS

    # FULL: tracing + metrics export over the PR 7 replica delta feed
    plane = _wide_plane(trace_sample=1.0, export=True)
    full = _run_wide(plane)
    plane.tick(n=3)                       # publication cadence + one ship
    agent = plane.agents["compute-a"]
    items = dict(agent.ow.range_stale("/metrics/", max_lag=10.0))
    svc = items.get("/metrics/compute-a/pipeline", {})
    svc_ok = (svc.get("service_time.default.count", 0) >= OVERHEAD_TASKS
              and "service_time.default.p50" in svc
              and "service_time.default.p99" in svc)
    cross0 = plane.fabric.cross_cluster_bytes()
    agent.ow.range_stale("/metrics/", max_lag=10.0)   # fleet-wide re-read
    read_cross = plane.fabric.cross_cluster_bytes() - cross0

    # crash sub-block: one injected master crash mid-DAG; truncation + WAL
    # replay must balance the span books exactly
    dur = LogStore()
    cplane = _wide_plane(trace_sample=1.0, durability=dur)

    def setup(worker):
        worker.register("sim", lambda p: {"ok": 1})

    comp = HybridComposer(cplane, workers={"compute-a": ["w0"]},
                          worker_batch=WORKER_BATCH, worker_setup=setup,
                          durability=dur)
    comp.add_dag(_wide_dag(CRASH_TASKS))
    h = ChaosHarness(cplane, comp, FaultPlan.crash_at_ops(12),
                     downtime_ticks=2)
    crash_ok = h.run(lambda: comp.scheduler.dag_success("wide"),
                     max_ticks=400)
    cbooks = _span_books(comp.tracer)
    crash = {
        "tasks": CRASH_TASKS, "crashes": h.crashes,
        "ok": bool(crash_ok) and h.crashes == 1
        and comp.tracer.accounting_ok(),
        "span_books": cbooks,
        # hard zeros: a fresh run may not lose, leak, or double-close a
        # single span across the crash/restart
        "flatness": {"lost_spans": float(cbooks["lost"]),
                     "double_closed_spans": float(cbooks["double_closed"]),
                     "leaked_open_spans": float(cbooks["leaked_open"])},
    }

    return {
        "label": (f"flight recorder on the wide {OVERHEAD_TASKS}-task "
                  "instant-handler DAG: off vs trace vs trace+export"),
        "tasks": OVERHEAD_TASKS,
        "ok": (off["ok"] and traced["ok"] and full["ok"] and svc_ok
               and tr.accounting_ok()
               and spans_per_task == float(SPANS_PER_TASK)
               and books["lost"] == 0 and books["double_closed"] == 0
               and books["leaked_open"] == 0),
        "span_books": books,
        "off_bytes": off["bytes"], "trace_bytes": traced["bytes"],
        "full_cross_bytes": full["cross_bytes"],
        "metrics_sections_read": len(items),
        "service_time_ok": svc_ok,
        "crash": crash,
        "flatness": {
            # exactly 5 spans per executed task, both directions: the count
            # can neither regress upward (duplicates) past tolerance nor
            # silently drop (lost spans fail the hard-zero + ok gates)
            "spans_per_task": spans_per_task,
            # the trace ctx riding each staged message costs this much
            "trace_bytes_per_task":
                (traced["bytes"] - off["bytes"]) / OVERHEAD_TASKS,
            # registry deltas riding the replica feed (includes the feed's
            # own telemetry baseline — the marginal price of /metrics/)
            "export_cross_bytes_per_task":
                (full["cross_bytes"] - traced["cross_bytes"])
                / OVERHEAD_TASKS,
            # a fleet-wide metrics read from a non-master cluster moves
            # ZERO bytes across the boundary (replica-local, hard zero)
            "metrics_read_cross_bytes": float(read_cross),
        },
    }


# ------------------------------------------------------------- wall clock
def run_overhead_wall() -> dict:
    """Tracing-on vs tracing-off wall clock on the wide DAG. Interleaved
    reps so host drift hits every arm equally, GC parked outside the timed
    region (the recorder's extra allocations otherwise trigger gen-0
    collections that bill phantom cost to unrelated functions). Gate:
    <= 1.05x at the production default sampling rate (``DEFAULT_SAMPLE``)
    — the recorder is cheap enough to leave on. The full-sampling (1.0)
    ratio is the debug rate, reported alongside but ungated."""
    import gc

    def timed(sample: float) -> float:
        plane = _wide_plane(trace_sample=sample)
        gc.collect()
        gc.disable()
        try:
            return _run_wide(plane)["wall_s"]
        finally:
            gc.enable()

    reps, trim = 21, 4
    timed(0.0)                          # warm imports/allocator once
    off: List[float] = []
    dflt: List[float] = []
    full: List[float] = []
    for _ in range(reps):               # (off, default, full) triples: an
        off.append(timed(0.0))          # adjacent pair shares the host's
        dflt.append(timed(DEFAULT_SAMPLE))   # momentary state, so the
        full.append(timed(1.0))         # per-pair ratio cancels drift

    def trimmed_ratio(xs: List[float]) -> float:
        rs = sorted(x / o for x, o in zip(xs, off))
        core = rs[trim:len(rs) - trim]
        return sum(core) / len(core)

    ratio = trimmed_ratio(dflt)
    return {
        "label": (f"wide {OVERHEAD_TASKS}-task DAG wall clock: "
                  f"trace_sample={DEFAULT_SAMPLE} (production default) vs "
                  f"off, trimmed mean of {reps} interleaved pair ratios"),
        "trace_sample": DEFAULT_SAMPLE,
        "off_wall_s": sorted(off)[reps // 2],
        "traced_wall_s": sorted(dflt)[reps // 2],
        "full_wall_s": sorted(full)[reps // 2],
        "tracing_overhead_ratio_raw": ratio,
        # sample=1.0 is the debug rate — priced, not gated
        "trace_full_overhead_ratio": trimmed_ratio(full),
        "ok": ratio <= 1.05,
        # floored at 1.0: a lucky sub-1.0 run must not tighten the
        # committed baseline below what an honest re-run can meet
        "flatness": {"tracing_overhead_ratio": max(ratio, 1.0)},
    }


# ----------------------------------------------------------------- report
def run_trace_report() -> dict:
    """Demo payload for ``make trace-report``: trace a small DAG, decompose
    the slowest task into its lifecycle segments."""
    plane = _wide_plane(trace_sample=1.0)
    res = _run_wide(plane, n_tasks=32)
    tr = res["comp"].tracer
    slowest = max(tr.trace_ids(),
                  key=lambda t: (critical_path(tr, t) or {}).get("total", 0))
    cp = critical_path(tr, slowest)
    return {"label": "critical-path decomposition of the slowest trace",
            "ok": res["ok"], "trace_id": slowest,
            "critical_path": {k: cp[k] for k in
                              ("trace_id", "total", "status", "segments",
                               "dominant", "path")},
            "text": format_trace_report(tr, top_n=5)}


_CACHE: dict = {}


def run_sweep() -> dict:
    if "sweep" in _CACHE:
        return _CACHE["sweep"]
    result = {
        "label": "flight recorder: tracing + metrics export priced",
        "overhead": run_json_overhead(),
        "overhead_wall": run_overhead_wall(),
        "report": run_trace_report(),
    }
    _CACHE["sweep"] = result
    return result


def run() -> List[tuple]:
    sweep = run_sweep()
    ov, ow = sweep["overhead"], sweep["overhead_wall"]
    fl = ov["flatness"]
    return [
        ("spans_per_task", fl["spans_per_task"]),
        ("trace_bytes_per_task", fl["trace_bytes_per_task"]),
        ("export_cross_bytes_per_task", fl["export_cross_bytes_per_task"]),
        ("metrics_read_cross_bytes", fl["metrics_read_cross_bytes"]),
        ("lost_spans", ov["crash"]["flatness"]["lost_spans"]),
        ("tracing_overhead_ratio",
         ow["flatness"]["tracing_overhead_ratio"]),
        ("traced_wall_s", ow["traced_wall_s"]),
        ("off_wall_s", ow["off_wall_s"]),
    ]


def run_json() -> dict:
    """Structured payload for ``benchmarks/run.py --json``."""
    return run_sweep()


if __name__ == "__main__":
    import sys
    if "--report" in sys.argv:
        rep = run_trace_report()
        print(rep["text"])
        cp = rep["critical_path"]
        print(f"slowest trace: {cp['trace_id']}  total={cp['total']:.3f} "
              f"dominant={cp['dominant']}")
        for name, secs in sorted(cp["segments"].items(),
                                 key=lambda kv: -kv[1]):
            print(f"  {name:<10} {secs:.4f}")
    else:
        for name, value in run():
            print(f"{name},{value:.6g}")
