"""Kernel micro-benchmarks on CPU: blocked (lowering target) vs naive oracle,
plus pallas-interpret parity cost. Wall numbers are CPU-only sanity signals;
the TPU story is the roofline bench.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp


def _t(fn, *args, n=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run() -> List[tuple]:
    from repro.kernels import ops
    rows = []
    key = jax.random.PRNGKey(0)
    B, S, H, K, D = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, K, D), jnp.float32)
    v = jax.random.normal(key, (B, S, K, D), jnp.float32)

    fa_blocked = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, impl="blocked"))
    fa_naive = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, impl="naive"))
    rows.append((f"flash_blocked[{B}x{S}x{H}x{D}]", _t(fa_blocked, q, k, v)))
    rows.append((f"flash_naive[{B}x{S}x{H}x{D}]", _t(fa_naive, q, k, v)))

    Hs, N, P = 4, 16, 32
    x = jax.random.normal(key, (B, S, Hs, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, Hs), jnp.float32))
    a = -jnp.exp(jax.random.normal(key, (Hs,), jnp.float32) * 0.1)
    bm = jax.random.normal(key, (B, S, N), jnp.float32)
    cm = jax.random.normal(key, (B, S, N), jnp.float32)
    ssd_blocked = jax.jit(lambda *t: ops.ssd_scan(*t, impl="blocked", chunk=64))
    ssd_naive = jax.jit(lambda *t: ops.ssd_scan(*t, impl="naive"))
    rows.append((f"ssd_blocked[{B}x{S}x{Hs}x{P}]",
                 _t(ssd_blocked, x, dt, a, bm, cm)))
    rows.append((f"ssd_naive[{B}x{S}x{Hs}x{P}]",
                 _t(ssd_naive, x, dt, a, bm, cm)))

    y = jax.random.normal(key, (B, S, 256), jnp.float32)
    sc = jnp.ones((256,), jnp.float32)
    rn = jax.jit(lambda y, sc: ops.rmsnorm(y, sc))
    rows.append((f"rmsnorm[{B}x{S}x256]", _t(rn, y, sc)))
    return rows
