"""Crash-survivable control plane: recovery cost + exactly-once accounting
under scripted master crashes (``make chaos`` / the ``durability`` suite).

A 50k-task backlog is driven through the durable pipeline plane while a
``FaultPlan`` kills the global plane at scripted points; every scenario must
finish with every task executed EXACTLY once (per-task-id counters in the
worker handlers — the same accounting the autoscale suite uses). The chaos
matrix:

  * ``static_seeded``      — static fleet, three seeded crashes spread across
    the run (the headline: crash anywhere, recover, lose nothing);
  * ``crash_mid_sweep``    — the crash fires AT the taskdb WAL group-commit
    boundary (``site="commit:taskdb"``), the tick's tail still volatile;
  * ``autoscaled_double``  — elastic fleet (scale from zero, replica fan-out
    on) crashed twice, once mid-ramp and once during scale-down drains: pod
    adoption + the drained-pod commit barrier under fire;
  * ``partition_crash``    — a worker cluster is partitioned before taking
    leases, the master dies and recovers, the cluster heals later.

Per recovery the harness records WAL length, records replayed (bounded by the
snapshot cadence, not run length), and recovery wall time — the trajectory a
deployment sizes its ``snapshot_every`` with.

Gates (committed in BENCH_durability.json, checked by ``make bench-check``):
``flatness.lost_tasks`` / ``flatness.duplicate_executions`` are HARD ZEROS —
any regression is a correctness bug, not a perf drift — and
``flatness.replay_amplification`` (total records replayed across recoveries /
total WAL records committed) pins snapshot+truncate compaction. CI gates the
``recovery`` part (``durability:recovery`` — the same properties at a
CI-sized task count) via ``run_json_recovery()``.
"""
from __future__ import annotations

import sys
import time
from collections import Counter
from typing import List, Optional

from repro.autoscale import ScalingPolicy
from repro.core.durability import LogStore
from repro.core.faults import ChaosHarness, FaultPlan, FaultPoint
from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.pipelines import DAG, Task, HybridComposer

N_TASKS = 50_000
WORKER_BATCH = 64
STATIC_FLEET = 8
MAX_REPLICAS = 16
TARGET_DEPTH = 4 * WORKER_BATCH


def run_chaos(name: str, plan: FaultPlan, n_tasks: int = N_TASKS,
              autoscale: bool = False, fanout: bool = False,
              downtime_ticks: int = 2, expect_crashes: Optional[int] = None,
              ) -> dict:
    """One scenario: durable plane + composer, scripted faults, exactly-once
    accounting. Deterministic except the recorded wall seconds."""
    dur = LogStore()
    plane = ManagementPlane(durability=dur, replica_fanout=fanout,
                            message_log_limit=1_000, op_log_limit=1_000)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a",
                      local_plane=SimLocalPlane(caps=("cpu", "onprem")))
    plane.add_cluster("cloud-a", local_plane=SimLocalPlane(caps=("cpu",)))
    counts: Counter = Counter()

    def setup(worker):
        worker.register(
            "count", lambda p, _c=counts: {"n": _c.update([p["i"]]) or 1})

    if autoscale:
        comp = HybridComposer(plane, workers={}, worker_batch=WORKER_BATCH,
                              durability=dur, worker_setup=setup)
        comp.attach_autoscaler(
            [ScalingPolicy(family="default", queues=("default",),
                           requires=("cpu",),
                           target_depth_per_worker=TARGET_DEPTH,
                           min_replicas=0, max_replicas=MAX_REPLICAS,
                           scale_up_step=MAX_REPLICAS // 2,
                           scale_down_step=4,
                           up_cooldown=1.0, down_cooldown=1.0)],
            quotas={"onprem-a": MAX_REPLICAS // 2, "master": 0},
            preferred=("onprem-a",))
    else:
        half = STATIC_FLEET // 2
        comp = HybridComposer(
            plane,
            workers={"onprem-a": [f"ws-{i}" for i in range(half)],
                     "cloud-a": [f"ws-{i + half}" for i in range(half)]},
            worker_batch=WORKER_BATCH, durability=dur, worker_setup=setup)
    comp.add_dag(DAG("backlog", [Task(f"t{i}", kind="count",
                                      payload={"i": i})
                                 for i in range(n_tasks)]))

    harness = ChaosHarness(plane, comp, plan, downtime_ticks=downtime_ticks)
    fleet = MAX_REPLICAS if autoscale else STATIC_FLEET
    max_ticks = n_tasks // (fleet * WORKER_BATCH) + 2_000
    t0 = time.perf_counter()
    # keep ticking until the WHOLE plan has fired (idle ticks still advance
    # the op counter): a backlog that drains before a late fault point must
    # still survive that crash — including "nothing left to redo" recoveries
    done = harness.run(lambda: (comp.scheduler.dag_success("backlog")
                                and not harness.injector.plan.points),
                       max_ticks=max_ticks)
    wall = time.perf_counter() - t0

    duplicates = sum(1 for c in counts.values() if c > 1)
    lost = n_tasks - len(counts)
    crashes_ok = (expect_crashes is None
                  or harness.crashes == expect_crashes)
    recoveries = [{"wal_records": r["wal_records"],
                   "replayed": r["replayed"],
                   "wall_s": r["wall_s"]} for r in harness.recoveries]
    return {
        "scenario": name, "tasks": n_tasks,
        "ok": bool(done and lost == 0 and duplicates == 0 and crashes_ok),
        "crashes": harness.crashes,
        "faults_fired": [f for f, _ in harness.injector.fired],
        "lost": lost, "duplicate_executions": duplicates,
        "stale_acks": sum(b.stats.get("stale_acks", 0)
                          for b in comp.brokers),
        "wal_committed": dur.stats["committed"],
        "wal_lost_at_crashes": dur.stats["lost_records"],
        "snapshots": dur.stats["snapshots"],
        "recoveries": recoveries,
        "recovery_wall_s": sum(r["wall_s"] for r in recoveries),
        "wall_s": wall,
    }


def _matrix(n_tasks: int) -> List[dict]:
    # fault-point op schedules scale with the run length so the CI-sized
    # matrix (run_json_recovery) crashes at the same relative phases as the
    # full 50k one; the plan-exhaustion loop in run_chaos absorbs rounding
    f = n_tasks / N_TASKS

    def at(op: int) -> int:
        return max(int(op * f), 30)

    return [
        run_chaos("static_seeded",
                  FaultPlan.seeded(3, crashes=3, first=at(400),
                                   span=max(at(1200), 90)),
                  n_tasks=n_tasks, expect_crashes=3),
        run_chaos("crash_mid_sweep",
                  FaultPlan.crash_at_site("commit:taskdb", hit=25),
                  n_tasks=n_tasks, expect_crashes=1),
        run_chaos("autoscaled_double",
                  FaultPlan.crash_at_ops(at(500), at(2500)),
                  n_tasks=n_tasks, autoscale=True, fanout=True,
                  downtime_ticks=3, expect_crashes=2),
        run_chaos("partition_crash", FaultPlan([
            FaultPoint(action="partition", cluster="cloud-a", at_op=1),
            FaultPoint(at_op=at(800)),
            FaultPoint(action="heal", cluster="cloud-a", at_op=at(2000)),
        ]), n_tasks=n_tasks, expect_crashes=1),
    ]


# ------------------------------------------------------------- multi-master
def run_migration_chaos(name: str, n_tasks: int,
                        plan: Optional[FaultPlan] = None,
                        migrations: tuple = (),
                        warmup_ticks: int = 3) -> dict:
    """One multi-master scenario: three master fault domains owning one
    overwatch shard + two broker shards behind the epoch-fenced shard map,
    with scripted live migrations and/or ``kill_master`` fault points fired
    mid-backlog. Alongside the exactly-once accounting this records the
    migration ledger: the unavailability window (coordinator frozen ticks),
    and how many operations bounced off a fence and were retried (stale-epoch
    rejections + frozen-broker bounces + scheduler push re-stashes). All
    deterministic counts — wall seconds are the only host-dependent field."""
    dur = LogStore()
    plane = ManagementPlane(durability=dur, num_masters=3,
                            message_log_limit=1_000, op_log_limit=1_000)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a",
                      local_plane=SimLocalPlane(caps=("cpu", "onprem")))
    plane.add_cluster("cloud-a", local_plane=SimLocalPlane(caps=("cpu",)))
    counts: Counter = Counter()

    def setup(worker):
        worker.register(
            "count", lambda p, _c=counts: {"n": _c.update([p["i"]]) or 1})

    half = STATIC_FLEET // 2
    comp = HybridComposer(
        plane,
        workers={"onprem-a": [f"ws-{i}" for i in range(half)],
                 "cloud-a": [f"ws-{i + half}" for i in range(half)]},
        worker_batch=WORKER_BATCH, durability=dur, broker_shards=2,
        worker_setup=setup)
    comp.add_dag(DAG("backlog", [Task(f"t{i}", kind="count",
                                      payload={"i": i})
                                 for i in range(n_tasks)]))

    harness = ChaosHarness(plane, comp, plan or FaultPlan([]))
    co = plane.coordinator
    for _ in range(warmup_ticks):      # get the backlog in flight first, so
        harness.tick()                 # migrations race live traffic
    for shard in migrations:
        target = next(m for m in sorted(co.masters)
                      if m != co.owner_of(shard))
        assert co.migrate(shard, target)
    t0 = time.perf_counter()
    # drain the backlog AND the migration protocol AND the fault plan —
    # a flat DAG finishes faster than a 4-step migration, so dag_success
    # alone would return with the flip still pending
    done = harness.run(lambda: (comp.scheduler.dag_success("backlog")
                                and not co.busy
                                and not harness.injector.plan.points),
                       max_ticks=n_tasks // (STATIC_FLEET * WORKER_BATCH)
                       + 1_000)
    wall = time.perf_counter() - t0

    duplicates = sum(1 for c in counts.values() if c > 1)
    lost = n_tasks - len(counts)
    bounced = (co.stats["stale_epoch_rejections"]
               + sum(b.stats.get("frozen_bounced", 0) for b in comp.brokers)
               + comp.scheduler.stats.get("push_retries", 0))
    return {
        "scenario": name, "tasks": n_tasks,
        "ok": bool(done and lost == 0 and duplicates == 0),
        "epoch": co.epoch,
        "migrations": co.stats["migrations"],
        "failovers": co.stats["failovers"],
        "frozen_ticks": co.stats["frozen_ticks"],
        "stale_epoch_rejections": co.stats["stale_epoch_rejections"],
        "bounced_then_retried": bounced,
        "push_gave_up": comp.scheduler.stats.get("push_gave_up", 0),
        "masters_alive": co.metrics()["masters_alive"],
        "faults_fired": [f for f, _ in harness.injector.fired],
        "lost": lost, "duplicate_executions": duplicates,
        "wall_s": wall,
    }


def _migration_matrix(n_tasks: int) -> List[dict]:
    # initial placement is registration order: ow-shard-0 -> m0,
    # broker-s0 -> m1, broker-s1 -> m2 — so the scripted kills below
    # name their victims statically
    return [
        # the headline: migrate a broker shard AND the overwatch shard off
        # their owners while the backlog drains — writes bounce, refresh,
        # land; nothing is lost, nothing runs twice
        run_migration_chaos("live_migration", n_tasks,
                            migrations=("broker-s0", "ow-shard-0")),
        # kill the broker-s0 owner cold mid-backlog: dead-owner detection
        # enqueues a from-WAL failover, survivors keep serving throughout
        run_migration_chaos(
            "kill_master_failover", n_tasks,
            plan=FaultPlan([FaultPoint(action="kill_master", cluster="m1",
                                       at_op=max(n_tasks // 4, 50))])),
        # kill the SOURCE at the flip boundary of its own live migration:
        # the payload was exported+snapshotted at transfer, so the flip
        # completes live and the dead domain ends the run empty-handed
        run_migration_chaos(
            "kill_source_at_flip", n_tasks,
            migrations=("broker-s0",),
            plan=FaultPlan([FaultPoint(site="migrate:broker-s0:flip",
                                       action="kill_master",
                                       cluster="m1")])),
    ]


def _summarize_migration(scenarios: List[dict]) -> dict:
    migrations = sum(s["migrations"] for s in scenarios)
    frozen = sum(s["frozen_ticks"] for s in scenarios)
    return {
        "scenarios": {s["scenario"]: s for s in scenarios},
        "flatness": {
            # the same hard zeros as the crash matrix — a migration or
            # failover may never lose or double-run a task
            "lost_tasks": float(sum(s["lost"] for s in scenarios)),
            "duplicate_executions":
                float(sum(s["duplicate_executions"] for s in scenarios)),
            # bounded unavailability: frozen plane-ticks per completed
            # migration (deterministic tick counts, host-independent)
            "unavailability_ticks_per_migration":
                frozen / max(migrations, 1),
        },
    }


def _summarize(scenarios: List[dict]) -> dict:
    replayed = sum(r["replayed"] for s in scenarios for r in s["recoveries"])
    committed = sum(s["wal_committed"] for s in scenarios)
    return {
        "scenarios": {s["scenario"]: s for s in scenarios},
        "flatness": {
            # hard zeros: any movement is a lost or double-run task
            "lost_tasks": float(sum(s["lost"] for s in scenarios)),
            "duplicate_executions":
                float(sum(s["duplicate_executions"] for s in scenarios)),
            # snapshot+truncate keeps replay << WAL history (deterministic
            # record counts, host-independent)
            "replay_amplification": replayed / max(committed, 1),
        },
    }


_CACHE: dict = {}


def run_sweep() -> dict:
    if "sweep" in _CACHE:
        return _CACHE["sweep"]
    result = {
        "label": ("crash-survivable pipeline plane: exactly-once across "
                  "scripted master crashes, recovery cost trajectory"),
        **_summarize(_matrix(N_TASKS)),
        "recovery": run_json_recovery(),
        "migration": run_json_migration(),
    }
    _CACHE["sweep"] = result
    return result


def run_json_recovery() -> dict:
    """CI-sized chaos matrix (``durability:recovery``): the same scenarios
    and the same hard-zero gates at a task count shared runners can afford.
    All gated numbers are deterministic record/execution counts."""
    if "recovery" in _CACHE:
        return _CACHE["recovery"]
    result = _summarize(_matrix(5_000))
    _CACHE["recovery"] = result
    return result


def run_json_migration() -> dict:
    """CI-sized multi-master matrix (``durability:migration``): live shard
    migration and master failover under load, gating hard-zero lost/dup
    tasks plus the frozen-ticks-per-migration unavailability bound."""
    if "migration" in _CACHE:
        return _CACHE["migration"]
    result = _summarize_migration(_migration_matrix(4_000))
    _CACHE["migration"] = result
    return result


def run() -> List[tuple]:
    sweep = run_sweep()
    rows = []
    for name, s in sweep["scenarios"].items():
        tag = f"[{name},{s['tasks']}tasks]"
        rows.append((f"crashes{tag}", float(s["crashes"])))
        rows.append((f"recovery_wall_s{tag}", s["recovery_wall_s"]))
        rows.append((f"wal_committed{tag}", float(s["wal_committed"])))
        rows.append((f"replayed{tag}",
                     float(sum(r["replayed"] for r in s["recoveries"]))))
        rows.append((f"wall_s{tag}", s["wall_s"]))
    for k, v in sweep["flatness"].items():
        rows.append((k, v))
    for name, s in sweep["migration"]["scenarios"].items():
        tag = f"[{name},{s['tasks']}tasks]"
        rows.append((f"migrations{tag}", float(s["migrations"])))
        rows.append((f"frozen_ticks{tag}", float(s["frozen_ticks"])))
        rows.append((f"bounced_then_retried{tag}",
                     float(s["bounced_then_retried"])))
    for k, v in sweep["migration"]["flatness"].items():
        rows.append((f"migration.{k}", v))
    return rows


def run_json() -> dict:
    """Structured payload for ``benchmarks/run.py --json``."""
    return run_sweep()


def _chaos_cli() -> int:
    """``make chaos``: run the full matrix, print the verdict table, exit
    nonzero if any scenario lost or double-ran a task."""
    sweep = run_sweep()
    bad = 0
    print(f"{'scenario':<20} {'ok':<4} {'crashes':<8} {'lost':<6} "
          f"{'dups':<6} {'stale_acks':<11} {'replayed':<9} {'rec_wall_s'}")
    for name, s in sweep["scenarios"].items():
        replayed = sum(r["replayed"] for r in s["recoveries"])
        print(f"{name:<20} {str(s['ok']):<4} {s['crashes']:<8} "
              f"{s['lost']:<6} {s['duplicate_executions']:<6} "
              f"{s['stale_acks']:<11} {replayed:<9} "
              f"{s['recovery_wall_s']:.3f}")
        bad += not s["ok"]
    f = sweep["flatness"]
    print(f"lost_tasks={f['lost_tasks']:.0f} "
          f"duplicate_executions={f['duplicate_executions']:.0f} "
          f"replay_amplification={f['replay_amplification']:.3f}")
    print(f"\n{'migration scenario':<22} {'ok':<4} {'epoch':<6} "
          f"{'migr':<5} {'failov':<7} {'frozen':<7} {'bounced':<8} "
          f"{'lost':<6} {'dups'}")
    for name, s in sweep["migration"]["scenarios"].items():
        print(f"{name:<22} {str(s['ok']):<4} {s['epoch']:<6} "
              f"{s['migrations']:<5} {s['failovers']:<7} "
              f"{s['frozen_ticks']:<7} {s['bounced_then_retried']:<8} "
              f"{s['lost']:<6} {s['duplicate_executions']}")
        bad += not s["ok"]
    mf = sweep["migration"]["flatness"]
    print(f"unavailability_ticks_per_migration="
          f"{mf['unavailability_ticks_per_migration']:.2f}")
    return 1 if bad else 0


if __name__ == "__main__":
    if "--chaos" in sys.argv[1:]:
        raise SystemExit(_chaos_cli())
    for n, v in run():
        print(f"{n},{v:.4g}")
