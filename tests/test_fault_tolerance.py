"""Fault tolerance: failure detection -> re-dispatch -> checkpoint restore.

The JAX test is the honest one: a training job is killed mid-run and must
resume on another cluster from the committed manifest, producing the SAME loss
trajectory as an uninterrupted run (the data pipeline is a pure function of
step, so the curves must match exactly at equal steps).
"""
import pytest

from repro.core.plane import ManagementPlane
from repro.runtime.local_plane import JaxLocalPlane
from repro.runtime.train_loop import Trainer, TrainJobConfig
from tests.conftest import make_plane


def test_sim_failure_redispatch_completes():
    plane = make_plane(2)
    jid = plane.submit_job("sim", steps=20, tags={"requires": ("cpu",)})
    plane.tick(n=3)
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    plane.fabric.partition_cluster(placed)
    assert plane.run_until_done([jid], max_ticks=100)
    placed2 = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    assert placed2 != placed


def _jax_plane(plane, name, tmp):
    lp = JaxLocalPlane(
        steps_per_poll=3,
        publish=lambda jid, man, _n=name: plane.agents[_n].ow.put(
            f"/checkpoints/{jid}", man),
        checkpoint_root=str(tmp / name))
    return lp


@pytest.mark.slow
def test_jax_job_survives_cluster_loss(tmp_path):
    from repro.core.plane import SimLocalPlane
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    for name in ("gpu-a", "gpu-b"):
        lp = _jax_plane(plane, name, tmp_path)
        plane.add_cluster(name, local_plane=lp)
    payload = {"arch": "qwen3-0.6b", "steps": 12, "seq_len": 16,
               "global_batch": 2, "checkpoint_every": 4}
    jid = plane.submit_job("train", arch="qwen3-0.6b", steps=12,
                           tags={"requires": ("train",)}, payload=payload)
    # let it run past one checkpoint, then kill the hosting cluster
    for _ in range(6):
        plane.tick()
        ck = plane.overwatch.handle(
            {"op": "get", "key": f"/checkpoints/{jid}"})["value"]
        if ck:
            break
    assert ck, "no checkpoint committed before failure injection"
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    plane.fabric.partition_cluster(placed)
    assert plane.run_until_done([jid], max_ticks=120)
    placed2 = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    assert placed2 != placed
    st = plane.job_status(jid)
    assert st["status"] == "done" and st["progress"] == 12.0


@pytest.mark.slow
def test_restore_matches_uninterrupted_run(tmp_path):
    kw = dict(arch="qwen3-0.6b", seq_len=16, global_batch=2, seed=3)
    # uninterrupted 8 steps
    t_ref = Trainer(TrainJobConfig(steps=8, **kw))
    t_ref.run()
    ref_loss = t_ref.metrics.series("loss")

    # 4 steps -> checkpoint -> NEW trainer restores -> 4 more steps
    t_a = Trainer(TrainJobConfig(steps=4, checkpoint_every=4,
                                 checkpoint_dir=str(tmp_path / "ck"), **kw))
    t_a.run()
    t_a.save_checkpoint()
    t_b = Trainer(TrainJobConfig(steps=8, checkpoint_every=100,
                                 checkpoint_dir=str(tmp_path / "ck"), **kw))
    assert t_b.restore() == 4
    t_b.run(4)
    res_loss = t_b.metrics.series("loss")
    assert ref_loss[4:] == pytest.approx(res_loss, rel=1e-5)


def test_checkpoint_manifest_commit_is_atomic(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    import jax.numpy as jnp
    mgr = CheckpointManager(str(tmp_path), keep=2, use_async=False)
    commits = []
    mgr.on_commit(lambda step, path: commits.append(step))
    tree = {"w": jnp.arange(8.0), "b": {"x": jnp.ones((2, 2))}}
    for s in (1, 2, 3):
        mgr.save(s, tree, extra={"data": {"step": s}})
    assert commits == [1, 2, 3]
    assert mgr.all_steps() == [2, 3]          # keep=2 gc'd step 1
    restored, step, extra = mgr.restore(tree, step=3)
    assert step == 3 and extra["data"]["step"] == 3
    assert (restored["w"] == tree["w"]).all()
