"""Fault tolerance: failure detection -> re-dispatch -> checkpoint restore.

The JAX test is the honest one: a training job is killed mid-run and must
resume on another cluster from the committed manifest, producing the SAME loss
trajectory as an uninterrupted run (the data pipeline is a pure function of
step, so the curves must match exactly at equal steps).
"""
import pytest

from repro.core.plane import ManagementPlane
from repro.runtime.local_plane import JaxLocalPlane
from repro.runtime.train_loop import Trainer, TrainJobConfig
from tests.conftest import make_plane


def test_sim_failure_redispatch_completes():
    plane = make_plane(2)
    jid = plane.submit_job("sim", steps=20, tags={"requires": ("cpu",)})
    plane.tick(n=3)
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    plane.fabric.partition_cluster(placed)
    assert plane.run_until_done([jid], max_ticks=100)
    placed2 = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    assert placed2 != placed


def _jax_plane(plane, name, tmp):
    lp = JaxLocalPlane(
        steps_per_poll=3,
        publish=lambda jid, man, _n=name: plane.agents[_n].ow.put(
            f"/checkpoints/{jid}", man),
        checkpoint_root=str(tmp / name))
    return lp


@pytest.mark.slow
def test_jax_job_survives_cluster_loss(tmp_path):
    from repro.core.plane import SimLocalPlane
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    for name in ("gpu-a", "gpu-b"):
        lp = _jax_plane(plane, name, tmp_path)
        plane.add_cluster(name, local_plane=lp)
    payload = {"arch": "qwen3-0.6b", "steps": 12, "seq_len": 16,
               "global_batch": 2, "checkpoint_every": 4}
    jid = plane.submit_job("train", arch="qwen3-0.6b", steps=12,
                           tags={"requires": ("train",)}, payload=payload)
    # let it run past one checkpoint, then kill the hosting cluster
    for _ in range(6):
        plane.tick()
        ck = plane.overwatch.handle(
            {"op": "get", "key": f"/checkpoints/{jid}"})["value"]
        if ck:
            break
    assert ck, "no checkpoint committed before failure injection"
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    plane.fabric.partition_cluster(placed)
    assert plane.run_until_done([jid], max_ticks=120)
    placed2 = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    assert placed2 != placed
    st = plane.job_status(jid)
    assert st["status"] == "done" and st["progress"] == 12.0


@pytest.mark.slow
def test_restore_matches_uninterrupted_run(tmp_path):
    kw = dict(arch="qwen3-0.6b", seq_len=16, global_batch=2, seed=3)
    # uninterrupted 8 steps
    t_ref = Trainer(TrainJobConfig(steps=8, **kw))
    t_ref.run()
    ref_loss = t_ref.metrics.series("loss")

    # 4 steps -> checkpoint -> NEW trainer restores -> 4 more steps
    t_a = Trainer(TrainJobConfig(steps=4, checkpoint_every=4,
                                 checkpoint_dir=str(tmp_path / "ck"), **kw))
    t_a.run()
    t_a.save_checkpoint()
    t_b = Trainer(TrainJobConfig(steps=8, checkpoint_every=100,
                                 checkpoint_dir=str(tmp_path / "ck"), **kw))
    assert t_b.restore() == 4
    t_b.run(4)
    res_loss = t_b.metrics.series("loss")
    assert ref_loss[4:] == pytest.approx(res_loss, rel=1e-5)


def test_checkpoint_manifest_commit_is_atomic(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    import jax.numpy as jnp
    mgr = CheckpointManager(str(tmp_path), keep=2, use_async=False)
    commits = []
    mgr.on_commit(lambda step, path: commits.append(step))
    tree = {"w": jnp.arange(8.0), "b": {"x": jnp.ones((2, 2))}}
    for s in (1, 2, 3):
        mgr.save(s, tree, extra={"data": {"step": s}})
    assert commits == [1, 2, 3]
    assert mgr.all_steps() == [2, 3]          # keep=2 gc'd step 1
    restored, step, extra = mgr.restore(tree, step=3)
    assert step == 3 and extra["data"]["step"] == 3
    assert (restored["w"] == tree["w"]).all()


# =====================================================================
# Chaos: scripted master crashes against the durable pipeline plane.
# Every scenario asserts the tentpole contract — after any number of
# injected crash/restart cycles the DAG completes with every task
# executed EXACTLY once (handlers count executions per task id).
# =====================================================================
from collections import Counter

from repro.autoscale import ScalingPolicy
from repro.core.durability import LogStore
from repro.core.faults import ChaosHarness, FaultPlan, FaultPoint
from repro.core.plane import SimLocalPlane
from repro.pipelines import DAG, Task, HybridComposer


def _chaos_pipeline(n_tasks, autoscale=False, fanout=False):
    dur = LogStore()
    plane = ManagementPlane(durability=dur, replica_fanout=fanout)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a", local_plane=SimLocalPlane(caps=("cpu",)))
    plane.add_cluster("cloud-a", local_plane=SimLocalPlane(caps=("cpu",)))
    executed = Counter()

    def setup(w):
        w.register("count",
                   lambda p: executed.update([p["i"]]) or {"i": p["i"]})

    workers = {} if autoscale else {"onprem-a": ["w0", "w1"],
                                    "cloud-a": ["w2"]}
    comp = HybridComposer(plane, workers=workers, durability=dur,
                          worker_setup=setup)
    if autoscale:
        comp.attach_autoscaler(
            [ScalingPolicy(family="f", queues=("default",), min_replicas=0,
                           max_replicas=4, target_depth_per_worker=20.0)])
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="count", payload={"i": i})
                           for i in range(n_tasks)]))
    return plane, comp, executed


def _assert_exactly_once(executed, n):
    dups = {k: v for k, v in executed.items() if v > 1}
    missing = [i for i in range(n) if i not in executed]
    assert not dups, f"duplicate executions: {dups}"
    assert not missing, f"lost executions: {missing}"
    assert sum(executed.values()) == n


def test_chaos_triple_crash_completes_exactly_once():
    plane, comp, executed = _chaos_pipeline(300)
    h = ChaosHarness(plane, comp, FaultPlan.crash_at_ops(40, 90, 150),
                     downtime_ticks=2)
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=400)
    assert h.crashes == 3
    _assert_exactly_once(executed, 300)
    # every recovery reports its replay work for the benchmark
    assert len(h.recoveries) == 3
    assert all(r["replayed"] > 0 for r in h.recoveries[1:])


def test_chaos_kill_master_mid_recovery_storm():
    # the second point lands inside the first crash's recovery barrier
    # (worker resync / reseed RPCs advance the same op counter), so the
    # restart path itself is killed and must be restartable from scratch
    plane, comp, executed = _chaos_pipeline(200)
    h = ChaosHarness(plane, comp, FaultPlan.crash_at_ops(50, 55),
                     downtime_ticks=1)
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=400)
    assert h.crashes == 2
    _assert_exactly_once(executed, 200)


def test_chaos_crash_between_pull_and_commit_retries_verbatim():
    # the worker has pulled + executed a batch and is about to commit its
    # rows: the crash lands just before that upsert_many is delivered. On
    # recovery the worker retries the stashed commit VERBATIM — handlers
    # never re-run, so the execution counter stays exactly-once.
    plane, comp, executed = _chaos_pipeline(120)
    h = ChaosHarness(plane, comp,
                     FaultPlan([FaultPoint(op_kind="upsert_many", hit=3)]),
                     downtime_ticks=2)
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=400)
    assert h.crashes == 1
    _assert_exactly_once(executed, 120)
    assert h.recoveries[0]["pipeline"]["retried_commits"] >= 1


def test_chaos_crash_during_autoscaler_drain():
    # scale-down drains + removes pods while the plan crashes the master:
    # a drained pod's final rows/acks must be durable BEFORE it leaves the
    # fleet (remove_worker forces the group commit), or its redelivered
    # batch re-executes — the exact bug this scenario regression-pins.
    plane, comp, executed = _chaos_pipeline(400, autoscale=True, fanout=True)
    h = ChaosHarness(plane, comp, FaultPlan.crash_at_ops(60, 200),
                     downtime_ticks=3)
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=600)
    assert h.crashes == 2
    _assert_exactly_once(executed, 400)
    assert any(r["pipeline"].get("adopted_pods", 0) > 0
               for r in h.recoveries)


def test_chaos_partition_then_crash_then_heal():
    # one worker cluster is cut off before it ever takes a lease, the
    # master then dies and recovers, and the cluster heals later: the
    # survivors' leases redeliver, the healed cluster rejoins, and the
    # run still completes exactly-once.
    plane, comp, executed = _chaos_pipeline(200)
    plan = FaultPlan([
        FaultPoint(action="partition", cluster="cloud-a", at_op=1),
        FaultPoint(at_op=60),
        FaultPoint(action="heal", cluster="cloud-a", at_op=120),
    ])
    h = ChaosHarness(plane, comp, plan, downtime_ticks=2)
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=600)
    assert h.crashes == 1
    _assert_exactly_once(executed, 200)


def test_chaos_seeded_plans_are_reproducible():
    plan_a = FaultPlan.seeded(7, crashes=3)
    plan_b = FaultPlan.seeded(7, crashes=3)
    assert [p.at_op for p in plan_a.points] == \
        [p.at_op for p in plan_b.points]
    assert [p.at_op for p in FaultPlan.seeded(8).points] != \
        [p.at_op for p in plan_a.points]


# =====================================================================
# Watch-over-replica crash recovery: remote watchers fed by the shipped
# envelopes must see every state transition EXACTLY once across a master
# crash — resumed feeds deliver no duplicates, reset-seeded feeds
# resynthesize the diff (tombstones included) instead of replaying the
# world.
# =====================================================================


def _watch_plane():
    dur = LogStore()
    plane = ManagementPlane(durability=dur, replica_fanout=True)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("c0", local_plane=SimLocalPlane(caps=("cpu",)))
    plane.tick(n=2)                          # bootstrap seed ships + acks
    return plane, dur


def _crash_and_recover(plane, dur, downtime=2):
    dur.lose_uncommitted()
    plane.fabric.partition_cluster(plane.master)
    for _ in range(downtime):
        plane.fabric.tick(1.0)
    return plane.recover_global_plane()


def test_watchers_see_no_gap_or_dup_across_resumed_recovery():
    """Reachable replica at recovery: the rebuilt shipper resumes the feed
    from the replica's horizon — the watcher's event stream is seamless."""
    plane, dur = _watch_plane()
    agent = plane.agents["c0"]
    seen = []
    agent.watch_local("/queues/", lambda e, k, v, r: seen.append((e, k, r)))
    plane.overwatch.handle({"op": "put", "key": "/queues/a",
                            "value": {"ready": 1, "inflight": 0}})
    plane.tick()                             # shipped + group-committed
    _crash_and_recover(plane, dur)
    assert agent.replica.stats["resets"] == 0   # resumed, not reseeded
    plane.overwatch.handle({"op": "put", "key": "/queues/b",
                            "value": {"ready": 2, "inflight": 0}})
    plane.tick(n=2)
    q_events = [(e, k) for e, k, _ in seen if k.startswith("/queues/")]
    assert q_events == [("put", "/queues/a"), ("put", "/queues/b")]
    revs = [r for _, _, r in seen]
    assert revs == sorted(revs)


def test_partitioned_watcher_gets_tombstones_via_reset_seed():
    """Unreachable replica at recovery: the feed is reseeded with a reset
    marker, and the first envelope after heal delivers the DIFF — a
    tombstone for the key deleted during the outage, one put for the new
    key, silence for the key the watcher already holds."""
    plane, dur = _watch_plane()
    agent = plane.agents["c0"]
    plane.overwatch.handle({"op": "put", "key": "/queues/keep",
                            "value": {"ready": 1, "inflight": 0}})
    plane.overwatch.handle({"op": "put", "key": "/queues/doomed",
                            "value": {"ready": 2, "inflight": 0}})
    plane.tick(n=2)
    seen = []
    agent.watch_local("/queues/", lambda e, k, v, r: seen.append((e, k)))
    plane.fabric.partition_cluster("c0")     # ships can no longer land
    plane.overwatch.handle({"op": "delete", "key": "/queues/doomed"})
    plane.overwatch.handle({"op": "put", "key": "/queues/new",
                            "value": {"ready": 3, "inflight": 0}})
    plane.tick()
    _crash_and_recover(plane, dur)
    assert plane.shipper._feeds["c0"].reset  # unreachable -> reset seed
    plane.fabric.heal_cluster("c0")
    plane.tick(n=2)
    assert agent.replica.stats["resets"] == 1
    q = [ev for ev in seen if ev[1].startswith("/queues/")]
    assert ("delete", "/queues/doomed") in q
    assert ("put", "/queues/new") in q
    assert not any(k == "/queues/keep" for _, k in q)
    assert len(q) == 2                       # exactly the diff, once
    assert agent.replica.get("/queues/doomed") is None
    # and the view the composer gates on agrees with the primary
    assert agent.local_view("/queues/").items() == \
        plane.overwatch.handle({"op": "range", "prefix": "/queues/"})["items"]


def test_replica_ahead_of_lossy_recovery_forces_reset():
    """A shipped-but-uncommitted write leaves the replica AHEAD of the
    recovered store; rev-based dedupe would silently eat legitimate events
    forever, so the shipper must detect it and reseed with a reset — the
    watcher sees the store revert exactly once."""
    plane, dur = _watch_plane()
    agent = plane.agents["c0"]
    plane.overwatch.handle({"op": "put", "key": "/queues/x",
                            "value": {"ready": 1, "inflight": 0}})
    plane.tick()                             # committed + shipped
    seen = []
    agent.watch_local("/queues/", lambda e, k, v, r: seen.append((e, k, v)))
    plane.overwatch.handle({"op": "put", "key": "/queues/x",
                            "value": {"ready": 9, "inflight": 0}})
    plane.shipper.ship_all()                 # shipped WITHOUT group commit
    assert agent.replica.get("/queues/x")["ready"] == 9
    _crash_and_recover(plane, dur)           # the v=9 record evaporates
    plane.tick(n=2)
    assert agent.replica.stats["resets"] == 1
    # the revert landed as ONE put, and the replica matches the store again
    xs = [v for _, k, v in seen if k == "/queues/x"]
    assert xs == [{"ready": 9, "inflight": 0}, {"ready": 1, "inflight": 0}]
    assert agent.replica.get("/queues/x")["ready"] == 1


def test_chaos_watcher_stream_consistent_after_triple_crash():
    """End-to-end: a depth watcher riding the chaos pipeline never sees a
    revision go backwards and converges to the primary after three crashes."""
    plane, comp, executed = _chaos_pipeline(300, fanout=True)
    agent = plane.agents["onprem-a"]
    revs = []
    agent.watch_local("/queues/", lambda e, k, v, r: revs.append(r))
    h = ChaosHarness(plane, comp, FaultPlan.crash_at_ops(40, 90, 150),
                     downtime_ticks=2)
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=400)
    assert h.crashes == 3
    _assert_exactly_once(executed, 300)
    assert revs and revs == sorted(revs)
    assert agent.local_view("/queues/").items() == \
        plane.overwatch.handle({"op": "range", "prefix": "/queues/"})["items"]


# ----------------------------------------------- workload resume (warm fleet)
def test_redelivered_train_task_resumes_not_reruns(tmp_path):
    """A train task's worker dies AFTER the checkpoint committed but BEFORE
    the taskdb/ack commit: the redelivered copy restores the committed step
    and runs ZERO steps — exactly-once step accounting rides the checkpoint,
    whatever the delivery count."""
    from repro.runtime.step_cache import run_train_task

    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    comp = HybridComposer(plane, workers={"onprem-a": ["w1"]})
    comp.broker.lease = 5.0
    payload = {"arch": "qwen3-0.6b", "seq_len": 8, "global_batch": 2,
               "steps": 4, "checkpoint_every": 2,
               "checkpoint_dir": str(tmp_path / "ck")}
    comp.add_dag(DAG("r", [Task("t", kind="train", payload=payload)]))
    comp.scheduler.tick()                # stage the task onto the broker
    w1 = comp.workers[0]
    assert w1.pull_phase() == 1          # w1 leases it...
    run_train_task(None, dict(payload))  # ...runs it (checkpoint commits)...
    comp.workers.remove(w1)              # ...and dies before commit/ack
    plane.tick(n=8)                      # lease expires -> redelivery
    comp.add_worker("w2", "onprem-a")
    assert comp.run_dag("r", max_ticks=80)
    row = comp.taskdb.handle({"op": "latest", "dag": "r", "task": "t"})["row"]
    assert row["worker"] == "w2"
    assert row["result"]["steps"] == 4 and row["result"]["ran_steps"] == 0
    assert row["result"]["resumed_from"] == 4


def test_eval_fails_on_half_written_checkpoint(tmp_path):
    """Regression: an eval task pointed at a torn or absent checkpoint must
    FAIL (strict restore), never silently score fresh params as a success."""
    ck = tmp_path / "ck"
    tr = Trainer(TrainJobConfig(arch="qwen3-0.6b", seq_len=8, global_batch=2,
                                steps=2, checkpoint_dir=str(ck)))
    tr.run()
    tr.save_checkpoint()
    # tear the committed checkpoint: truncate one leaf under the manifest
    leaf = sorted((ck / "step_00000002").glob("leaf_*.bin"))[0]
    leaf.write_bytes(leaf.read_bytes()[:-4])

    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    comp = HybridComposer(plane, workers={"onprem-a": ["w1"]})
    base = {"arch": "qwen3-0.6b", "seq_len": 8, "global_batch": 2}
    comp.add_dag(DAG("e", [
        Task("torn", kind="eval", retries=0,
             payload={**base, "restore_from": {"path": str(ck)}}),
        Task("absent", kind="eval", retries=0,
             payload={**base,
                      "restore_from": {"path": str(tmp_path / "nowhere")}}),
    ]))
    assert comp.run_dag("e", max_ticks=80) is False
    state = comp.taskdb.handle({"op": "dag_state", "dag": "e"})["tasks"]
    assert state["torn"]["status"] == "failed"
    assert state["absent"]["status"] == "failed"
    assert "result" not in state["torn"] or not (
        state["torn"].get("result") or {}).get("eval_loss")


# =====================================================================
# Multi-master global plane: epoch-fenced shard map, live migration,
# chaos-tested master failover. Every scenario asserts the same
# contract — exactly-once pipeline completion and zero lost/duplicated
# shard keys — with single fault DOMAINS dying instead of the whole
# global plane.
# =====================================================================
from repro.core.shardmap import MIGRATION_STEPS
from repro.core.transport import StaleEpochError


def _mm_pipeline(n_tasks, num_masters=3, broker_shards=2, fanout=False,
                 metrics_every=None):
    dur = LogStore()
    plane = ManagementPlane(durability=dur, replica_fanout=fanout,
                            num_masters=num_masters,
                            metrics_every=metrics_every)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a", local_plane=SimLocalPlane(caps=("cpu",)))
    plane.add_cluster("cloud-a", local_plane=SimLocalPlane(caps=("cpu",)))
    executed = Counter()

    def setup(w):
        w.register("count",
                   lambda p: executed.update([p["i"]]) or {"i": p["i"]})

    comp = HybridComposer(plane,
                          workers={"onprem-a": ["w0", "w1"],
                                   "cloud-a": ["w2"]},
                          durability=dur, broker_shards=broker_shards,
                          worker_setup=setup)
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="count", payload={"i": i})
                           for i in range(n_tasks)]))
    return plane, comp, executed


def _other_master(co, shard):
    return next(n for n in sorted(co.masters) if n != co.owner_of(shard))


def test_single_master_plane_builds_no_coordinator():
    # num_masters=1 (the default everywhere else in this suite) must stay
    # byte-identical to the seed single-process plane: no coordinator, no
    # epoch stamping on any client
    plane, comp, executed = _mm_pipeline(20, num_masters=1)
    assert plane.coordinator is None
    assert not plane.master_agent.ow.fenced
    assert comp.run_dag("d", max_ticks=120)
    _assert_exactly_once(executed, 20)


def test_live_migration_under_load_exactly_once():
    # migrate a loaded broker shard AND the overwatch shard mid-run: the
    # run completes exactly-once and each freeze window stays bounded
    plane, comp, executed = _mm_pipeline(200)
    co = plane.coordinator
    for _ in range(4):
        comp.tick()
    assert co.migrate("broker-s0", _other_master(co, "broker-s0"))
    assert co.migrate("ow-shard-0", _other_master(co, "ow-shard-0"))
    assert comp.run_dag("d", max_ticks=400)
    _assert_exactly_once(executed, 200)
    while co.busy:                  # the run can outrace the 4-step protocol
        comp.tick()
    assert co.epoch == 2 and co.stats["migrations"] == 2
    # bounded unavailability: a 4-step migration freezes its shard for a
    # handful of ticks, not the run
    for shard, ticks in co.frozen_ticks_by_shard.items():
        assert ticks <= 6, (shard, ticks)


def test_concurrent_writes_during_freeze_bounce_then_land():
    # writes racing the freeze window bounce with a stale-epoch hint and
    # land on retry: no key is lost, none lands twice (revisions monotonic)
    dur = LogStore()
    plane = ManagementPlane(durability=dur, num_masters=3)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("cloud-a", local_plane=SimLocalPlane(caps=("cpu",)))
    co = plane.coordinator
    ow = plane.master_agent.ow
    assert ow.fenced
    assert co.migrate("ow-shard-0", _other_master(co, "ow-shard-0"))
    pending, written, bounced = [], {}, 0
    for i in range(20):
        if i < 12:
            pending.append((f"/telemetry/load-{i:02d}", {"i": i}))
        retry = []
        for key, val in pending:
            try:
                ow.put(key, val)
                written[key] = val
            except StaleEpochError:
                bounced += 1
                retry.append((key, val))
        pending = retry
        plane.tick()
    assert not pending, f"writes never landed: {pending}"
    assert bounced > 0 and co.stats["stale_epoch_rejections"] > 0
    assert co.epoch == 1 and co.stats["migrations"] == 1
    items = plane.overwatch.handle(
        {"op": "range", "prefix": "/telemetry/load-"})["items"]
    assert set(items) == set(written)
    for key, val in written.items():
        assert plane.overwatch.handle(
            {"op": "get", "key": key})["value"] == val


@pytest.mark.parametrize("step", MIGRATION_STEPS)
def test_chaos_kill_source_master_at_each_migration_step(step):
    # the migration SOURCE dies at every protocol boundary: pre-transfer
    # the migration degrades to a WAL failover, post-transfer the exported
    # payload finishes the live path — either way exactly-once holds
    plane, comp, executed = _mm_pipeline(120)
    co = plane.coordinator
    src = co.owner_of("broker-s0")
    plan = FaultPlan([FaultPoint(site=f"migrate:broker-s0:{step}",
                                 action="kill_master", cluster=src)])
    h = ChaosHarness(plane, comp, plan)
    for _ in range(3):
        h.tick()
    assert co.migrate("broker-s0", _other_master(co, "broker-s0"))
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=500)
    _assert_exactly_once(executed, 120)
    while co.busy:                  # the run can outrace the protocol
        h.tick()
    assert h.injector.fired and not co.masters[src].alive
    owner = co.owner_of("broker-s0")
    assert owner != src and co.masters[owner].alive
    assert not co.frozen("broker-s0")


def test_chaos_partition_during_flip_both_epoch_halves():
    # the fabric splits exactly at the flip: one half of the fleet keeps
    # the pre-flip epoch, the other learns the new one. The cut cluster's
    # first fenced write after heal bounces once (stale epoch) and lands
    # on the piggybacked refresh; nothing is lost on either half.
    plane, comp, executed = _mm_pipeline(150, fanout=True)
    co = plane.coordinator
    plan = FaultPlan([FaultPoint(site="migrate:ow-shard-0:flip",
                                 action="partition", cluster="cloud-a")])
    h = ChaosHarness(plane, comp, plan)
    for _ in range(3):
        h.tick()
    assert co.migrate("ow-shard-0", _other_master(co, "ow-shard-0"))
    while co.busy:
        h.tick()
    assert h.injector.fired and co.epoch == 1
    plane.fabric.heal_cluster("cloud-a")
    cut = plane.agents["cloud-a"].ow
    pre = cut.stats["stale_epoch_retries"]
    cut.put("/telemetry/cloud-a-probe", {"half": "old-epoch"})
    assert cut.stats["stale_epoch_retries"] == pre + 1
    plane.master_agent.ow.put("/telemetry/master-probe",
                              {"half": "new-epoch"})
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=500)
    _assert_exactly_once(executed, 150)
    items = plane.overwatch.handle(
        {"op": "range", "prefix": "/telemetry/"})["items"]
    assert "/telemetry/cloud-a-probe" in items
    assert "/telemetry/master-probe" in items


def test_chaos_double_failover_kills_target_too():
    # kill a master, then kill the failover TARGET while the repair
    # migration is still in flight: the coordinator re-detects the dead
    # owner and fails over again to the last survivor
    plane, comp, executed = _mm_pipeline(150)
    co = plane.coordinator
    h = ChaosHarness(plane, comp)
    for _ in range(3):
        h.tick()
    victim = co.owner_of("broker-s0")
    plane.kill_master(victim)
    h.tick()                          # failover enqueued + first step
    target1 = next(m.target for m in co._active if m.shard == "broker-s0")
    plane.kill_master(target1)
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=600)
    _assert_exactly_once(executed, 150)
    while co.busy:                  # the run can outrace the repairs
        h.tick()
    final = co.owner_of("broker-s0")
    assert final not in (victim, target1) and co.masters[final].alive
    assert co.stats["failovers"] >= 2
    assert co.metrics()["masters_alive"] == 1


def test_shardmap_metrics_flow_through_replica_feed():
    # satellite: shardmap.epoch / per-shard counters ride the existing
    # /metrics/<cluster>/<section> replica fan-out
    plane, comp, executed = _mm_pipeline(60, fanout=True, metrics_every=1.0)
    co = plane.coordinator
    for _ in range(4):
        comp.tick()
    assert co.migrate("broker-s1", _other_master(co, "broker-s1"))
    assert comp.run_dag("d", max_ticks=300)
    _assert_exactly_once(executed, 60)
    for _ in range(6):                # let the final publish + ship land
        comp.tick()
    view = plane.agents["onprem-a"].local_view("/metrics/")
    row = view.get("/metrics/master/shardmap")
    assert row is not None
    assert row["epoch"] >= 1 and row["migrations"] >= 1
    assert row.get("broker-s1.migrations", 0) >= 1


def test_service_client_backoff_is_bounded_and_deterministic():
    # satellite: DeliveryError opens a seeded, sim-clock backoff window;
    # real attempts are bounded (gave_up fires instead of a hang) and two
    # clients with the same pod seed fail on identical schedules
    from types import SimpleNamespace
    from repro.core.transport import DeliveryError
    from repro.pipelines.services import ServiceClient

    def make(pod="w0"):
        fabric = SimpleNamespace(clock=0.0)
        attempts = []

        def send(*a, **k):
            attempts.append(fabric.clock)
            raise DeliveryError("down")
        fabric.send = send
        state = SimpleNamespace(dns={"broker": ("10.0.0.1", 6379)},
                                cluster="c")
        return ServiceClient(fabric, state, pod), fabric, attempts

    client, fabric, attempts = make()
    gave_up_at = None
    for tick in range(200):
        fabric.clock = float(tick)
        try:
            client.call("broker", {"op": "push"})
        except DeliveryError:
            pass
        if client.stats["gave_up"]:
            gave_up_at = tick
            break
    assert gave_up_at is not None               # bounded, never a hang
    assert len(attempts) == ServiceClient.MAX_ATTEMPTS
    assert client.stats["retries"] == ServiceClient.MAX_ATTEMPTS - 1
    assert client.stats["fast_fails"] == gave_up_at + 1 - len(attempts)
    client2, fabric2, attempts2 = make()
    for tick in range(gave_up_at + 1):
        fabric2.clock = float(tick)
        try:
            client2.call("broker", {"op": "push"})
        except DeliveryError:
            pass
    assert attempts2 == attempts                # pod-seeded determinism
    # recovery: a successful call clears the window
    fabric.send = lambda *a, **k: {"ok": True}
    fabric.clock += 20.0
    assert client.call("broker", {"op": "push"}) == {"ok": True}
    assert client.stats["recovered"] == 1
    assert client._down == {}


def test_scheduler_push_giveup_surfaces_failed_tasks():
    # satellite: a broker that stays unreachable past the push-retry bound
    # turns its tasks into FAILED rows — surfaced, never silently dropped
    # or hung
    from repro.pipelines.scheduler import Scheduler
    from repro.pipelines.taskdb import TaskDB
    from repro.core.transport import DeliveryError

    db = TaskDB()
    clock = [0.0]

    class StubClient:
        def call(self, service, msg):
            if service == "taskdb":
                return db.handle(dict(msg))
            raise DeliveryError("broker down forever")

    sched = Scheduler(StubClient(), clock_fn=lambda: clock[0])
    sched.add_dag(DAG("d", [Task("only", kind="count", retries=0)]))
    for i in range(40):
        clock[0] = float(i)
        sched.tick()
        if sched.dag_done("d"):
            break
    assert sched.dag_done("d") and not sched.dag_success("d")
    assert sched.stats["push_gave_up"] >= 1
    assert sched.stats["push_retries"] >= Scheduler.PUSH_MAX_ATTEMPTS
    assert sched.dag_status("d")["only"] == "failed"
