"""Titchener local-sync trainer: equivalence + boundary-traffic properties.

Key property: with H=1, no compression, outer_lr=1, momentum=0, local SGD over
P pods consuming the SAME total batch is exactly synchronous AdamW when P=1 —
and for P>1 the outer step applies the pod-mean delta (DiLoCo semantics).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as configs
from repro.launch.mesh import make_test_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.local_sgd import (LocalSGDConfig, dcn_bytes_per_round,
                                   init_local_sgd_state, make_round_fn)
from repro.parallel.sharding import MeshPlan

tmap = jax.tree_util.tree_map


def tiny_model():
    cfg = dataclasses.replace(configs.get("qwen3-0.6b").reduced(),
                              remat="none", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=128, num_heads=2,
                              num_kv_heads=1, head_dim=32)
    model = Model(cfg, MeshPlan(mesh=make_test_mesh(), fsdp=False))
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def batch(cfg, key, B=2, S=8):
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
            "loss_mask": jnp.ones((B, S), jnp.bfloat16)}


def test_single_pod_h1_equals_sync_adamw():
    cfg, model, params = tiny_model()
    opt_cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=1, total_steps=100,
                          weight_decay=0.0)
    lcfg = LocalSGDConfig(inner_steps=1, outer_lr=1.0, outer_momentum=0.0,
                          nesterov=False, compress=False)
    state = init_local_sgd_state(params, n_pods=1)
    round_fn = jax.jit(make_round_fn(model.loss_fn, opt_cfg, lcfg,
                                     spmd_axis=None))
    b = batch(cfg, jax.random.PRNGKey(1))
    stacked = tmap(lambda x: x[None, None], b)        # [H=1, P=1, ...]
    state, _ = round_fn(state, stacked)

    # reference: one synchronous AdamW step
    ref_state = init_opt_state(params)
    g = jax.grad(lambda p, bb: model.loss_fn(p, bb)[0])(params, b)
    ref_params, ref_state, _ = adamw_update(params, g, ref_state, opt_cfg)

    for a, r in zip(jax.tree_util.tree_leaves(state["master"]),
                    jax.tree_util.tree_leaves(ref_state["master"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=2e-5, atol=2e-5)


def test_round_reduces_loss_and_pods_stay_synced():
    cfg, model, params = tiny_model()
    opt_cfg = AdamWConfig(peak_lr=5e-3, warmup_steps=1, total_steps=1000,
                          weight_decay=0.0)
    lcfg = LocalSGDConfig(inner_steps=4, compress=True)
    state = init_local_sgd_state(params, n_pods=2)
    round_fn = jax.jit(make_round_fn(model.loss_fn, opt_cfg, lcfg,
                                     spmd_axis=None))

    def round_batches(r):
        rows = []
        for h in range(lcfg.inner_steps):
            key = jax.random.fold_in(jax.random.PRNGKey(7), r * 10 + h)
            pods = [batch(cfg, jax.random.fold_in(key, p)) for p in range(2)]
            rows.append(tmap(lambda *x: jnp.stack(x), *pods))
        return tmap(lambda *x: jnp.stack(x), *rows)

    eval_b = batch(cfg, jax.random.PRNGKey(99))

    def eval_loss():
        return float(model.loss_fn(tmap(
            lambda m: m.astype(jnp.bfloat16), state["master"]), eval_b)[0])

    loss0 = eval_loss()
    losses = []
    for r in range(6):
        state, metrics = round_fn(state, round_batches(r))
        losses.append(eval_loss())
    # The outer Nesterov step (DiLoCo lr=0.7, mu=0.9) overshoots around the
    # optimum of this 2-round toy problem, so the trajectory oscillates; assert
    # training makes clear progress rather than pinning one oscillation phase.
    assert min(losses) < loss0 - 0.1, (loss0, losses)
    # after the round, every pod's working copy equals the synced master
    for wp, gm in zip(jax.tree_util.tree_leaves(state["pod_params"]),
                      jax.tree_util.tree_leaves(state["master"])):
        np.testing.assert_array_equal(np.asarray(wp[0]), np.asarray(wp[1]))
        np.testing.assert_allclose(np.asarray(wp[0], np.float32),
                                   np.asarray(gm.astype(wp.dtype), np.float32))


def test_dcn_byte_accounting():
    cfg, model, params = tiny_model()
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    compressed = LocalSGDConfig(inner_steps=4, compress=True)
    plain = LocalSGDConfig(inner_steps=4, compress=False)
    c_bytes, sync_bytes = dcn_bytes_per_round(params, compressed)
    p_bytes, _ = dcn_bytes_per_round(params, plain)
    assert p_bytes == 8 * n_params                 # f32 delta, ring 2x
    assert c_bytes < p_bytes / 3.5                 # int8 ~ 4x smaller
    assert sync_bytes / c_bytes > 7                # H(4) x bf16->int8(2x) = 8x
