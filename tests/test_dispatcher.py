"""Dispatcher: routing rules, capability matching, placement, stragglers."""
import pytest

from repro.core.dispatcher import RoutingRule
from tests.conftest import make_plane


def test_capability_matching():
    plane = make_plane(2, caps={0: ("cpu",), 1: ("cpu", "gpu")})
    jid = plane.submit_job("sim", steps=5, tags={"requires": ("gpu",)})
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]
    assert placed["cluster"] == "onprem-1"


def test_no_eligible_cluster_raises():
    plane = make_plane(1)
    with pytest.raises(RuntimeError):
        plane.submit_job("sim", steps=5, tags={"requires": ("tpu-v5e",)})


def test_routing_rule_compliance_pinning(plane):
    plane.add_routing_rule(RoutingRule(
        name="pii-stays-onprem",
        match=lambda job: job.get("tags", {}).get("pii"),
        clusters=["onprem-a"]))
    jid = plane.submit_job("sim", steps=5, tags={"pii": True})
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]
    assert placed["cluster"] == "onprem-a"


def test_least_loaded_placement(plane):
    # saturate onprem-a, then expect next jobs elsewhere
    for _ in range(3):
        plane.add_routing_rule(RoutingRule(
            name="pin", match=lambda j: j["job_id"] == "pin-1",
            clusters=["onprem-a"]))
    plane.submit_job("sim", steps=100, job_id="pin-1")
    plane.tick(n=2)
    jid = plane.submit_job("sim", steps=5)
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]
    assert placed["cluster"] != "onprem-a"


def test_straggler_redispatch():
    plane = make_plane(3, rates={0: 1.0, 1: 1.0, 2: 0.01})
    pinning = {"on": True}                      # pins apply to initial placement only
    for i in range(3):
        plane.add_routing_rule(RoutingRule(
            name=f"pin-j{i}",
            match=lambda j, _i=i: pinning["on"] and j["job_id"] == f"j{_i}",
            clusters=[f"onprem-{i}"]))
    jids = [plane.submit_job("sim", steps=50, job_id=f"j{i}",
                             tags={"requires": ("cpu",)})
            for i in range(3)]
    pinning["on"] = False
    plane.tick(n=3)
    rates = {j: plane.job_status(j)["rate"] for j in jids}
    slow = [j for j, r in rates.items() if r <= 0.011]
    assert slow
    moved = plane.dispatcher.check_stragglers()
    assert any(m.startswith(f"{slow[0]}:") for m in moved)
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{slow[0]}/placement"})["value"]
    assert placed["cluster"] != "onprem-2"


def test_jobs_complete_and_report(plane):
    jid = plane.submit_job("sim", steps=5)
    assert plane.run_until_done([jid], max_ticks=30)
    st = plane.job_status(jid)
    assert st["status"] == "done" and st["progress"] == 5.0
