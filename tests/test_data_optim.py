"""Data pipeline determinism/shard invariance (hypothesis), AdamW, compression,
schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data.pipeline import SyntheticTokens


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([1, 2, 4, 8]), st.integers(0, 3))
def test_data_is_pure_function_of_step_and_shard(step, num_shards, seed):
    kw = dict(vocab_size=512, seq_len=16, global_batch=8, seed=seed)
    a = SyntheticTokens(num_shards=num_shards, shard_id=0, **kw)
    b = SyntheticTokens(num_shards=num_shards, shard_id=0, **kw)
    x, y = a.batch_at(step), b.batch_at(step)
    assert (np.asarray(x["tokens"]) == np.asarray(y["tokens"])).all()


def test_targets_are_shifted_tokens():
    d = SyntheticTokens(vocab_size=512, seq_len=16, global_batch=4)
    b = d.batch_at(3)
    assert (np.asarray(b["tokens"][:, 1:]) ==
            np.asarray(b["targets"][:, :-1])).all()


def test_checkpoint_roundtrip_resumes_exactly():
    d = SyntheticTokens(vocab_size=512, seq_len=8, global_batch=2)
    for _ in range(5):
        next(d)
    saved = d.state_dict()
    want = next(d)
    d2 = SyntheticTokens(vocab_size=512, seq_len=8, global_batch=2)
    d2.load_state_dict(saved)
    got = next(d2)
    assert (np.asarray(want["tokens"]) == np.asarray(got["tokens"])).all()


def test_adamw_decreases_loss_on_quadratic():
    from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2
    assert int(state["step"]) == 100


def test_grad_clip_bounds_update():
    from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=1, total_steps=10,
                      grad_clip=1.0, weight_decay=0.0)
    g = {"w": jnp.full(4, 1e6)}
    p2, state, m = adamw_update(params, g, state, cfg)
    assert m["grad_norm"] > 1e5
    assert np.abs(np.asarray(p2["w"])).max() < 10.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 5))
def test_int8_error_feedback_reduces_bias(seed):
    """EF property: quantize(x + ef) accumulated over repeats -> mean error
    vanishes vs one-shot quantization error."""
    from repro.optim.compression import compress_tree, init_error_feedback
    key = jax.random.PRNGKey(seed)
    x = {"g": jax.random.normal(key, (256,)) * 0.3}
    ef = init_error_feedback(x)
    acc = jnp.zeros((256,))
    n = 16
    for _ in range(n):
        (q, s), ef = compress_tree(x, ef)
        acc = acc + q["g"].astype(jnp.float32) * s["g"]
    mean_err = float(jnp.abs(acc / n - x["g"]).mean())
    (q1, s1), _ = compress_tree(x, init_error_feedback(x))
    oneshot_err = float(jnp.abs(q1["g"].astype(jnp.float32) * s1["g"]
                                - x["g"]).mean())
    assert mean_err <= oneshot_err * 0.55 + 1e-6


def test_warmup_cosine_shape():
    from repro.optim.schedules import warmup_cosine
    lr = lambda s: float(warmup_cosine(jnp.asarray(s), peak_lr=1.0,
                                       warmup_steps=10, total_steps=100))
    assert lr(0) < lr(5) < lr(10)
    assert abs(lr(10) - 1.0) < 1e-5
    assert lr(50) < 1.0 and lr(100) < lr(50)
