"""Paper §5: the hybrid Composer — DAGs, scheduler, broker, workers, ACLs."""
import pytest

from repro.core.plane import ManagementPlane
from repro.core.transport import DeliveryError
from repro.pipelines import DAG, Task, HybridComposer
from repro.pipelines.dag import DAG as DAG2
from repro.pipelines.services import ServiceClient


def test_dag_validation_and_topo():
    dag = DAG("d", [Task("a"), Task("b", upstream=("a",)),
                    Task("c", upstream=("a",)), Task("d", upstream=("b", "c"))])
    order = dag.topological_order()
    assert order.index("a") < order.index("b") < order.index("d")
    with pytest.raises(ValueError):
        DAG2("cyc", [Task("x", upstream=("y",)), Task("y", upstream=("x",))])
    with pytest.raises(ValueError):
        DAG2("dup", [Task("x"), Task("x")])


@pytest.fixture
def composer():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    comp = HybridComposer(
        plane, workers={"master": ["w-pub"], "onprem-a": ["w-priv"]},
        worker_queues={"w-pub": ("default",),
                       "w-priv": ("onprem", "default")})
    return plane, comp


def test_hybrid_dag_runs_across_clouds(composer):
    plane, comp = composer
    seen_workers = {}

    def probe(payload):
        return {"ok": 1}

    dag = DAG("run", [
        Task("etl", kind="etl", payload={"batches": 1}),
        Task("private_step", kind="python", upstream=("etl",),
             requires=("onprem",)),
        Task("final", kind="python", upstream=("private_step",)),
    ])
    comp.add_dag(dag)
    assert comp.run_dag("run", max_ticks=80)
    state = comp.taskdb.handle({"op": "dag_state", "dag": "run"})["tasks"]
    # the compliance-tagged task ran on the private worker
    assert state["private_step"]["worker"] == "w-priv"
    assert state["etl"]["result"]["tokens"] > 0


def test_failed_task_retries_then_blocks_downstream(composer):
    plane, comp = composer
    calls = {"n": 0}

    def flaky(payload):
        calls["n"] += 1
        raise RuntimeError("boom")

    for w in comp.workers:
        w.register("flaky", flaky)
    dag = DAG("f", [Task("bad", kind="flaky", retries=1),
                    Task("after", kind="python", upstream=("bad",))])
    comp.add_dag(dag)
    assert comp.run_dag("f", max_ticks=80) is False
    st = comp.status("f")
    assert st["bad"] == "failed" and st["after"] == "upstream_failed"
    assert calls["n"] == 2                     # initial + one retry


def test_broker_redelivers_on_lost_worker(composer):
    plane, comp = composer
    comp.broker.lease = 5.0
    comp.broker.handle({"op": "push", "queue": "default", "msg": {"k": 1}})
    got = comp.broker.handle({"op": "pull", "queue": "default"})
    assert got["msg"] == {"k": 1}
    # no ack; advance the clock past the lease -> message redelivered
    plane.tick(n=8)
    again = comp.broker.handle({"op": "pull", "queue": "default"})
    assert again["msg"] == {"k": 1}


def test_workers_use_only_gateway_routes(composer):
    """A pod NOT in the dependency graph cannot reach the broker (Algorithm 3)."""
    plane, comp = composer
    rogue = ServiceClient(plane.fabric, plane.agents["onprem-a"].state,
                          "not-in-spec")
    with pytest.raises(DeliveryError):
        rogue.call("broker", {"op": "depth", "queue": "default"})


def test_train_task_through_pipeline(composer):
    plane, comp = composer
    dag = DAG("t", [Task("train_tiny", kind="train",
                         payload={"arch": "qwen3-0.6b", "steps": 2,
                                  "seq_len": 8, "global_batch": 2})])
    comp.add_dag(dag)
    assert comp.run_dag("t", max_ticks=60)
    row = comp.taskdb.handle({"op": "latest", "dag": "t",
                              "task": "train_tiny"})["row"]
    assert row["result"]["steps"] == 2
    assert row["result"]["loss"] is not None


def test_mid_dag_train_resume_across_worker_retire(tmp_path):
    """Mid-DAG resume with an elastic fleet: stage 1 trains to step 4 and
    checkpoints; the autoscaler retires the idle worker (scale-to-zero);
    stage 2 raises the target to 8, and the freshly spawned pod restores the
    committed step and runs exactly the 4-step remainder — exactly-once step
    accounting across retire/re-spawn."""
    from repro.autoscale import ScalingPolicy
    from repro.core.plane import SimLocalPlane

    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a", local_plane=SimLocalPlane(caps=("cpu",)))
    comp = HybridComposer(plane, workers={})
    asc = comp.attach_autoscaler(
        [ScalingPolicy(family="default", queues=("default",),
                       requires=("cpu",), target_depth_per_worker=8,
                       min_replicas=0, max_replicas=1,
                       up_cooldown=0.0, down_cooldown=0.0)])
    base = {"arch": "qwen3-0.6b", "seq_len": 8, "global_batch": 2,
            "checkpoint_every": 2, "checkpoint_dir": str(tmp_path / "ck")}
    comp.add_dag(DAG("s1", [Task("t", kind="train",
                                 payload={**base, "steps": 4})]))
    assert comp.run_dag("s1", max_ticks=120)
    row1 = comp.taskdb.handle({"op": "latest", "dag": "s1",
                               "task": "t"})["row"]
    assert row1["result"]["ran_steps"] == 4
    assert row1["result"]["checkpoint"]["step"] == 4
    # queues now empty -> the policy drains and retires the pod
    for _ in range(200):
        comp.tick()
        if asc.replicas("default") == 0 and not comp.workers:
            break
    assert asc.replicas("default") == 0 and not comp.workers
    comp.add_dag(DAG("s2", [Task("t", kind="train",
                                 payload={**base, "steps": 8})]))
    assert comp.run_dag("s2", max_ticks=120)
    row2 = comp.taskdb.handle({"op": "latest", "dag": "s2",
                               "task": "t"})["row"]
    assert row2["worker"] != row1["worker"]        # a different pod
    assert row2["result"]["resumed_from"] == 4
    assert row2["result"]["ran_steps"] == 4
    assert row2["result"]["steps"] == 8
