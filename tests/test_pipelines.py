"""Paper §5: the hybrid Composer — DAGs, scheduler, broker, workers, ACLs."""
import pytest

from repro.core.plane import ManagementPlane
from repro.core.transport import DeliveryError
from repro.pipelines import DAG, Task, HybridComposer
from repro.pipelines.dag import DAG as DAG2
from repro.pipelines.services import ServiceClient


def test_dag_validation_and_topo():
    dag = DAG("d", [Task("a"), Task("b", upstream=("a",)),
                    Task("c", upstream=("a",)), Task("d", upstream=("b", "c"))])
    order = dag.topological_order()
    assert order.index("a") < order.index("b") < order.index("d")
    with pytest.raises(ValueError):
        DAG2("cyc", [Task("x", upstream=("y",)), Task("y", upstream=("x",))])
    with pytest.raises(ValueError):
        DAG2("dup", [Task("x"), Task("x")])


@pytest.fixture
def composer():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    comp = HybridComposer(
        plane, workers={"master": ["w-pub"], "onprem-a": ["w-priv"]},
        worker_queues={"w-pub": ("default",),
                       "w-priv": ("onprem", "default")})
    return plane, comp


def test_hybrid_dag_runs_across_clouds(composer):
    plane, comp = composer
    seen_workers = {}

    def probe(payload):
        return {"ok": 1}

    dag = DAG("run", [
        Task("etl", kind="etl", payload={"batches": 1}),
        Task("private_step", kind="python", upstream=("etl",),
             requires=("onprem",)),
        Task("final", kind="python", upstream=("private_step",)),
    ])
    comp.add_dag(dag)
    assert comp.run_dag("run", max_ticks=80)
    state = comp.taskdb.handle({"op": "dag_state", "dag": "run"})["tasks"]
    # the compliance-tagged task ran on the private worker
    assert state["private_step"]["worker"] == "w-priv"
    assert state["etl"]["result"]["tokens"] > 0


def test_failed_task_retries_then_blocks_downstream(composer):
    plane, comp = composer
    calls = {"n": 0}

    def flaky(payload):
        calls["n"] += 1
        raise RuntimeError("boom")

    for w in comp.workers:
        w.register("flaky", flaky)
    dag = DAG("f", [Task("bad", kind="flaky", retries=1),
                    Task("after", kind="python", upstream=("bad",))])
    comp.add_dag(dag)
    assert comp.run_dag("f", max_ticks=80) is False
    st = comp.status("f")
    assert st["bad"] == "failed" and st["after"] == "upstream_failed"
    assert calls["n"] == 2                     # initial + one retry


def test_broker_redelivers_on_lost_worker(composer):
    plane, comp = composer
    comp.broker.lease = 5.0
    comp.broker.handle({"op": "push", "queue": "default", "msg": {"k": 1}})
    got = comp.broker.handle({"op": "pull", "queue": "default"})
    assert got["msg"] == {"k": 1}
    # no ack; advance the clock past the lease -> message redelivered
    plane.tick(n=8)
    again = comp.broker.handle({"op": "pull", "queue": "default"})
    assert again["msg"] == {"k": 1}


def test_workers_use_only_gateway_routes(composer):
    """A pod NOT in the dependency graph cannot reach the broker (Algorithm 3)."""
    plane, comp = composer
    rogue = ServiceClient(plane.fabric, plane.agents["onprem-a"].state,
                          "not-in-spec")
    with pytest.raises(DeliveryError):
        rogue.call("broker", {"op": "depth", "queue": "default"})


def test_train_task_through_pipeline(composer):
    plane, comp = composer
    dag = DAG("t", [Task("train_tiny", kind="train",
                         payload={"arch": "qwen3-0.6b", "steps": 2,
                                  "seq_len": 8, "global_batch": 2})])
    comp.add_dag(dag)
    assert comp.run_dag("t", max_ticks=60)
    row = comp.taskdb.handle({"op": "latest", "dag": "t",
                              "task": "train_tiny"})["row"]
    assert row["result"]["steps"] == 2
    assert row["result"]["loss"] is not None
