"""Pallas kernel validation: interpret=True kernel body vs ref.py oracle,
swept over shapes and dtypes; blocked (CPU lowering target) vs oracle; custom
flash VJP vs autodiff-of-oracle gradients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _qkv(B, S, H, K, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32).astype(dtype)
    return q, k, v


FLASH_SWEEP = [
    # B, S, H, K, D, causal, window
    (1, 128, 4, 4, 64, True, 0),
    (2, 256, 4, 2, 64, True, 0),        # GQA
    (1, 256, 8, 1, 32, True, 0),        # MQA, small head
    (1, 128, 4, 4, 64, False, 0),       # bidirectional (encoder)
    (1, 256, 4, 2, 64, True, 64),       # sliding window
    (1, 96, 2, 2, 80, True, 0),         # ragged: S % block, D % 128 != 0
]


@pytest.mark.parametrize("B,S,H,K,D,causal,window", FLASH_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_interpret_vs_ref(B, S, H, K, D, causal, window, dtype):
    q, k, v = _qkv(B, S, H, K, D, dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="pallas", interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,H,K,D,causal,window", FLASH_SWEEP)
def test_flash_blocked_vs_ref(B, S, H, K, D, causal, window):
    q, k, v = _qkv(B, S, H, K, D, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="blocked", blk_kv=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_custom_vjp_matches_autodiff_oracle():
    q, k, v = _qkv(1, 128, 4, 2, 64, jnp.float32)

    def loss_blocked(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, impl="blocked",
                                           blk_kv=64) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True) ** 2)

    g1 = jax.grad(loss_blocked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


SSD_SWEEP = [
    # B, S, H, P, N, chunk
    (1, 128, 2, 32, 16, 32),
    (2, 256, 4, 64, 32, 64),
    (1, 100, 2, 32, 16, 32),            # ragged S % chunk
]


@pytest.mark.parametrize("B,S,H,P,N,chunk", SSD_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_interpret_vs_ref(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    a = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.2)
    bm = jax.random.normal(ks[3], (B, S, N), jnp.float32).astype(dtype)
    cm = jax.random.normal(ks[4], (B, S, N), jnp.float32).astype(dtype)
    out = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk, impl="pallas",
                       interpret=True)
    want, _ = ref.ssd_ref(x, dt, a, bm, cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,H,P,N,chunk", SSD_SWEEP)
def test_ssd_blocked_vs_ref_with_state(B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    a = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.2)
    bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    cm = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    y, h = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk, impl="blocked",
                        return_state=True)
    y_ref, h_ref = ref.ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_decode_step_matches_scan_tail():
    """Running S steps of the decode recurrence == the scan's final state/out."""
    B, S, H, P, N = 1, 32, 2, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    a = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.2)
    bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    cm = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    y_scan, h_scan = ref.ssd_ref(x, dt, a, bm, cm)
    h = jnp.zeros((B, H, N, P), jnp.float32)
    outs = []
    for t in range(S):
        y, h = ops.ssd_decode_step(x[:, t:t+1], dt[:, t:t+1], a,
                                   bm[:, t:t+1], cm[:, t:t+1], h)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_scan), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_scan),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(2, 64, 128), (1, 7, 256), (4, 1, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas_interpret_vs_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, jnp.float32).astype(dtype)
    sc = jnp.ones((shape[-1],), dtype) * 1.5
    out = ops.rmsnorm(x, sc, impl="pallas", interpret=True)
    want = ref.rmsnorm_ref(x, sc)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_attend_cache_matches_full_attention():
    """Decode attention against a cache == last-row of full causal attention."""
    B, S, H, K, D = 2, 64, 4, 2, 32
    q, k, v = _qkv(B, S, H, K, D, jnp.float32)
    full = ref.attention_ref(q, k, v, causal=True)
    pos = jnp.full((B,), S - 1, jnp.int32)
    out = ops.attend_cache(q[:, -1:], k, v, pos[:, None, None, None])
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-5, atol=1e-5)


def test_attend_cache_packed_matches_reference():
    """§Perf decode lever: packed GQA decode == repeat-based reference."""
    B, S, H, K, D = 2, 64, 8, 2, 32
    q, k, v = _qkv(B, S, H, K, D, jnp.float32)
    pos = jnp.array([S - 1, S // 2])[:, None, None, None]
    a = ops.attend_cache(q[:, -1:], k, v, pos)
    b = ops.attend_cache(q[:, -1:], k, v, pos, packed=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
    # and with a sliding window
    aw = ops.attend_cache(q[:, -1:], k, v, pos, window=16)
    bw = ops.attend_cache(q[:, -1:], k, v, pos, window=16, packed=True)
    np.testing.assert_allclose(np.asarray(aw), np.asarray(bw),
                               rtol=2e-5, atol=2e-5)
