"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real single
CPU device (the 512-device override belongs exclusively to launch/dryrun.py)."""
import pytest

from repro.core.plane import ManagementPlane, SimLocalPlane


@pytest.fixture
def plane():
    p = ManagementPlane()
    p.add_cluster("master", is_master=True)
    p.add_cluster("onprem-a")
    p.add_cluster("onprem-b")
    return p


def make_plane(n_private: int = 2, rates=None, caps=None) -> ManagementPlane:
    """Master is control-plane-only (the paper's always-on public master);
    compute jobs land on private clusters via requires=("cpu",)."""
    p = ManagementPlane()
    p.add_cluster("master", is_master=True,
                  local_plane=SimLocalPlane(caps=("control",)))
    for i in range(n_private):
        rate = (rates or {}).get(i, 1.0)
        cap = (caps or {}).get(i, ("cpu",))
        p.add_cluster(f"onprem-{i}", local_plane=SimLocalPlane(cap, rate))
    return p


CPU = {"requires": ("cpu",)}
