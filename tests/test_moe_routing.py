"""MoE routing behaviour: top-k selection, capacity drops, aux loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as configs
from repro.launch.mesh import make_test_mesh
from repro.models import moe as MOE
from repro.models.model import Model
from repro.parallel.sharding import MeshPlan


def setup(capacity=1.25):
    cfg = dataclasses.replace(configs.get("deepseek-moe-16b").reduced(),
                              remat="none", capacity_factor=capacity)
    plan = MeshPlan(mesh=make_test_mesh(), fsdp=False)
    model = Model(cfg, plan)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, plan, model, params


def test_router_topk_and_normalization():
    cfg, plan, model, params = setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.bfloat16)
    p = params["layers"]["moe"]
    p0 = jax.tree_util.tree_map(lambda a: a[0], p)
    probs, idx, w = MOE.router_probs(cfg, p0, x)
    assert idx.shape == (2, 8, cfg.top_k)
    assert w.shape == (2, 8, cfg.num_experts)   # dense combine weights over E
    s = np.asarray(jnp.sum(w, -1), np.float32)
    np.testing.assert_allclose(s, np.ones_like(s), rtol=1e-2, atol=1e-2)
    # indices are distinct per token
    ii = np.asarray(idx)
    for b in range(2):
        for t in range(8):
            assert len(set(ii[b, t])) == cfg.top_k


def test_aux_loss_penalizes_imbalance():
    cfg, plan, model, params = setup()
    E = cfg.num_experts
    # balanced probabilities -> aux ~ 1; collapsed -> aux ~ E
    bal = jnp.full((2, 8, E), 1.0 / E)
    idx_bal = jnp.tile(jnp.arange(cfg.top_k)[None, None], (2, 8, 1))
    col = jnp.zeros((2, 8, E)).at[:, :, 0].set(1.0)
    idx_col = jnp.zeros((2, 8, cfg.top_k), jnp.int32)
    a_bal = float(MOE.aux_load_balance_loss(cfg, bal, idx_bal))
    a_col = float(MOE.aux_load_balance_loss(cfg, col, idx_col))
    assert a_col > a_bal * 2


def test_capacity_drops_tokens_gracefully():
    """Tiny capacity must drop tokens (output != high-capacity) but stay finite."""
    cfg_hi, plan, model_hi, params = setup(capacity=8.0)
    cfg_lo = dataclasses.replace(cfg_hi, capacity_factor=0.05)
    model_lo = Model(cfg_lo, plan)
    x = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                      cfg_hi.vocab_size)}
    hi, _ = jax.jit(model_hi.forward)(params, x)
    lo, _ = jax.jit(model_lo.forward)(params, x)
    assert np.isfinite(np.asarray(lo, np.float32)).all()
    assert not np.allclose(np.asarray(hi, np.float32),
                           np.asarray(lo, np.float32), atol=1e-3)


def test_moe_decode_matches_block_at_high_capacity():
    cfg, plan, model, params = setup(capacity=8.0)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 1, cfg.d_model),
                          jnp.bfloat16)
    p0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"])
    y_block, _ = MOE.moe_block(cfg, p0, x, plan)
    y_dec = MOE.moe_block_decode(cfg, p0, x, plan)
    np.testing.assert_allclose(np.asarray(y_block, np.float32),
                               np.asarray(y_dec, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_combine_reshard_is_numerically_identical():
    """§Perf MoE lever: resharding slot buffers before the combine gather is a
    pure layout change — outputs must match exactly."""
    import dataclasses as dc
    cfg, plan, model, params = setup(capacity=2.0)
    plan2 = dc.replace(plan, moe_combine_reshard=True)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, cfg.d_model),
                          jnp.bfloat16)
    p0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"])
    y1, aux1 = MOE.moe_block(cfg, p0, x, plan)
    y2, aux2 = MOE.moe_block(cfg, p0, x, plan2)
    np.testing.assert_array_equal(np.asarray(y1, np.float32),
                                  np.asarray(y2, np.float32))
    assert float(aux1) == float(aux2)
