"""Durability layer: WAL group commit + snapshot/truncate (LogStore), the
on-disk backend's crash tolerance, and each store's recover() contract —
overwatch equivalence + lease grace, broker exactly-once replay + tag-epoch
stale-ack fencing, taskdb replay, checkpoint staleness validation."""
import json
import os

import pytest

from repro.core.durability import DirBackend, LogStore, MemoryBackend
from repro.core.overwatch import OverwatchService
from repro.core.transport import Fabric
from repro.pipelines.broker import TAG_EPOCH_STRIDE, Broker
from repro.pipelines.taskdb import TaskDB


# ------------------------------------------------------------------ LogStore
def test_group_commit_buffers_until_commit():
    dur = LogStore()
    dur.append("s", ("a", 1))
    dur.append("s", ("b", 2))
    assert dur.load("s") == (None, [])             # nothing durable yet
    assert dur.commit("s") == 2
    assert dur.load("s") == (None, [("a", 1), ("b", 2)])


def test_lose_uncommitted_drops_exactly_the_tail():
    dur = LogStore()
    dur.append("s", ("a",))
    dur.commit("s")
    dur.append("s", ("b",))
    dur.append("s", ("c",))
    assert dur.lose_uncommitted() == 2             # the crash window
    payload, records = dur.load("s")
    assert payload is None and records == [("a",)]
    assert dur.commit("s") == 0                    # tail really is gone


def test_snapshot_truncates_and_replay_starts_after_it():
    dur = LogStore()
    for i in range(5):
        dur.append("s", ("op", i))
    dur.commit("s")
    dur.snapshot("s", {"upto": 5})
    assert dur.records_since_snapshot("s") == 0
    dur.append("s", ("op", 5))
    dur.commit("s")
    assert dur.records_since_snapshot("s") == 1
    payload, records = dur.load("s")
    # LSN filtering: replay input is the snapshot + ONLY post-snapshot records
    assert payload == {"upto": 5}
    assert records == [("op", 5)]


def test_shards_are_independent():
    dur = LogStore()
    dur.append("a", 1)
    dur.append("b", 2)
    dur.commit("a")
    assert dur.load("a") == (None, [1])
    assert dur.load("b") == (None, [])
    assert dur.has_data("a") and not dur.has_data("b")


def test_fault_hook_fires_before_persistence():
    sites = []
    dur = LogStore(fault_hook=lambda kind, shard: sites.append((kind, shard)))
    dur.append("s", 1)
    dur.commit("s")
    dur.snapshot("s", {})
    assert sites == [("commit", "s"), ("snapshot", "s")]


# ---------------------------------------------------------------- DirBackend
def test_dirbackend_round_trip(tmp_path):
    dur = LogStore(DirBackend(str(tmp_path)))
    dur.append("s", ("put", "k", {"v": 1}))
    dur.commit("s")
    dur.snapshot("s", {"state": [1, 2]})
    dur.append("s", ("del", "k"))
    dur.commit("s")
    # a brand-new LogStore over the same directory (real process restart)
    dur2 = LogStore(DirBackend(str(tmp_path)))
    assert dur2.has_data("s")
    payload, records = dur2.load("s")
    assert payload == {"state": [1, 2]}
    # JSON round-trips tuples as lists: recovery code reads positionally
    assert records == [["del", "k"]]
    # LSNs continue past the reloaded history, no reuse
    dur2.append("s", ("x",))
    dur2.commit("s")
    assert dur2.records_since_snapshot("s") == 2


def test_dirbackend_torn_tail_is_dropped(tmp_path):
    dur = LogStore(DirBackend(str(tmp_path)))
    for i in range(3):
        dur.append("s", ("op", i))
    dur.commit("s")
    with open(tmp_path / "s.wal", "a", encoding="utf-8") as f:
        f.write('[4, ["op", 3')                   # crash mid-append
    payload, records = LogStore(DirBackend(str(tmp_path))).load("s")
    assert records == [["op", 0], ["op", 1], ["op", 2]]


def test_dirbackend_snapshot_truncates_wal_file(tmp_path):
    dur = LogStore(DirBackend(str(tmp_path)))
    for i in range(10):
        dur.append("s", ("op", i))
    dur.commit("s")
    dur.snapshot("s", {"n": 10})
    assert (tmp_path / "s.snap.json").exists()
    assert (tmp_path / "s.wal").read_text().strip() == ""   # truncated
    dur.append("s", ("tail",))
    dur.commit("s")
    payload, records = LogStore(DirBackend(str(tmp_path))).load("s")
    assert payload == {"n": 10} and records == [["tail"]]


# ----------------------------------------------------------------- overwatch
def _ow(dur, fabric=None, **kw):
    return OverwatchService(fabric or Fabric(), "m", durability=dur, **kw)


def test_overwatch_recovers_kv_revisions_and_indexes():
    dur = LogStore()
    ow = _ow(dur)
    ow.handle({"op": "put", "key": "/a/x", "value": 1})
    ow.handle({"op": "put", "key": "/a/y", "value": {"v": 2}})
    ow.handle({"op": "put", "key": "/b/z", "value": 3})
    ow.handle({"op": "delete", "key": "/b/z"})
    ow.sweep()                                     # the group commit
    ow2 = _ow(dur)                                 # auto-recovers in ctor
    assert ow2.handle({"op": "range", "prefix": "/"})["items"] == \
        {"/a/x": 1, "/a/y": {"v": 2}}
    assert ow2._rev == ow._rev                     # revision clock restored
    assert ow2.recovery_stats["replayed"] == 4
    # the restored clock keeps revisions monotone across the crash
    r = ow2.handle({"op": "put", "key": "/c", "value": 9})["revision"]
    assert r > ow._rev


def test_overwatch_snapshot_compaction_preserves_recovery():
    dur = LogStore()
    ow = _ow(dur, snapshot_every=8)
    for i in range(40):
        ow.handle({"op": "put", "key": f"/k/{i % 10}", "value": i})
        if i % 4 == 0:
            ow.sweep()
    ow.sweep()
    assert dur.stats["snapshots"] > 0              # compaction really ran
    ow2 = _ow(dur, snapshot_every=8)
    want = {f"/k/{i}": 30 + i for i in range(10)}
    assert ow2.handle({"op": "range", "prefix": "/k/"})["items"] == want
    assert ow2._rev == ow._rev
    # replay length is bounded by the snapshot cadence, not total history
    assert ow2.recovery_stats["replayed"] < 40


def test_overwatch_recovered_lease_gets_grace_then_expires():
    dur = LogStore()
    fab = Fabric()
    ow = _ow(dur, fabric=fab)
    lease = ow.handle({"op": "lease_grant", "ttl": 5.0})["lease"]
    ow.handle({"op": "put", "key": "/svc/ep", "value": "x", "lease": lease})
    ow.sweep()
    fab2 = Fabric()
    fab2.tick(4.0)                                 # restart happens at t=4
    ow2 = _ow(dur, fabric=fab2)
    assert ow2.recovery_stats["leases"] == 1
    # grace: expiry pushed to now+ttl so the surviving owner can keep alive
    assert ow2.handle({"op": "get", "key": "/svc/ep"})["value"] == "x"
    fab2.tick(5.5)                                 # ...but without keepalive
    assert ow2.handle({"op": "get", "key": "/svc/ep"})["value"] is None


def test_overwatch_without_durability_unchanged():
    ow = OverwatchService(Fabric(), "m")
    ow.handle({"op": "put", "key": "/a", "value": 1})
    ow.sweep()                                     # no durability: no-op path
    assert ow.recovery_stats == {}


# -------------------------------------------------------------------- broker
def _msg(i):
    return {"dag": "d", "task": f"t{i}", "kind": "python", "payload": {},
            "try": 1}


def test_broker_recover_requeues_inflight_and_flags_everything():
    dur = LogStore()
    b = Broker(durability=dur)
    b.handle({"op": "push_many", "queue": "q", "msgs": [_msg(i)
                                                       for i in range(5)]})
    pulled = b.handle({"op": "pull_many", "queue": "q", "max_n": 2})
    assert "redelivered" not in pulled             # clean path: no flags
    b.handle({"op": "ack", "tag": pulled["tags"][0]})
    dur.commit("broker")
    b2 = Broker(durability=dur)
    # the acked task is gone forever; the unacked lease + 3 ready survive
    got = b2.handle({"op": "pull_many", "queue": "q", "max_n": 10})
    names = sorted(m["task"] for m in got["msgs"])
    assert names == ["t1", "t2", "t3", "t4"]
    assert got["redelivered"] == [True] * 4        # all need a dedup probe
    assert b2.recovered_task_keys == {("d", f"t{i}", 1) for i in (1, 2, 3, 4)}
    assert b2.stats["recovered_inflight"] == 1


def test_broker_epoch_fences_pre_crash_tags():
    dur = LogStore()
    b = Broker(durability=dur)
    b.handle({"op": "push", "queue": "q", "msg": _msg(0)})
    old_tag = b.handle({"op": "pull", "queue": "q"})["tag"]
    dur.commit("broker")
    b2 = Broker(durability=dur)
    new_tag = b2.handle({"op": "pull", "queue": "q"})["tag"]
    assert new_tag >= TAG_EPOCH_STRIDE             # epoch bumped
    assert new_tag != old_tag
    # a survivor worker acking its pre-crash lease: idempotent success,
    # counted, and it can NOT release the new lease
    resp = b2.handle({"op": "ack_many", "tags": [old_tag]})
    assert resp == {"ok": True, "acked": 0}
    assert b2.stats["stale_acks"] == 1
    assert len(b2.inflight) == 1                   # new lease untouched


def test_broker_snapshot_compaction_equivalence():
    dur = LogStore()
    b = Broker(durability=dur)
    b.handle({"op": "push_many", "queue": "q", "msgs": [_msg(i)
                                                       for i in range(6)]})
    got = b.handle({"op": "pull_many", "queue": "q", "max_n": 3})
    b.handle({"op": "ack_many", "tags": got["tags"][:2]})
    dur.commit("broker")
    dur.snapshot("broker", b.snapshot_payload())
    b.handle({"op": "push", "queue": "q", "msg": _msg(6)})
    b.handle({"op": "nack", "tag": got["tags"][2]})
    dur.commit("broker")
    b2 = Broker(durability=dur)
    names = sorted(m["task"]
                   for m in b2.handle({"op": "pull_many", "queue": "q",
                                       "max_n": 10})["msgs"])
    assert names == ["t2", "t3", "t4", "t5", "t6"]   # t0,t1 acked forever


def test_broker_stale_acks_and_nacks_are_idempotent_success():
    b = Broker()                                    # satellite: no durability
    assert b.handle({"op": "ack_many", "tags": [7, 8]}) == \
        {"ok": True, "acked": 0}
    assert b.handle({"op": "nack_many", "tags": [9]}) == \
        {"ok": True, "nacked": 0}
    assert b.stats["stale_acks"] == 3


# -------------------------------------------------------------------- taskdb
def _row(i, status="success"):
    return {"dag": "d", "task": f"t{i}", "try": 1, "status": status,
            "worker": "w0", "clock": 0.0}


def test_taskdb_recovers_rows_and_serves_dedup_probes():
    dur = LogStore()
    db = TaskDB(durability=dur)
    db.handle({"op": "upsert_many", "rows": [_row(0), _row(1)]})
    dur.commit("taskdb")
    dur.snapshot("taskdb", db.snapshot_payload())
    db.handle({"op": "upsert_many", "rows": [_row(2), _row(3, "running")]})
    dur.commit("taskdb")
    db.handle({"op": "upsert", **_row(4)})          # uncommitted -> lost
    dur.lose_uncommitted()
    db2 = TaskDB(durability=dur)
    assert db2.recovery_replayed == 1               # one post-snapshot batch
    probe = db2.handle({"op": "status_many", "keys": [
        ("d", "t0", 1), ("d", "t3", 1), ("d", "t4", 1)]})
    assert probe["statuses"] == ["success", "running", None]
    # the latest-try view rebuilt through the normal upsert path
    assert db2.handle({"op": "latest", "dag": "d",
                       "task": "t2"})["row"]["status"] == "success"
    # every recovered row is dirty from cursor 0: a fresh scheduler's first
    # delta probe sees the full surviving state
    delta = db2.handle({"op": "dag_delta", "dag": "d", "since": 0})
    assert set(delta["tasks"]) == {"t0", "t1", "t2", "t3"}


# -------------------------------------------------- checkpoint (satellite a)
jnp = pytest.importorskip("jax.numpy")
from repro.checkpoint.manager import CheckpointManager  # noqa: E402


def _tree():
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.zeros((3,), dtype=jnp.float32)}


def test_checkpoint_overwrite_same_step_never_loses_committed_tree(tmp_path):
    mgr = CheckpointManager(str(tmp_path), use_async=False)
    mgr.save(1, _tree(), extra={"gen": 1})
    mgr.save(1, _tree(), extra={"gen": 2})          # rename-aside overwrite
    tree, step, extra = mgr.restore(_tree(), step=1)
    assert step == 1 and extra == {"gen": 2}
    assert mgr.all_steps() == [1]                   # no .tmp/.old ghosts


def test_checkpoint_restore_rejects_stale_manifest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), use_async=False)
    mgr.save(3, _tree())
    mpath = tmp_path / "step_00000003" / "manifest.json"
    doc = json.loads(mpath.read_text())
    doc["step"] = 2                                 # dir/manifest disagree
    mpath.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="stale checkpoint"):
        mgr.restore(_tree(), step=3)


def test_checkpoint_restore_rejects_truncated_leaf(tmp_path):
    mgr = CheckpointManager(str(tmp_path), use_async=False)
    mgr.save(5, _tree())
    target = tmp_path / "step_00000005"
    doc = json.loads((target / "manifest.json").read_text())
    leaf = target / doc["leaves"]["w"]["file"]
    leaf.write_bytes(leaf.read_bytes()[:-4])        # torn write
    with pytest.raises(ValueError, match="bytes"):
        mgr.restore(_tree(), step=5)


def test_checkpoint_restore_rejects_missing_leaf(tmp_path):
    mgr = CheckpointManager(str(tmp_path), use_async=False)
    mgr.save(7, _tree())
    target = tmp_path / "step_00000007"
    doc = json.loads((target / "manifest.json").read_text())
    os.remove(target / doc["leaves"]["b"]["file"])
    with pytest.raises(FileNotFoundError, match="leaf file missing"):
        mgr.restore(_tree(), step=7)
