"""Per-arch smoke tests (assignment requirement): a REDUCED config of each
family instantiates and runs one forward + one train step on CPU, asserting
output shapes and no NaNs. Also checks prefill+decode vs full-forward logit
consistency for every family (the serving path computes the same function).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as configs
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import init_train_state, make_train_step
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import MeshPlan

ARCHS = configs.names()
B, S = 2, 16


def build(arch, **overrides):
    cfg = configs.get(arch).reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, remat="none", **overrides)
    plan = MeshPlan(mesh=make_test_mesh(), fsdp=False)
    model = Model(cfg, plan)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def batch_for(cfg, key, b=B, s=S):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)}
    batch["targets"] = jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)
    batch["loss_mask"] = jnp.ones((b, s), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[2], (b, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg, model, params = build(arch)
    batch = batch_for(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_improves_nothing_breaks(arch):
    cfg, model, params = build(arch)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig(peak_lr=1e-3,
                                                      warmup_steps=1,
                                                      total_steps=100), 1))
    batch = batch_for(cfg, jax.random.PRNGKey(2))
    state, m = step(state, batch)
    state, m2 = step(state, batch)           # same batch: loss must not explode
    assert np.isfinite(m2["loss"]) and np.isfinite(m2["grad_norm"])
    assert int(state["opt"]["step"]) == 2


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """decode(prefill(t[:k]), t[k]) logits == forward(t[:k+1]) last logits.

    MoE runs with a high capacity factor here: capacity-based routing drops
    tokens shape-dependently, so exact train/decode equivalence only holds
    when no token is dropped (drop behaviour is tested in test_moe_routing).
    """
    overrides = {"capacity_factor": 8.0} if \
        configs.get(arch).family == "moe" else {}
    cfg, model, params = build(arch, **overrides)
    key = jax.random.PRNGKey(3)
    full = batch_for(cfg, key, b=B, s=S)
    k = S - 1
    prompt = {**full, "tokens": full["tokens"][:, :k]}
    prompt.pop("targets"), prompt.pop("loss_mask")
    logits_full, _ = jax.jit(model.forward)(params, full)
    last_logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=S + 4))(params, prompt)
    # prefill's last logits == forward logits at position k-1
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(logits_full[:, k - 1], np.float32), rtol=0.08, atol=0.08)
    step_logits, cache = jax.jit(model.decode_step)(
        params, full["tokens"][:, k:k + 1], cache)
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(logits_full[:, k], np.float32), rtol=0.08, atol=0.08)


def test_param_count_analytics_match_actual():
    for arch in ARCHS:
        cfg = configs.get(arch).reduced()
        model = Model(cfg, MeshPlan(mesh=make_test_mesh(), fsdp=False))
        actual = sum(x.size for x in jax.tree_util.tree_leaves(
            model.abstract_params()))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.02, \
            f"{arch}: analytic {analytic} vs actual {actual}"
