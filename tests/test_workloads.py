"""Roofline-cost-aware placement + the compiled-step cache (ISSUE 8).

Control-plane half (no jax): cost vectors / classification, steering tags,
queue-name routing, dispatcher tier preference, autoscaler family classes,
and the acceptance guarantee that cost-aware OFF (or an unpriced task) is
behavior-identical to the depth-aware-only plane.

Workload half (jax): TrainerCache hit/miss/evict semantics, warm-worker
reuse through the composer, and exactly-once step accounting when a train
task resumes from its own checkpoint.
"""
import pytest

from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.pipelines import DAG, Task, HybridComposer
from repro.pipelines.scheduler import queue_for
from repro.roofline.cost import (ACCEL_CAP, CHEAP_IO_CAP, CostVector,
                                 classify, steering_tag, task_cost)
from tests.conftest import make_plane


# ------------------------------------------------------------ cost vectors
def test_classification_roofline_split():
    assert classify(CostVector(flops=0.0, io_bytes=1e9)) == "io"
    assert classify(CostVector(flops=1e12, hbm_bytes=1e9)) == "compute"
    assert classify(CostVector(flops=1e9, hbm_bytes=1e9)) == "memory"


def test_builtin_kinds_priced_analytically():
    train = Task("t", kind="train", payload={"steps": 10, "seq_len": 64,
                                             "global_batch": 8})
    ev = Task("e", kind="eval", payload={"seq_len": 64, "global_batch": 8})
    etl = Task("x", kind="etl", payload={"batches": 2})
    exp = Task("o", kind="export")
    srv = Task("s", kind="serve", payload={"slots": 4})
    assert classify(task_cost(train)) == "compute"
    assert steering_tag(train) == ACCEL_CAP
    assert classify(task_cost(ev)) == "compute"
    assert classify(task_cost(etl)) == "io"
    assert steering_tag(etl) == CHEAP_IO_CAP
    assert classify(task_cost(exp)) == "io"
    # decode: ~slots flops per HBM byte, below the machine balance
    assert classify(task_cost(srv)) == "memory"
    assert steering_tag(srv) == ACCEL_CAP


def test_unpriced_tasks_never_steered():
    py = Task("p", kind="python")
    assert task_cost(py) is None and steering_tag(py) is None
    unknown = Task("u", kind="train", payload={"arch": "no-such-arch"})
    assert task_cost(unknown) is None and steering_tag(unknown) is None
    # cost-aware routing is a strict no-op for both
    assert queue_for(py, cost_aware=True) == "default"
    assert queue_for(unknown, cost_aware=True) == "default"


def test_explicit_cost_and_artifact_beat_the_estimate():
    # an etl task whose committed dry-run artifact says it is compute-bound
    t = Task("t", kind="etl", cost={"flops": 1e12, "hbm_bytes": 1e9})
    assert steering_tag(t) == ACCEL_CAP
    # same artifact inlined in the payload (hlo_stats.stats_to_json shape)
    t2 = Task("t2", kind="etl",
              payload={"hlo_stats": {"flops": 1e12, "hbm_bytes": 1e9}})
    assert steering_tag(t2) == ACCEL_CAP


# ---------------------------------------------------------- queue routing
def test_queue_for_cost_aware_off_is_todays_behavior():
    tasks = [Task("a", kind="train", payload={"steps": 5}),
             Task("b", kind="etl"),
             Task("c", kind="python", requires=("onprem",)),
             Task("d", kind="eval", requires=("gpu", "onprem"))]
    expected = ["default", "default", "onprem", "gpu,onprem"]
    for t, q in zip(tasks, expected):
        assert queue_for(t) == q                      # default: off
        assert queue_for(t, cost_aware=False) == q


def test_queue_for_cost_aware_merges_steering_tag():
    t = Task("t", kind="train", payload={"steps": 5}, requires=("onprem",))
    assert queue_for(t, cost_aware=True) == "accel,onprem"
    assert queue_for(Task("x", kind="etl"), cost_aware=True) == "cheap-io"


def test_cost_aware_off_runs_priced_dag_on_default_queue_only():
    """Acceptance: with cost_aware off, priced tasks route exactly as today —
    the broker only ever sees the queues the requires tags imply."""
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    comp = HybridComposer(plane, workers={"onprem-a": ["w0"]})

    def instant(p):
        return {"ok": 1}

    comp.workers[0].register("sim_train", instant)
    comp.workers[0].register("sim_etl", instant)
    dag = DAG("d", [Task("t", kind="sim_train",
                         cost={"flops": 1e12, "hbm_bytes": 1e9}),
                    Task("x", kind="sim_etl", cost={"io_bytes": 1e9},
                         upstream=("t",))])
    comp.add_dag(dag)
    assert comp.run_dag("d", max_ticks=60)
    assert set(comp.broker.queues) == {"default"}


# ------------------------------------------------------- dispatcher tiers
def test_dispatcher_prefers_matching_tier_for_cost_class():
    plane = make_plane(2, caps={0: ("cpu", "accel"),
                                1: ("cpu", "cheap-io")})
    jid = plane.submit_job("sim", steps=5,
                           tags={"requires": ("cpu",),
                                 "cost_class": "compute"})
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]
    assert placed["cluster"] == "onprem-0"
    jid2 = plane.submit_job("sim", steps=5,
                            tags={"requires": ("cpu",), "cost_class": "io"})
    placed2 = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid2}/placement"})["value"]
    assert placed2["cluster"] == "onprem-1"


def test_dispatcher_cost_class_degrades_without_matching_tier():
    # no accel-tier cluster registered: the preference is soft — placement
    # falls back to plain least-load instead of failing
    plane = make_plane(2)
    jid = plane.submit_job("sim", steps=5,
                           tags={"requires": ("cpu",),
                                 "cost_class": "compute"})
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]
    assert placed["cluster"] in ("onprem-0", "onprem-1")


def test_dispatcher_untagged_job_pick_unchanged():
    plane = make_plane(2, caps={0: ("cpu", "accel"),
                                1: ("cpu", "cheap-io")})
    # cost_class absent: byte-identical to the pre-cost plane (least-load)
    picked = {plane.dispatcher.pick({"job_id": f"j{i}",
                                     "tags": {"requires": ("cpu",)}})
              for i in range(4)}
    assert picked == {"onprem-0", "onprem-1"}      # round-robin over the tie


# ------------------------------------------------------ autoscaler family
def test_scaling_policy_folds_cost_class_into_requires():
    from repro.autoscale import ScalingPolicy
    pol = ScalingPolicy(family="train", queues=("accel",), requires=("cpu",),
                        cost_class="compute")
    assert ACCEL_CAP in pol.requires
    pol2 = ScalingPolicy(family="etl", queues=("cheap-io",),
                         cost_class="io")
    assert CHEAP_IO_CAP in pol2.requires
    with pytest.raises(ValueError):
        ScalingPolicy(family="bad", queues=("q",), cost_class="quantum")


# ------------------------------------------------------ compiled-step cache
def _train_cfg(**kw):
    from repro.runtime.train_loop import TrainJobConfig
    base = dict(arch="qwen3-0.6b", seq_len=8, global_batch=2, steps=1)
    base.update(kw)
    return TrainJobConfig(**base)


def test_trainer_cache_hit_miss_evict():
    from repro.runtime.step_cache import TrainerCache
    cache = TrainerCache(capacity=1)
    a = cache.get(_train_cfg())
    # per-run knobs (steps, seed, checkpoint_dir) are NOT part of the key
    a2 = cache.get(_train_cfg(steps=3, seed=7))
    assert a2 is a
    assert a2.cfg.steps == 3 and a2.step == 0     # rebound to the new task
    # a different compiled family misses and (capacity=1) evicts the first
    b = cache.get(_train_cfg(seq_len=16))
    assert b is not a
    a3 = cache.get(_train_cfg())
    assert a3 is not a
    assert cache.stats() == {"hits": 1, "misses": 3, "evictions": 2,
                             "size": 1}


def test_cache_capacity_zero_always_builds_cold():
    from repro.runtime.step_cache import TrainerCache
    cache = TrainerCache(capacity=0)
    a = cache.get(_train_cfg())
    b = cache.get(_train_cfg())
    assert b is not a and len(cache) == 0
    assert cache.stats()["misses"] == 2


def test_rebind_reproduces_cold_run(tmp_path):
    """A warm trainer re-armed for a new task must produce bit-identical
    losses to a cold build with the same config."""
    from repro.runtime.train_loop import Trainer
    cfg = _train_cfg(steps=4, seed=3)
    cold = Trainer(cfg)
    cold.run()
    warm = Trainer(_train_cfg(steps=2, seed=3))    # same family, other task
    warm.run()
    warm.rebind(cfg)
    assert warm.step == 0
    warm.run()
    assert cold.metrics.series("loss") == pytest.approx(
        warm.metrics.series("loss"), rel=1e-6)


def test_worker_cache_reuse_through_composer():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    comp = HybridComposer(plane, workers={"onprem-a": ["w0"]}, step_cache=4)
    payload = {"arch": "qwen3-0.6b", "steps": 1, "seq_len": 8,
               "global_batch": 2}
    dag = DAG("c", [Task(f"s{i}", kind="train", payload=dict(payload),
                         upstream=(f"s{i - 1}",) if i else ())
                    for i in range(3)])
    comp.add_dag(dag)
    assert comp.run_dag("c", max_ticks=100)
    stats = comp.workers[0]._trainer_cache.stats()
    assert stats["misses"] == 1 and stats["hits"] == 2
    state = comp.taskdb.handle({"op": "dag_state", "dag": "c"})["tasks"]
    for row in state.values():
        assert row["status"] == "success"
        assert row["result"]["steps"] == 1 and row["result"]["ran_steps"] == 1


def test_train_task_resume_exactly_once_accounting(tmp_path):
    """The handler-level resume contract: a re-delivered/continued train task
    restores the committed step and runs only the remainder."""
    from repro.runtime.step_cache import run_train_task
    payload = {"arch": "qwen3-0.6b", "seq_len": 8, "global_batch": 2,
               "steps": 4, "checkpoint_every": 2,
               "checkpoint_dir": str(tmp_path / "ck")}
    r1 = run_train_task(None, payload)
    assert r1["steps"] == 4 and r1["ran_steps"] == 4
    assert r1["resumed_from"] == 0 and r1["checkpoint"]["step"] == 4
    # redelivery after the checkpoint committed: nothing re-runs
    r2 = run_train_task(None, dict(payload))
    assert r2["steps"] == 4 and r2["ran_steps"] == 0
    assert r2["resumed_from"] == 4
    # a later stage raising the target runs only the delta
    r3 = run_train_task(None, {**payload, "steps": 6})
    assert r3["steps"] == 6 and r3["ran_steps"] == 2
    assert r3["resumed_from"] == 4
