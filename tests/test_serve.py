"""Serving: continuous batching must not change results (greedy decoding is
batch-size invariant), slots must be reused, EOS must free slots early."""
import pytest

from repro.runtime.serve_loop import Server, ServeJobConfig

PROMPTS = [[1, 2, 3, 4], [9, 8, 7], [5, 5], [2, 4, 6, 8, 10]]


def generate(slots, prompts, max_new=6, **kw):
    sv = Server(ServeJobConfig(arch="qwen3-0.6b", slots=slots, max_len=64,
                               seed=11, **kw))
    ids = [sv.submit(p, max_new=max_new) for p in prompts]
    sv.run()
    return {i: sv.requests[i].generated for i in ids}, sv


def test_batching_invariance():
    solo, _ = generate(1, PROMPTS)
    batched, _ = generate(4, PROMPTS)
    assert list(solo.values()) == list(batched.values())


def test_slot_reuse_more_requests_than_slots():
    out, sv = generate(2, PROMPTS, max_new=4)
    assert all(len(g) == 4 for g in out.values())
    assert all(r.done for r in sv.requests.values())


def test_eos_frees_slot_early():
    # run once to discover the first emitted token, then use it as EOS
    probe, _ = generate(1, [PROMPTS[0]], max_new=4)
    eos = list(probe.values())[0][0]
    out, sv = generate(2, [PROMPTS[0]], max_new=8, eos_id=int(eos))
    gen = list(out.values())[0]
    assert gen[-1] == eos and len(gen) < 8


def test_mixed_lengths_no_head_of_line_blocking():
    out, sv = generate(2, [[1, 2, 3]] * 2 + [[4, 5, 6]], max_new=3)
    assert len(out) == 3
    assert all(len(g) == 3 for g in out.values())
